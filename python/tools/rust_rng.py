"""Bit-exact Python port of rust/src/init/rng.rs (+ the data sources'
batch streams) for fixture generation and test calibration.

Keep in lockstep with the Rust side: splitmix64, xoshiro256++, Box-Muller
gaussian with spare, zipf-by-CDF, `Rng::fork`, and the LmSource /
VisionSource batch derivations.  Any drift here invalidates calibration
numbers, not shipped tests — the Rust tests consume their own RNG — but
bit-exactness is what makes numpy-side calibration trustworthy.
"""

from __future__ import annotations

import math

M64 = (1 << 64) - 1


def splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & M64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return (z ^ (z >> 31)) & M64


def u64_to_unit(z: int) -> float:
    return (z >> 11) * (1.0 / (1 << 53))


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    """xoshiro256++ seeded via splitmix64, like rust Rng::new."""

    def __init__(self, seed: int):
        s = []
        x = seed & M64
        for _ in range(4):
            x = (x + 0x9E3779B97F4A7C15) & M64
            s.append(splitmix64(x))
        self.s = s
        self.spare = None

    def fork(self, stream: int) -> "Rng":
        mix = splitmix64(self.s[0] ^ splitmix64((stream * 0x9E3779B97F4A7C15) & M64))
        return Rng(mix)

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def uniform(self) -> float:
        return u64_to_unit(self.next_u64())

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def gaussian(self) -> float:
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        while True:
            u1 = self.uniform()
            u2 = self.uniform()
            if u1 <= 2.2250738585072014e-308:
                continue
            r = math.sqrt(-2.0 * math.log(u1))
            self.spare = r * math.sin(2.0 * math.pi * u2)
            return r * math.cos(2.0 * math.pi * u2)

    def gaussian_vec(self, n: int, std: float):
        import numpy as np

        return np.array([self.gaussian() * std for _ in range(n)], np.float32)

    def zipf(self, n: int, cdf) -> int:
        u = self.uniform() * cdf[n - 1]
        import bisect

        i = bisect.bisect_left(cdf, u)
        return min(i, n - 1)


def zipf_cdf(n: int, s: float):
    acc = 0.0
    out = []
    for k in range(1, n + 1):
        acc += 1.0 / (k**s)
        out.append(acc)
    return out


# --- data sources (rust/src/data/{corpus,vision}.rs) -----------------------


class LmSource:
    def __init__(self, vocab, batch, seq, seed, copy_p=0.55, induct_p=0.2,
                 zipf_s=1.1, a=5, b=3):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.copy_p, self.induct_p, self.zipf_s, self.a, self.b = (
            copy_p, induct_p, zipf_s, a, b)
        self.cdf = zipf_cdf(vocab, zipf_s)

    def batch_tokens(self, split_val: bool, step: int):
        import numpy as np

        stream = step * 2 + (1 if split_val else 0)
        base = Rng(self.seed ^ 0xC0FFEE).fork(stream)
        ln = self.seq + 1
        rows = []
        for row_i in range(self.batch):
            rng = base.fork(row_i)
            v = self.vocab
            prev = rng.below(v)
            out = [prev]
            succ = [None] * v
            for _ in range(1, ln):
                u = rng.uniform()
                if u < self.copy_p:
                    nxt = (self.a * prev + self.b) % v
                elif u < self.copy_p + self.induct_p:
                    nxt = succ[prev] if succ[prev] is not None else rng.zipf(v, self.cdf)
                else:
                    nxt = rng.zipf(v, self.cdf)
                succ[prev] = nxt
                out.append(nxt)
                prev = nxt
            rows.append(out)
        return np.array(rows, np.int32)


class VisionSource:
    def __init__(self, d_in, n_class, batch, seed, margin=2.5, noise=0.6,
                 warp=0.5, geometry_seed=1234):
        import numpy as np

        self.d_in, self.n_class, self.batch, self.seed = d_in, n_class, batch, seed
        self.noise, self.warp = noise, warp
        g = Rng(geometry_seed)
        scale = margin / math.sqrt(d_in)
        self.means = [g.gaussian_vec(d_in, scale) for _ in range(n_class)]
        self.warps = [g.gaussian_vec(d_in, 1.0 / math.sqrt(d_in)) for _ in range(n_class)]
        self._np = np

    def batch_xy(self, split_val: bool, step: int):
        np = self._np
        stream = step * 2 + (1 if split_val else 0)
        rng = Rng(self.seed ^ 0xF00D).fork(stream)
        xs, ys = [], []
        for _ in range(self.batch):
            c = rng.below(self.n_class)
            ys.append(c)
            z = rng.gaussian_vec(self.d_in, self.noise)
            z2 = float((z.astype(np.float64) ** 2).sum() / self.d_in)
            centered = z2 - self.noise * self.noise
            xs.append(self.means[c] + z + np.float32(self.warp * centered) * self.warps[c])
        return np.stack(xs), np.array(ys, np.int32)
