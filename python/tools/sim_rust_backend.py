"""Loop-level simulation of rust/src/runtime/native/ for pre-merge
verification: transcribes the Rust implementation's exact flat-array
indexing (transformer.rs / mlp.rs / tensor.rs) into Python and diffs the
results against the independently-verified vectorized reference
(native_ref.py).  A mismatch here means the Rust translation has an
indexing/wiring bug; agreement means the Rust code computes the same
function as the finite-difference-checked reference.

Not part of the test suite — a development-time harness (slow, pure
Python loops).  Run on tiny shapes:

    python3 tools/sim_rust_backend.py
"""

from __future__ import annotations

import math
import sys

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/tools")
import native_ref as R  # noqa: E402

F = np.float32


# --- tensor.rs ---------------------------------------------------------


def mm(a, b, m, k, n):
    c = [F(0.0)] * (m * n)
    for i in range(m):
        for l in range(k):
            av = a[i * k + l]
            for j in range(n):
                c[i * n + j] = F(c[i * n + j] + F(av * b[l * n + j]))
    return c


def mm_tn(a, b, k, m, n):
    c = [F(0.0)] * (m * n)
    for l in range(k):
        for i in range(m):
            av = a[l * m + i]
            for j in range(n):
                c[i * n + j] = F(c[i * n + j] + F(av * b[l * n + j]))
    return c


def mm_nt(a, b, m, k, n):
    c = [F(0.0)] * (m * n)
    for i in range(m):
        for j in range(n):
            acc = F(0.0)
            for l in range(k):
                acc = F(acc + F(a[i * k + l] * b[j * k + l]))
            c[i * n + j] = acc
    return c


def layernorm(x, g, b, rows, d):
    y = [F(0.0)] * (rows * d)
    xhat = [F(0.0)] * (rows * d)
    rstd = [F(0.0)] * rows
    inv_d = F(1.0 / d)
    for r in range(rows):
        mu = F(0.0)
        for j in range(d):
            mu = F(mu + x[r * d + j])
        mu = F(mu * inv_d)
        var = F(0.0)
        for j in range(d):
            cc = F(x[r * d + j] - mu)
            var = F(var + F(cc * cc))
        var = F(var * inv_d)
        rs = F(1.0 / math.sqrt(F(var + F(1e-5))))
        rstd[r] = rs
        for j in range(d):
            h = F(F(x[r * d + j] - mu) * rs)
            xhat[r * d + j] = h
            y[r * d + j] = F(F(h * g[j]) + b[j])
    return y, (xhat, rstd)


def layernorm_bwd(dy, g, cache, rows, d, dg, db):
    xhat, rstd = cache
    dx = [F(0.0)] * (rows * d)
    inv_d = F(1.0 / d)
    for r in range(rows):
        m1 = F(0.0)
        m2 = F(0.0)
        for j in range(d):
            dxh = F(dy[r * d + j] * g[j])
            m1 = F(m1 + dxh)
            m2 = F(m2 + F(dxh * xhat[r * d + j]))
            dg[j] = F(dg[j] + F(dy[r * d + j] * xhat[r * d + j]))
            db[j] = F(db[j] + dy[r * d + j])
        m1 = F(m1 * inv_d)
        m2 = F(m2 * inv_d)
        for j in range(d):
            dxh = F(dy[r * d + j] * g[j])
            dx[r * d + j] = F(rstd[r] * F(F(dxh - m1) - F(xhat[r * d + j] * m2)))
    return dx


def softmax_prefix(row, active):
    m = max(row[:active])
    s = F(0.0)
    for j in range(active):
        row[j] = F(math.exp(F(row[j] - m)))
        s = F(s + row[j])
    inv = F(1.0 / s)
    for j in range(active):
        row[j] = F(row[j] * inv)
    for j in range(active, len(row)):
        row[j] = F(0.0)


def xent(logits, targets, n):
    rows = len(targets)
    d = [F(0.0)] * (rows * n)
    inv_rows = F(1.0 / rows)
    acc = 0.0
    for r in range(rows):
        lr = logits[r * n : (r + 1) * n]
        m = max(lr)
        s = F(0.0)
        for v in lr:
            s = F(s + F(math.exp(F(v - m))))
        lse = F(m + F(math.log(s)))
        acc += float(F(lse - lr[targets[r]]))
        inv_sum = F(1.0 / s)
        for j in range(n):
            d[r * n + j] = F(F(F(math.exp(F(lr[j] - m))) * inv_sum) * inv_rows)
        d[r * n + targets[r]] = F(d[r * n + targets[r]] - inv_rows)
    return acc / rows, d


# --- transformer.rs ----------------------------------------------------

PB = 10
LN1_G, LN1_B, WQ, WK, WV, WO, LN2_G, LN2_B, W1, W2 = range(10)


class TfmSim:
    def __init__(self, cfg: R.TfmCfg, flat_params):
        self.cfg = cfg
        self.params = flat_params  # list of python lists of F

    def block(self, i, off):
        return self.params[2 + i * PB + off]

    def attn_fwd(self, i, h, scale, want_alog):
        c = self.cfg
        bsz, s, d, da, nh, dh = c.batch, c.seq, c.d_model, c.d_attn, c.n_head, c.d_head
        rows = bsz * s
        q = mm(h, self.block(i, WQ), rows, d, da)
        k = mm(h, self.block(i, WK), rows, d, da)
        v = mm(h, self.block(i, WV), rows, d, da)
        prob = [F(0.0)] * (bsz * nh * s * s)
        alog = [F(0.0)] * (bsz * nh * s * s) if want_alog else []
        merged = [F(0.0)] * (rows * da)
        for b in range(bsz):
            for hh in range(nh):
                head = hh * dh
                for qi in range(s):
                    qrow = q[(b * s + qi) * da + head : (b * s + qi) * da + head + dh]
                    base = ((b * nh + hh) * s + qi) * s
                    prow = prob[base : base + s]
                    for kj in range(qi + 1):
                        krow = k[(b * s + kj) * da + head : (b * s + kj) * da + head + dh]
                        dot = F(0.0)
                        for t in range(dh):
                            dot = F(dot + F(F(qrow[t] * scale) * krow[t]))
                        prow[kj] = dot
                    if want_alog:
                        alog[base : base + qi + 1] = prow[: qi + 1]
                    softmax_prefix(prow, qi + 1)
                    prob[base : base + s] = prow
                    ctx = [F(0.0)] * dh
                    for kj in range(qi + 1):
                        p = prob[base + kj]
                        vrow = v[(b * s + kj) * da + head : (b * s + kj) * da + head + dh]
                        for t in range(dh):
                            ctx[t] = F(ctx[t] + F(p * vrow[t]))
                    mb = (b * s + qi) * da + head
                    merged[mb : mb + dh] = ctx
        out = mm(merged, self.block(i, WO), rows, da, d)
        return out, alog, q, k, v, prob, merged

    def attn_bwd(self, i, dout, scale, cache, grads):
        c = self.cfg
        bsz, s, d, da, nh, dh = c.batch, c.seq, c.d_model, c.d_attn, c.n_head, c.d_head
        rows = bsz * s
        gb = 2 + i * PB
        q, k, v, prob, merged, attn_in = cache
        axpy(grads[gb + WO], mm_tn(merged, dout, rows, da, d))
        dmerged = mm_nt(dout, self.block(i, WO), rows, d, da)
        dq = [F(0.0)] * (rows * da)
        dk = [F(0.0)] * (rows * da)
        dv = [F(0.0)] * (rows * da)
        dprob = [F(0.0)] * s
        for b in range(bsz):
            for hh in range(nh):
                head = hh * dh
                for qi in range(s):
                    dctx = dmerged[(b * s + qi) * da + head : (b * s + qi) * da + head + dh]
                    base = ((b * nh + hh) * s + qi) * s
                    sum_dp = F(0.0)
                    for kj in range(qi + 1):
                        vrow = v[(b * s + kj) * da + head : (b * s + kj) * da + head + dh]
                        dot = F(0.0)
                        for t in range(dh):
                            dot = F(dot + F(dctx[t] * vrow[t]))
                        dprob[kj] = dot
                        sum_dp = F(sum_dp + F(dot * prob[base + kj]))
                    qrow = q[(b * s + qi) * da + head : (b * s + qi) * da + head + dh]
                    for kj in range(qi + 1):
                        p = prob[base + kj]
                        for t in range(dh):
                            idx = (b * s + kj) * da + head + t
                            dv[idx] = F(dv[idx] + F(p * dctx[t]))
                        dmasked = F(p * F(dprob[kj] - sum_dp))
                        if dmasked == 0.0:
                            continue
                        krow = k[(b * s + kj) * da + head : (b * s + kj) * da + head + dh]
                        for t in range(dh):
                            qidx = (b * s + qi) * da + head + t
                            kidx = (b * s + kj) * da + head + t
                            dq[qidx] = F(dq[qidx] + F(F(dmasked * krow[t]) * scale))
                            dk[kidx] = F(dk[kidx] + F(F(dmasked * qrow[t]) * scale))
        axpy(grads[gb + WQ], mm_tn(attn_in, dq, rows, d, da))
        axpy(grads[gb + WK], mm_tn(attn_in, dk, rows, d, da))
        axpy(grads[gb + WV], mm_tn(attn_in, dv, rows, d, da))
        dh_ = mm_nt(dq, self.block(i, WQ), rows, da, d)
        axpy(dh_, mm_nt(dk, self.block(i, WK), rows, da, d))
        axpy(dh_, mm_nt(dv, self.block(i, WV), rows, da, d))
        return dh_

    def ffn_fwd(self, i, h):
        c = self.cfg
        rows = c.batch * c.seq
        u = mm(h, self.block(i, W1), rows, c.d_model, c.d_ffn)
        r = [x if x > 0.0 else F(0.0) for x in u]
        f = mm(r, self.block(i, W2), rows, c.d_ffn, c.d_model)
        return f, u, r

    def ffn_bwd(self, i, df, u, r, ffn_in, grads):
        c = self.cfg
        rows = c.batch * c.seq
        gb = 2 + i * PB
        axpy(grads[gb + W2], mm_tn(r, df, rows, c.d_ffn, c.d_model))
        dr = mm_nt(df, self.block(i, W2), rows, c.d_model, c.d_ffn)
        du = [g if x > 0.0 else F(0.0) for g, x in zip(dr, u)]
        axpy(grads[gb + W1], mm_tn(ffn_in, du, rows, c.d_model, c.d_ffn))
        return mm_nt(du, self.block(i, W1), rows, c.d_ffn, c.d_model)

    def forward_backward(self, tokens, hp):
        c = self.cfg
        bsz, s, d, v = c.batch, c.seq, c.d_model, c.vocab
        rows = bsz * s
        attn_scale, output_scale, embed_scale = F(hp[0]), F(hp[1]), F(hp[2])
        pre = c.ln == "pre"
        t_in, t_gt = [], []
        for b in range(bsz):
            for j in range(s):
                t_in.append(tokens[b * (s + 1) + j])
                t_gt.append(tokens[b * (s + 1) + j + 1])
        embed, pos = self.params[0], self.params[1]
        x = [F(0.0)] * (rows * d)
        for r in range(rows):
            tok = t_in[r]
            p = (r % s) * d
            for j in range(d):
                x[r * d + j] = F(F(embed[tok * d + j] + pos[p + j]) * embed_scale)
        x0 = list(x)
        blocks = []
        alog0 = None
        for i in range(c.n_layer):
            g1, b1 = self.block(i, LN1_G), self.block(i, LN1_B)
            g2, b2 = self.block(i, LN2_G), self.block(i, LN2_B)
            want_alog = i == 0
            if pre:
                h1, ln1 = layernorm(x, g1, b1, rows, d)
                a, alog, q, k, vv, prob, merged = self.attn_fwd(i, h1, attn_scale, want_alog)
                x1 = [F(xa + xb) for xa, xb in zip(x, a)]
                h2, ln2 = layernorm(x1, g2, b2, rows, d)
                f, u, rr = self.ffn_fwd(i, h2)
                x = [F(xa + xb) for xa, xb in zip(x1, f)]
                blocks.append(dict(attn_in=h1, q=q, k=k, v=vv, prob=prob, merged=merged,
                                   ffn_in=h2, u=u, r=rr, ln1=ln1, ln2=ln2))
            else:
                a, alog, q, k, vv, prob, merged = self.attn_fwd(i, x, attn_scale, want_alog)
                attn_in = x
                y1 = [F(xa + xb) for xa, xb in zip(attn_in, a)]
                x1, ln1 = layernorm(y1, g1, b1, rows, d)
                f, u, rr = self.ffn_fwd(i, x1)
                y2 = [F(xa + xb) for xa, xb in zip(x1, f)]
                x, ln2 = layernorm(y2, g2, b2, rows, d)
                blocks.append(dict(attn_in=attn_in, q=q, k=k, v=vv, prob=prob, merged=merged,
                                   ffn_in=x1, u=u, r=rr, ln1=ln1, ln2=ln2))
            if want_alog:
                alog0 = alog
        if pre:
            li = 2 + c.n_layer * PB
            xf, lnf = layernorm(x, self.params[li], self.params[li + 1], rows, d)
        else:
            xf, lnf = x, None
        un = len(self.params) - 1
        logits = mm(xf, self.params[un], rows, d, v)
        logits = [F(l * output_scale) for l in logits]
        loss, dlogits = xent(logits, t_gt, v)

        grads = [[F(0.0)] * len(p) for p in self.params]
        dlogits = [F(g * output_scale) for g in dlogits]
        axpy(grads[un], mm_tn(xf, dlogits, rows, d, v))
        dxf = mm_nt(dlogits, self.params[un], rows, v, d)
        if pre:
            li = 2 + c.n_layer * PB
            dx = layernorm_bwd(dxf, self.params[li], lnf, rows, d, grads[li], grads[li + 1])
        else:
            dx = dxf
        for i in reversed(range(c.n_layer)):
            gb = 2 + i * PB
            bl = blocks[i]
            acache = (bl["q"], bl["k"], bl["v"], bl["prob"], bl["merged"], bl["attn_in"])
            if pre:
                dh2 = self.ffn_bwd(i, dx, bl["u"], bl["r"], bl["ffn_in"], grads)
                dln2 = layernorm_bwd(dh2, self.block(i, LN2_G), bl["ln2"], rows, d,
                                     grads[gb + LN2_G], grads[gb + LN2_B])
                dx1 = list(dx)
                axpy(dx1, dln2)
                dh1 = self.attn_bwd(i, dx1, attn_scale, acache, grads)
                dln1 = layernorm_bwd(dh1, self.block(i, LN1_G), bl["ln1"], rows, d,
                                     grads[gb + LN1_G], grads[gb + LN1_B])
                dx = list(dx1)
                axpy(dx, dln1)
            else:
                dy2 = layernorm_bwd(dx, self.block(i, LN2_G), bl["ln2"], rows, d,
                                    grads[gb + LN2_G], grads[gb + LN2_B])
                dx1 = list(dy2)
                axpy(dx1, self.ffn_bwd(i, dy2, bl["u"], bl["r"], bl["ffn_in"], grads))
                dy1 = layernorm_bwd(dx1, self.block(i, LN1_G), bl["ln1"], rows, d,
                                    grads[gb + LN1_G], grads[gb + LN1_B])
                dx = list(dy1)
                axpy(dx, self.attn_bwd(i, dy1, attn_scale, acache, grads))
        for r in range(rows):
            tok = t_in[r]
            p = (r % s) * d
            for j in range(d):
                ds = F(dx[r * d + j] * embed_scale)
                grads[0][tok * d + j] = F(grads[0][tok * d + j] + ds)
                grads[1][p + j] = F(grads[1][p + j] + ds)
        probes = dict(embed_out=x0, attn_logits_l0=alog0, block_out=xf, logits=logits)
        return loss, grads, probes


def axpy(dst, src):
    for i in range(len(dst)):
        dst[i] = F(dst[i] + src[i])


# --- harness -----------------------------------------------------------


def flat(a):
    return [F(x) for x in np.asarray(a, F).reshape(-1)]


def compare(tag, got, want, tol=2e-5):
    got = np.array(got, np.float64)
    want = np.asarray(want, np.float64).reshape(-1)
    denom = np.maximum(1.0, np.maximum(np.abs(got), np.abs(want)))
    rel = np.abs(got - want) / denom
    worst = float(rel.max()) if rel.size else 0.0
    status = "ok" if worst < tol else "FAIL"
    print(f"  {tag:<18} worst rel {worst:.2e}  {status}")
    return worst < tol


def run_tfm(ln):
    cfg = R.TfmCfg(vocab=13, seq=7, batch=3, d_model=8, n_layer=2,
                   n_head=2, d_head=4, d_ffn=12, ln=ln)
    specs = R.tfm_param_specs(cfg)
    params_np = {name: R.det_fill(shape, 50 + i, 0.08, F) for i, (name, shape, _) in enumerate(specs)}
    tokens_np = R.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 321)
    hp = [0.31, 1.7, 0.9, 0.9, 0.999, 1e-8, 0.0, 1.0]
    loss_ref, grads_ref, probes_ref = R.tfm_fwd_bwd(cfg, params_np, tokens_np, hp)

    sim = TfmSim(cfg, [flat(params_np[name]) for name, _, _ in specs])
    loss_sim, grads_sim, probes_sim = sim.forward_backward(
        [int(t) for t in tokens_np.reshape(-1)], hp
    )
    print(f"transformer {ln}-ln: loss sim {loss_sim:.6f} ref {loss_ref:.6f}")
    ok = abs(loss_sim - loss_ref) < 1e-5 * (1 + abs(loss_ref))
    for key in ["embed_out", "attn_logits_l0", "block_out", "logits"]:
        ok &= compare(f"probe {key}", probes_sim[key], probes_ref[key])
    for i, (name, _, _) in enumerate(specs):
        ok &= compare(f"grad {name}", grads_sim[i], grads_ref[name])
    return ok


def main():
    ok = True
    for ln in ["post", "pre"]:
        ok &= run_tfm(ln)
    if not ok:
        print("SIMULATION MISMATCH", file=sys.stderr)
        return 1
    print("rust-structure simulation matches the verified reference")
    return 0


if __name__ == "__main__":
    sys.exit(main())
