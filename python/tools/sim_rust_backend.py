"""Loop-level simulation of rust/src/runtime/native/ for pre-merge
verification: transcribes the Rust implementation's exact flat-array
indexing (transformer.rs / mlp.rs / tensor.rs) into Python and diffs the
results against the independently-verified vectorized reference
(native_ref.py).  A mismatch here means the Rust translation has an
indexing/wiring bug; agreement means the Rust code computes the same
function as the finite-difference-checked reference.

Not part of the test suite — a development-time harness (slow, pure
Python loops).  Run on tiny shapes:

    python3 tools/sim_rust_backend.py
"""

from __future__ import annotations

import math
import sys

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/tools")
import native_ref as R  # noqa: E402

F = np.float32


# --- tensor.rs ---------------------------------------------------------


# Blocked kernels (PR 3): panel-packed, register-tiled GEMMs.  The index
# arithmetic below is a line-for-line transcription of the Rust blocked
# drivers; _micro mirrors the MR x NR microkernel including its f32
# accumulation order (k ascending within a KC block, KC blocks ascending).

MR = 4  # microkernel rows (the 4x unroll)
NR = 16  # B-panel width
KC = 256  # k-dimension cache block
NC = 256  # n-dimension cache block (multiple of NR)


def _pack_b(b, k0, kb, j0, nb, n):
    npan = (nb + NR - 1) // NR
    out = [F(0.0)] * (npan * kb * NR)
    for p in range(npan):
        jl = j0 + p * NR
        w = min(NR, j0 + nb - jl)
        dst0 = p * kb * NR
        for l in range(kb):
            src = (k0 + l) * n + jl
            dst = dst0 + l * NR
            out[dst : dst + w] = b[src : src + w]
    return out


def _pack_bt(b, k0, kb, j0, nb, kstride):
    npan = (nb + NR - 1) // NR
    out = [F(0.0)] * (npan * kb * NR)
    for p in range(npan):
        jl = j0 + p * NR
        w = min(NR, j0 + nb - jl)
        dst0 = p * kb * NR
        for jr in range(w):
            src = (jl + jr) * kstride + k0
            for l in range(kb):
                out[dst0 + l * NR + jr] = b[src + l]
    return out


def _pack_at(a, k0, kb, m):
    out = [F(0.0)] * (m * kb)
    for i in range(m):
        for l in range(kb):
            out[i * kb + l] = a[(k0 + l) * m + i]
    return out


def _micro(a, a_off, a_stride, mr, panel, kb, c, c_off, c_stride, w):
    acc = [[F(0.0)] * NR for _ in range(MR)]
    for l in range(kb):
        bl = panel[l * NR : (l + 1) * NR]
        for r in range(mr):
            av = a[a_off + r * a_stride + l]
            accr = acc[r]
            for j in range(NR):
                accr[j] = F(accr[j] + F(av * bl[j]))
    for r in range(mr):
        base = c_off + r * c_stride
        for j in range(w):
            c[base + j] = F(c[base + j] + acc[r][j])


def _kernel_block(c, a, a_col0, a_stride, m, panel, kb, j0, nb, n):
    npan = (nb + NR - 1) // NR
    i0 = 0
    while i0 < m:
        mr = min(MR, m - i0)
        for p in range(npan):
            jl = j0 + p * NR
            w = min(NR, j0 + nb - jl)
            _micro(
                a,
                i0 * a_stride + a_col0,
                a_stride,
                mr,
                panel[p * kb * NR : (p + 1) * kb * NR],
                kb,
                c,
                i0 * n + jl,
                n,
                w,
            )
        i0 += mr


def mm_into(c, a, b, m, k, n):
    for k0 in range(0, k, KC):
        kb = min(KC, k - k0)
        for j0 in range(0, n, NC):
            nb = min(NC, n - j0)
            panel = _pack_b(b, k0, kb, j0, nb, n)
            _kernel_block(c, a, k0, k, m, panel, kb, j0, nb, n)


def mm(a, b, m, k, n):
    c = [F(0.0)] * (m * n)
    mm_into(c, a, b, m, k, n)
    return c


def mm_tn_into(c, a, b, k, m, n):
    for k0 in range(0, k, KC):
        kb = min(KC, k - k0)
        at = _pack_at(a, k0, kb, m)
        for j0 in range(0, n, NC):
            nb = min(NC, n - j0)
            panel = _pack_b(b, k0, kb, j0, nb, n)
            _kernel_block(c, at, 0, kb, m, panel, kb, j0, nb, n)


def mm_tn(a, b, k, m, n):
    c = [F(0.0)] * (m * n)
    mm_tn_into(c, a, b, k, m, n)
    return c


def mm_nt_into(c, a, b, m, k, n):
    for k0 in range(0, k, KC):
        kb = min(KC, k - k0)
        for j0 in range(0, n, NC):
            nb = min(NC, n - j0)
            panel = _pack_bt(b, k0, kb, j0, nb, k)
            _kernel_block(c, a, k0, k, m, panel, kb, j0, nb, n)


def mm_nt(a, b, m, k, n):
    c = [F(0.0)] * (m * n)
    mm_nt_into(c, a, b, m, k, n)
    return c


def pack_head(src, row0, s, stride, off, dh):
    dst = [F(0.0)] * (s * dh)
    for si in range(s):
        sb = (row0 + si) * stride + off
        dst[si * dh : (si + 1) * dh] = src[sb : sb + dh]
    return dst


def unpack_head(src, dst, row0, s, stride, off, dh):
    for si in range(s):
        db = (row0 + si) * stride + off
        dst[db : db + dh] = src[si * dh : (si + 1) * dh]


def softmax_ctx_fused(scores, v, s, dh, ctx):
    for qi in range(s):
        row = scores[qi * s : (qi + 1) * s]
        softmax_prefix(row, qi + 1)
        scores[qi * s : (qi + 1) * s] = row
        crow = [F(0.0)] * dh
        kj = 0
        while kj + MR <= s:
            p0, p1, p2, p3 = row[kj], row[kj + 1], row[kj + 2], row[kj + 3]
            for t in range(dh):
                acc = F(F(p0 * v[kj * dh + t]) + F(p1 * v[(kj + 1) * dh + t]))
                acc = F(acc + F(p2 * v[(kj + 2) * dh + t]))
                acc = F(acc + F(p3 * v[(kj + 3) * dh + t]))
                crow[t] = F(crow[t] + acc)
            kj += MR
        while kj < s:
            p = row[kj]
            for t in range(dh):
                crow[t] = F(crow[t] + F(p * v[kj * dh + t]))
            kj += 1
        ctx[qi * dh : (qi + 1) * dh] = crow


def layernorm(x, g, b, rows, d):
    y = [F(0.0)] * (rows * d)
    xhat = [F(0.0)] * (rows * d)
    rstd = [F(0.0)] * rows
    inv_d = F(1.0 / d)
    for r in range(rows):
        mu = F(0.0)
        for j in range(d):
            mu = F(mu + x[r * d + j])
        mu = F(mu * inv_d)
        var = F(0.0)
        for j in range(d):
            cc = F(x[r * d + j] - mu)
            var = F(var + F(cc * cc))
        var = F(var * inv_d)
        rs = F(1.0 / math.sqrt(F(var + F(1e-5))))
        rstd[r] = rs
        for j in range(d):
            h = F(F(x[r * d + j] - mu) * rs)
            xhat[r * d + j] = h
            y[r * d + j] = F(F(h * g[j]) + b[j])
    return y, (xhat, rstd)


def layernorm_bwd(dy, g, cache, rows, d, dg, db):
    xhat, rstd = cache
    dx = [F(0.0)] * (rows * d)
    inv_d = F(1.0 / d)
    for r in range(rows):
        m1 = F(0.0)
        m2 = F(0.0)
        for j in range(d):
            dxh = F(dy[r * d + j] * g[j])
            m1 = F(m1 + dxh)
            m2 = F(m2 + F(dxh * xhat[r * d + j]))
            dg[j] = F(dg[j] + F(dy[r * d + j] * xhat[r * d + j]))
            db[j] = F(db[j] + dy[r * d + j])
        m1 = F(m1 * inv_d)
        m2 = F(m2 * inv_d)
        for j in range(d):
            dxh = F(dy[r * d + j] * g[j])
            dx[r * d + j] = F(rstd[r] * F(F(dxh - m1) - F(xhat[r * d + j] * m2)))
    return dx


def softmax_prefix(row, active):
    m = max(row[:active])
    s = F(0.0)
    for j in range(active):
        row[j] = F(math.exp(F(row[j] - m)))
        s = F(s + row[j])
    inv = F(1.0 / s)
    for j in range(active):
        row[j] = F(row[j] * inv)
    for j in range(active, len(row)):
        row[j] = F(0.0)


def xent(logits, targets, n):
    rows = len(targets)
    d = [F(0.0)] * (rows * n)
    inv_rows = F(1.0 / rows)
    acc = 0.0
    for r in range(rows):
        lr = logits[r * n : (r + 1) * n]
        m = max(lr)
        s = F(0.0)
        for v in lr:
            s = F(s + F(math.exp(F(v - m))))
        lse = F(m + F(math.log(s)))
        acc += float(F(lse - lr[targets[r]]))
        inv_sum = F(1.0 / s)
        for j in range(n):
            d[r * n + j] = F(F(F(math.exp(F(lr[j] - m))) * inv_sum) * inv_rows)
        d[r * n + targets[r]] = F(d[r * n + targets[r]] - inv_rows)
    return acc / rows, d


# --- transformer.rs ----------------------------------------------------

PB = 10
LN1_G, LN1_B, WQ, WK, WV, WO, LN2_G, LN2_B, W1, W2 = range(10)


class TfmSim:
    def __init__(self, cfg: R.TfmCfg, flat_params):
        self.cfg = cfg
        self.params = flat_params  # list of python lists of F

    def block(self, i, off):
        return self.params[2 + i * PB + off]

    def attn_fwd(self, i, h, scale, want_alog):
        c = self.cfg
        bsz, s, d, da, nh, dh = c.batch, c.seq, c.d_model, c.d_attn, c.n_head, c.d_head
        rows = bsz * s
        q = mm(h, self.block(i, WQ), rows, d, da)
        k = mm(h, self.block(i, WK), rows, d, da)
        v = mm(h, self.block(i, WV), rows, d, da)
        prob = [F(0.0)] * (bsz * nh * s * s)
        alog = [F(0.0)] * (bsz * nh * s * s) if want_alog else []
        merged = [F(0.0)] * (rows * da)
        for b in range(bsz):
            for hh in range(nh):
                head = hh * dh
                qh = pack_head(q, b * s, s, da, head, dh)
                kh = pack_head(k, b * s, s, da, head, dh)
                vh = pack_head(v, b * s, s, da, head, dh)
                qh = [F(x * scale) for x in qh]
                blk = (b * nh + hh) * s * s
                scores = [F(0.0)] * (s * s)
                mm_nt_into(scores, qh, kh, s, dh, s)
                if want_alog:
                    for qi in range(s):
                        alog[blk + qi * s : blk + qi * s + qi + 1] = scores[
                            qi * s : qi * s + qi + 1
                        ]
                ctx = [F(0.0)] * (s * dh)
                softmax_ctx_fused(scores, vh, s, dh, ctx)
                prob[blk : blk + s * s] = scores
                unpack_head(ctx, merged, b * s, s, da, head, dh)
        out = mm(merged, self.block(i, WO), rows, da, d)
        return out, alog, q, k, v, prob, merged

    def attn_bwd(self, i, dout, scale, cache, grads):
        c = self.cfg
        bsz, s, d, da, nh, dh = c.batch, c.seq, c.d_model, c.d_attn, c.n_head, c.d_head
        rows = bsz * s
        gb = 2 + i * PB
        q, k, v, prob, merged, attn_in = cache
        axpy(grads[gb + WO], mm_tn(merged, dout, rows, da, d))
        dmerged = mm_nt(dout, self.block(i, WO), rows, d, da)
        dq = [F(0.0)] * (rows * da)
        dk = [F(0.0)] * (rows * da)
        dv = [F(0.0)] * (rows * da)
        for b in range(bsz):
            for hh in range(nh):
                head = hh * dh
                qh = pack_head(q, b * s, s, da, head, dh)
                kh = pack_head(k, b * s, s, da, head, dh)
                vh = pack_head(v, b * s, s, da, head, dh)
                dctx = pack_head(dmerged, b * s, s, da, head, dh)
                blk = (b * nh + hh) * s * s
                pblk = prob[blk : blk + s * s]
                # dprob = dctx · vhᵀ over full rows; masked columns carry
                # exact-zero probabilities so they only contribute zeros
                # (or NaN-poison, matching numpy) below.
                dprob = [F(0.0)] * (s * s)
                mm_nt_into(dprob, dctx, vh, s, dh, s)
                # dvh = probᵀ · dctx
                dvh = [F(0.0)] * (s * dh)
                mm_tn_into(dvh, pblk, dctx, s, s, dh)
                unpack_head(dvh, dv, b * s, s, da, head, dh)
                # softmax backward rowwise: dmasked = p ⊙ (dprob − ⟨dprob, p⟩)
                for qi in range(s):
                    sdp = F(0.0)
                    for j in range(s):
                        sdp = F(sdp + F(dprob[qi * s + j] * pblk[qi * s + j]))
                    for j in range(s):
                        dprob[qi * s + j] = F(
                            pblk[qi * s + j] * F(dprob[qi * s + j] - sdp)
                        )
                # dqh = (dmasked · kh) · scale
                dqh = [F(0.0)] * (s * dh)
                mm_into(dqh, dprob, kh, s, s, dh)
                dqh = [F(x * scale) for x in dqh]
                unpack_head(dqh, dq, b * s, s, da, head, dh)
                # dkh = dmaskedᵀ · (qh · scale)
                qh = [F(x * scale) for x in qh]
                dkh = [F(0.0)] * (s * dh)
                mm_tn_into(dkh, dprob, qh, s, s, dh)
                unpack_head(dkh, dk, b * s, s, da, head, dh)
        axpy(grads[gb + WQ], mm_tn(attn_in, dq, rows, d, da))
        axpy(grads[gb + WK], mm_tn(attn_in, dk, rows, d, da))
        axpy(grads[gb + WV], mm_tn(attn_in, dv, rows, d, da))
        dh_ = mm_nt(dq, self.block(i, WQ), rows, da, d)
        axpy(dh_, mm_nt(dk, self.block(i, WK), rows, da, d))
        axpy(dh_, mm_nt(dv, self.block(i, WV), rows, da, d))
        return dh_

    def ffn_fwd(self, i, h):
        c = self.cfg
        rows = c.batch * c.seq
        u = mm(h, self.block(i, W1), rows, c.d_model, c.d_ffn)
        # mirrors tensor.rs relu: np.maximum semantics, NaN propagates
        r = [x if x > 0.0 or math.isnan(x) else F(0.0) for x in u]
        f = mm(r, self.block(i, W2), rows, c.d_ffn, c.d_model)
        return f, u, r

    def ffn_bwd(self, i, df, u, r, ffn_in, grads):
        c = self.cfg
        rows = c.batch * c.seq
        gb = 2 + i * PB
        axpy(grads[gb + W2], mm_tn(r, df, rows, c.d_ffn, c.d_model))
        dr = mm_nt(df, self.block(i, W2), rows, c.d_model, c.d_ffn)
        du = [g if x > 0.0 else F(0.0) for g, x in zip(dr, u)]
        axpy(grads[gb + W1], mm_tn(ffn_in, du, rows, c.d_model, c.d_ffn))
        return mm_nt(du, self.block(i, W1), rows, c.d_ffn, c.d_model)

    def forward_backward(self, tokens, hp):
        c = self.cfg
        bsz, s, d, v = c.batch, c.seq, c.d_model, c.vocab
        rows = bsz * s
        attn_scale, output_scale, embed_scale = F(hp[0]), F(hp[1]), F(hp[2])
        pre = c.ln == "pre"
        t_in, t_gt = [], []
        for b in range(bsz):
            for j in range(s):
                t_in.append(tokens[b * (s + 1) + j])
                t_gt.append(tokens[b * (s + 1) + j + 1])
        embed, pos = self.params[0], self.params[1]
        x = [F(0.0)] * (rows * d)
        for r in range(rows):
            tok = t_in[r]
            p = (r % s) * d
            for j in range(d):
                x[r * d + j] = F(F(embed[tok * d + j] + pos[p + j]) * embed_scale)
        x0 = list(x)
        blocks = []
        alog0 = None
        for i in range(c.n_layer):
            g1, b1 = self.block(i, LN1_G), self.block(i, LN1_B)
            g2, b2 = self.block(i, LN2_G), self.block(i, LN2_B)
            want_alog = i == 0
            if pre:
                h1, ln1 = layernorm(x, g1, b1, rows, d)
                a, alog, q, k, vv, prob, merged = self.attn_fwd(i, h1, attn_scale, want_alog)
                x1 = [F(xa + xb) for xa, xb in zip(x, a)]
                h2, ln2 = layernorm(x1, g2, b2, rows, d)
                f, u, rr = self.ffn_fwd(i, h2)
                x = [F(xa + xb) for xa, xb in zip(x1, f)]
                blocks.append(dict(attn_in=h1, q=q, k=k, v=vv, prob=prob, merged=merged,
                                   ffn_in=h2, u=u, r=rr, ln1=ln1, ln2=ln2))
            else:
                a, alog, q, k, vv, prob, merged = self.attn_fwd(i, x, attn_scale, want_alog)
                attn_in = x
                y1 = [F(xa + xb) for xa, xb in zip(attn_in, a)]
                x1, ln1 = layernorm(y1, g1, b1, rows, d)
                f, u, rr = self.ffn_fwd(i, x1)
                y2 = [F(xa + xb) for xa, xb in zip(x1, f)]
                x, ln2 = layernorm(y2, g2, b2, rows, d)
                blocks.append(dict(attn_in=attn_in, q=q, k=k, v=vv, prob=prob, merged=merged,
                                   ffn_in=x1, u=u, r=rr, ln1=ln1, ln2=ln2))
            if want_alog:
                alog0 = alog
        if pre:
            li = 2 + c.n_layer * PB
            xf, lnf = layernorm(x, self.params[li], self.params[li + 1], rows, d)
        else:
            xf, lnf = x, None
        un = len(self.params) - 1
        logits = mm(xf, self.params[un], rows, d, v)
        logits = [F(l * output_scale) for l in logits]
        loss, dlogits = xent(logits, t_gt, v)

        grads = [[F(0.0)] * len(p) for p in self.params]
        dlogits = [F(g * output_scale) for g in dlogits]
        axpy(grads[un], mm_tn(xf, dlogits, rows, d, v))
        dxf = mm_nt(dlogits, self.params[un], rows, v, d)
        if pre:
            li = 2 + c.n_layer * PB
            dx = layernorm_bwd(dxf, self.params[li], lnf, rows, d, grads[li], grads[li + 1])
        else:
            dx = dxf
        for i in reversed(range(c.n_layer)):
            gb = 2 + i * PB
            bl = blocks[i]
            acache = (bl["q"], bl["k"], bl["v"], bl["prob"], bl["merged"], bl["attn_in"])
            if pre:
                dh2 = self.ffn_bwd(i, dx, bl["u"], bl["r"], bl["ffn_in"], grads)
                dln2 = layernorm_bwd(dh2, self.block(i, LN2_G), bl["ln2"], rows, d,
                                     grads[gb + LN2_G], grads[gb + LN2_B])
                dx1 = list(dx)
                axpy(dx1, dln2)
                dh1 = self.attn_bwd(i, dx1, attn_scale, acache, grads)
                dln1 = layernorm_bwd(dh1, self.block(i, LN1_G), bl["ln1"], rows, d,
                                     grads[gb + LN1_G], grads[gb + LN1_B])
                dx = list(dx1)
                axpy(dx, dln1)
            else:
                dy2 = layernorm_bwd(dx, self.block(i, LN2_G), bl["ln2"], rows, d,
                                    grads[gb + LN2_G], grads[gb + LN2_B])
                dx1 = list(dy2)
                axpy(dx1, self.ffn_bwd(i, dy2, bl["u"], bl["r"], bl["ffn_in"], grads))
                dy1 = layernorm_bwd(dx1, self.block(i, LN1_G), bl["ln1"], rows, d,
                                    grads[gb + LN1_G], grads[gb + LN1_B])
                dx = list(dy1)
                axpy(dx, self.attn_bwd(i, dy1, attn_scale, acache, grads))
        for r in range(rows):
            tok = t_in[r]
            p = (r % s) * d
            for j in range(d):
                ds = F(dx[r * d + j] * embed_scale)
                grads[0][tok * d + j] = F(grads[0][tok * d + j] + ds)
                grads[1][p + j] = F(grads[1][p + j] + ds)
        probes = dict(embed_out=x0, attn_logits_l0=alog0, block_out=xf, logits=logits)
        return loss, grads, probes


def axpy(dst, src):
    for i in range(len(dst)):
        dst[i] = F(dst[i] + src[i])


# --- harness -----------------------------------------------------------


def flat(a):
    return [F(x) for x in np.asarray(a, F).reshape(-1)]


def compare(tag, got, want, tol=2e-5):
    got = np.array(got, np.float64)
    want = np.asarray(want, np.float64).reshape(-1)
    denom = np.maximum(1.0, np.maximum(np.abs(got), np.abs(want)))
    rel = np.abs(got - want) / denom
    worst = float(rel.max()) if rel.size else 0.0
    status = "ok" if worst < tol else "FAIL"
    print(f"  {tag:<18} worst rel {worst:.2e}  {status}")
    return worst < tol


def check_kernels():
    """Blocked GEMMs vs numpy on shapes that exercise every edge path:
    non-multiple-of-MR rows, non-multiple-of-NR columns, k spanning
    multiple KC blocks, and degenerate dims."""
    rng = np.random.default_rng(7)
    ok = True
    for (m, k, n) in [
        (1, 1, 1),
        (3, 5, 2),
        (4, 16, 16),
        (5, 17, 33),
        (9, 40, 21),
        (2, 300, 7),  # k crosses the KC=256 block edge
        (13, 260, 18),
        (5, 7, 300),  # n crosses the NC=256 block edge
    ]:
        a = rng.standard_normal((m, k)).astype(F)
        b = rng.standard_normal((k, n)).astype(F)
        got = mm(flat(a), flat(b), m, k, n)
        ok &= compare(f"mm {m}x{k}x{n}", got, (a.astype(np.float64) @ b.astype(np.float64)).astype(F))
        # mm_tn takes a as (k, m) row-major: that's a.T laid out row-major
        got = mm_tn(flat(np.ascontiguousarray(a.T)), flat(b), k, m, n)
        ok &= compare(f"mm_tn {m}x{k}x{n}", got, (a.astype(np.float64) @ b.astype(np.float64)).astype(F))
        bt = np.ascontiguousarray(b.T)  # (n, k) input for mm_nt
        got = mm_nt(flat(a), flat(bt), m, k, n)
        ok &= compare(f"mm_nt {m}x{k}x{n}", got, (a.astype(np.float64) @ b.astype(np.float64)).astype(F))
    # NaN poisoning: 0 · Inf in A/B must reach C (no zero-skip shortcut)
    a = np.zeros((4, 4), F)
    b = np.full((4, 4), np.inf, F)
    for got, tag in [
        (mm(flat(a), flat(b), 4, 4, 4), "mm"),
        (mm_tn(flat(a), flat(b), 4, 4, 4), "mm_tn"),
        (mm_nt(flat(a), flat(b), 4, 4, 4), "mm_nt"),
    ]:
        if not all(math.isnan(x) for x in got):
            print(f"  {tag} zero-times-inf failed to poison: FAIL")
            ok = False
    return ok


def run_tfm(ln, odd=False):
    if odd:
        # deliberately awkward dims: s and dh off every tile boundary
        cfg = R.TfmCfg(vocab=11, seq=9, batch=2, d_model=20, n_layer=1,
                       n_head=2, d_head=5, d_ffn=17, ln=ln)
    else:
        cfg = R.TfmCfg(vocab=13, seq=7, batch=3, d_model=8, n_layer=2,
                       n_head=2, d_head=4, d_ffn=12, ln=ln)
    specs = R.tfm_param_specs(cfg)
    params_np = {name: R.det_fill(shape, 50 + i, 0.08, F) for i, (name, shape, _) in enumerate(specs)}
    tokens_np = R.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 321)
    hp = [0.31, 1.7, 0.9, 0.9, 0.999, 1e-8, 0.0, 1.0]
    loss_ref, grads_ref, probes_ref = R.tfm_fwd_bwd(cfg, params_np, tokens_np, hp)

    sim = TfmSim(cfg, [flat(params_np[name]) for name, _, _ in specs])
    loss_sim, grads_sim, probes_sim = sim.forward_backward(
        [int(t) for t in tokens_np.reshape(-1)], hp
    )
    print(f"transformer {ln}-ln: loss sim {loss_sim:.6f} ref {loss_ref:.6f}")
    ok = abs(loss_sim - loss_ref) < 1e-5 * (1 + abs(loss_ref))
    for key in ["embed_out", "attn_logits_l0", "block_out", "logits"]:
        ok &= compare(f"probe {key}", probes_sim[key], probes_ref[key])
    for i, (name, _, _) in enumerate(specs):
        ok &= compare(f"grad {name}", grads_sim[i], grads_ref[name])
    return ok


def main():
    print("blocked-kernel self-check vs numpy:")
    ok = check_kernels()
    for ln in ["post", "pre"]:
        ok &= run_tfm(ln)
        ok &= run_tfm(ln, odd=True)
    if not ok:
        print("SIMULATION MISMATCH", file=sys.stderr)
        return 1
    print("rust-structure simulation matches the verified reference")
    return 0


if __name__ == "__main__":
    sys.exit(main())
