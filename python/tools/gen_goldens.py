"""Regenerate rust/tests/fixtures/goldens.json.

Replicates ``compile/aot.py::compute_golden`` (same deterministic fills,
same hp vectors, same two-step protocol) through the finite-difference-
verified numpy reference in native_ref.py — so the fixture is an
*independent* cross-language anchor for the Rust native backend.  Run
``tools/check_grads.py`` first if native_ref.py changed.

    python3 tools/gen_goldens.py [--steps N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/tools")
import native_ref as R  # noqa: E402


def init_golden_params(specs, seed, scale):
    """aot.py compute_golden protocol: every tensor det_fill'd (even
    zeros/ones specs) with seed+index."""
    return {
        name: R.det_fill(shape, seed + i, scale, np.float32)
        for i, (name, shape, _) in enumerate(specs)
    }


def golden_tfm(name, cfg, seed, steps, scale=0.02):
    specs = R.tfm_param_specs(cfg)
    params = init_golden_params(specs, seed, scale)
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(p) for k, p in params.items()}
    tokens = R.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, seed + 100)
    # LR chosen so the loss moves by >> the 1e-3 relative test tolerance
    # each step (Adam steps are ~lr in parameter space): a broken backward
    # or optimizer cannot hide inside the tolerance band.
    lr = np.float32(5e-2)
    hp = [0.125, 1.0, 1.0, 0.9, 0.999, 1e-8, 0.0, 1.0]
    losses = []
    for step in range(steps):
        hp[7] = float(step + 1)
        loss, grads, _ = R.tfm_fwd_bwd(cfg, params, tokens, hp)
        losses.append(loss)
        for k in params:
            params[k], m[k], v[k] = R.adam_update(
                params[k], grads[k], m[k], v[k], lr,
                np.float32(hp[3]), np.float32(hp[4]), np.float32(hp[5]),
                np.float32(hp[6]), np.float32(hp[7]),
            )
    return {"name": name, "seed": seed, "lr": float(lr), "scale": scale,
            "hp": hp[:7] + [1.0], "opt": "adam", "losses": losses}


def golden_mlp(name, cfg, seed, steps, scale=0.1):
    specs = R.mlp_param_specs(cfg)
    params = init_golden_params(specs, seed, scale)
    m = {k: np.zeros_like(v) for k, v in params.items()}
    x = R.det_fill((cfg.batch, cfg.d_in), seed + 100, 1.0, np.float32)
    y = R.det_tokens(cfg.batch, 1, cfg.d_out, seed + 200).reshape(cfg.batch)
    # big enough steps that the loss falls by ~2 nats over the recorded
    # trajectory — a broken backward/update cannot hide inside tolerance
    lr = np.float32(2.0)
    hp = [1.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
    losses = []
    for _ in range(steps):
        loss, grads, _ = R.mlp_fwd_bwd(cfg, params, x, y, hp)
        losses.append(loss)
        for k in params:
            params[k], m[k] = R.sgd_update(
                params[k], grads[k], m[k], lr, np.float32(hp[1]), np.float32(hp[2])
            )
    return {"name": name, "seed": seed, "lr": float(lr), "scale": scale,
            "hp": hp, "opt": "sgd", "losses": losses}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    # default must match .github/workflows/ci.yml's fixture check
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument(
        "--check",
        action="store_true",
        help="verify the checked-in fixture reproduces within 1e-4 relative "
        "(BLAS reassociation makes bitwise equality machine-dependent) "
        "instead of rewriting it",
    )
    args = ap.parse_args()

    entries = [
        golden_tfm(
            "tfm_post_w32_d2",
            R.TfmCfg(vocab=64, seq=32, batch=16, d_model=32, n_layer=2,
                     n_head=4, d_head=8, d_ffn=128, ln="post"),
            seed=7, steps=args.steps,
        ),
        golden_mlp(
            "mlp_w64",
            R.MlpCfg(d_in=256, width=64, d_out=10, batch=64),
            seed=11, steps=args.steps,
        ),
    ]
    out = {
        "comment": "recorded by python/tools/gen_goldens.py (numpy reference, "
                   "gradients finite-difference-verified by tools/check_grads.py); "
                   "asserted by rust/tests/golden.rs against the native backend",
        "protocol": "params[i] = det_fill(shape, seed+i, scale); opt state zero; "
                    "tokens/x/y from det_tokens/det_fill with seed+100/+200; "
                    "losses are the pre-update loss of each train step",
        "entries": entries,
    }
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "rust", "tests", "fixtures", "goldens.json",
    )
    for e in entries:
        print(f"{e['name']:<20} losses: " + " ".join(f"{l:.6f}" for l in e["losses"]))
    if args.check:
        with open(path) as fh:
            old = {e["name"]: e for e in json.load(fh)["entries"]}
        worst = 0.0
        for e in entries:
            o = old.get(e["name"])
            assert o is not None, f"fixture missing {e['name']}"
            assert len(o["losses"]) == len(e["losses"]), e["name"]
            for a, b in zip(o["losses"], e["losses"]):
                worst = max(worst, abs(a - b) / (1.0 + abs(b)))
        print(f"fixture check: worst rel deviation {worst:.2e}")
        assert worst < 1e-4, "checked-in fixture drifted from the reference"
        return 0
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(out, fh, indent=1)
        fh.write("\n")
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
