"""Pure-numpy reference for the Rust native backend (no JAX required).

Mirrors ``python/compile/model.py`` + ``python/compile/kernels/ref.py``
semantics exactly — same parameter layouts, same forward math (causal
attention with -1e30 masking, layernorm eps 1e-5, logsumexp cross-entropy),
same fused per-tensor-LR Adam/SGD updates — with hand-derived backward
passes.  ``rust/src/runtime/native/`` is a line-by-line translation of this
file; ``tools/gen_goldens.py`` uses it to record the golden-trajectory
fixture that ``rust/tests/golden.rs`` asserts, and
``tools/check_grads.py`` validates every gradient here against finite
differences (in float64) so the fixture is anchored to an independently
verified implementation.

No imports from ``compile/`` (those need jax); this file is standalone.
"""

from __future__ import annotations

import numpy as np

LN_EPS = 1e-5
NEG_INF = -1e30

_M64 = (1 << 64) - 1


# ---------------------------------------------------------------------------
# deterministic fill (bit-identical to rust/src/init/rng.rs det_fill/tokens)
# ---------------------------------------------------------------------------


def _splitmix64_vec(x):
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        z = x
        z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
        z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
        return z ^ (z >> np.uint64(31))


def det_fill(shape, seed: int, scale: float = 0.02, dtype=np.float32):
    n = int(np.prod(shape)) if shape else 1
    base = np.uint64((seed << 32) & _M64)
    idx = np.arange(n, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = _splitmix64_vec(base + idx)
    u = (z >> np.uint64(11)).astype(np.float64) * (2.0**-53)
    out = (u - 0.5) * 2.0 * scale
    return out.reshape(shape).astype(dtype)


def det_tokens(batch: int, seq: int, vocab: int, seed: int):
    n = batch * seq
    base = np.uint64((seed << 32) & _M64)
    idx = np.arange(n, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = _splitmix64_vec(base + idx)
    return (z % np.uint64(vocab)).astype(np.int32).reshape(batch, seq)


# ---------------------------------------------------------------------------
# shared ops (forward + backward)
# ---------------------------------------------------------------------------


def layernorm_fwd(x, g, b):
    mu = x.mean(axis=-1, keepdims=True)
    xc = x - mu
    var = (xc * xc).mean(axis=-1, keepdims=True)
    rstd = 1.0 / np.sqrt(var + np.asarray(LN_EPS, x.dtype))
    xhat = xc * rstd
    return xhat * g + b, (xhat, rstd)


def layernorm_bwd(dy, g, cache):
    xhat, rstd = cache
    dxhat = dy * g
    dg = (dy * xhat).sum(axis=tuple(range(dy.ndim - 1)))
    db = dy.sum(axis=tuple(range(dy.ndim - 1)))
    m1 = dxhat.mean(axis=-1, keepdims=True)
    m2 = (dxhat * xhat).mean(axis=-1, keepdims=True)
    dx = rstd * (dxhat - m1 - xhat * m2)
    return dx, dg, db


def softmax(x):
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def logsumexp(x):
    m = x.max(axis=-1, keepdims=True)
    return (m + np.log(np.exp(x - m).sum(axis=-1, keepdims=True)))[..., 0]


def xent_fwd(logits, targets):
    """Mean cross-entropy over all leading dims; targets int, same leading
    shape as logits minus the class axis.  Returns (loss, dlogits)."""
    lz = logsumexp(logits)
    gold = np.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    n = float(np.prod(targets.shape))
    loss = float((lz - gold).astype(np.float64).sum() / n)
    d = softmax(logits)
    np.put_along_axis(
        d, targets[..., None],
        np.take_along_axis(d, targets[..., None], axis=-1) - np.asarray(1.0, d.dtype),
        axis=-1,
    )
    return loss, d / np.asarray(n, d.dtype)


# ---------------------------------------------------------------------------
# optimizers (ref.py oracles, elementwise)
# ---------------------------------------------------------------------------


def adam_update(p, g, m, v, lr, beta1, beta2, eps, wd, count):
    one = np.asarray(1.0, p.dtype)
    m2 = beta1 * m + (one - beta1) * g
    v2 = beta2 * v + (one - beta2) * g * g
    mhat = m2 / (one - beta1**count)
    vhat = v2 / (one - beta2**count)
    p2 = p - lr * (mhat / (np.sqrt(vhat) + eps)) - lr * wd * p
    return p2, m2, v2


def sgd_update(p, g, m, lr, momentum, wd):
    m2 = momentum * m + g
    p2 = p - lr * (m2 + wd * p)
    return p2, m2


# ---------------------------------------------------------------------------
# transformer (decoder-only LM, pre/post-LN) — model.py transformer_fwd
# ---------------------------------------------------------------------------


class TfmCfg:
    def __init__(self, vocab=64, seq=32, batch=16, d_model=128, n_layer=2,
                 n_head=4, d_head=32, d_ffn=512, ln="pre"):
        self.vocab, self.seq, self.batch = vocab, seq, batch
        self.d_model, self.n_layer = d_model, n_layer
        self.n_head, self.d_head, self.d_ffn, self.ln = n_head, d_head, d_ffn, ln

    @property
    def d_attn(self):
        return self.n_head * self.d_head


def tfm_param_specs(c: TfmCfg):
    d, da, f, v, s = c.d_model, c.d_attn, c.d_ffn, c.vocab, c.seq
    specs = [("embed", (v, d), "normal"), ("pos_embed", (s, d), "normal")]
    for i in range(c.n_layer):
        p = f"block{i}."
        specs += [
            (p + "ln1_g", (d,), "ones"), (p + "ln1_b", (d,), "zeros"),
            (p + "wq", (d, da), "zeros"), (p + "wk", (d, da), "normal"),
            (p + "wv", (d, da), "normal"), (p + "wo", (da, d), "normal"),
            (p + "ln2_g", (d,), "ones"), (p + "ln2_b", (d,), "zeros"),
            (p + "w1", (d, f), "normal"), (p + "w2", (f, d), "normal"),
        ]
    if c.ln == "pre":
        specs += [("lnf_g", (d,), "ones"), ("lnf_b", (d,), "zeros")]
    specs.append(("unembed", (d, v), "zeros"))
    return specs


def _split_heads(x, h, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, h, dh).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _attn_fwd(c, p, pre, h, attn_scale):
    q = h @ p[pre + "wq"]
    k = h @ p[pre + "wk"]
    v = h @ p[pre + "wv"]
    qh = _split_heads(q, c.n_head, c.d_head)
    kh = _split_heads(k, c.n_head, c.d_head)
    vh = _split_heads(v, c.n_head, c.d_head)
    logits = np.einsum("bhqd,bhkd->bhqk", qh * attn_scale, kh)
    s = h.shape[1]
    causal = np.tril(np.ones((s, s), bool))
    masked = np.where(causal, logits, np.asarray(NEG_INF, logits.dtype))
    prob = softmax(masked)
    ctx = np.einsum("bhqk,bhkd->bhqd", prob, vh)
    merged = _merge_heads(ctx)
    out = merged @ p[pre + "wo"]
    alog = np.where(causal, logits, np.asarray(0.0, logits.dtype))
    cache = (h, qh, kh, vh, prob, merged)
    return out, alog, cache


def _attn_bwd(c, p, pre, dout, attn_scale, cache, grads):
    h, qh, kh, vh, prob, merged = cache
    grads[pre + "wo"] += np.einsum("bsd,bse->de", merged, dout)
    dmerged = dout @ p[pre + "wo"].T
    dctx = _split_heads(dmerged, c.n_head, c.d_head)
    dprob = np.einsum("bhqd,bhkd->bhqk", dctx, vh)
    dvh = np.einsum("bhqk,bhqd->bhkd", prob, dctx)
    dmasked = prob * (dprob - (dprob * prob).sum(axis=-1, keepdims=True))
    # masked entries have prob == 0 so dmasked is already 0 there
    dqh = np.einsum("bhqk,bhkd->bhqd", dmasked, kh) * attn_scale
    dkh = np.einsum("bhqk,bhqd->bhkd", dmasked, qh * attn_scale)
    dq = _merge_heads(dqh)
    dk = _merge_heads(dkh)
    dv = _merge_heads(dvh)
    grads[pre + "wq"] += np.einsum("bsd,bse->de", h, dq)
    grads[pre + "wk"] += np.einsum("bsd,bse->de", h, dk)
    grads[pre + "wv"] += np.einsum("bsd,bse->de", h, dv)
    return dq @ p[pre + "wq"].T + dk @ p[pre + "wk"].T + dv @ p[pre + "wv"].T


def _ffn_fwd(p, pre, h):
    u = h @ p[pre + "w1"]
    r = np.maximum(u, np.asarray(0.0, u.dtype))
    return r @ p[pre + "w2"], (h, u, r)


def _ffn_bwd(p, pre, df, cache, grads):
    h, u, r = cache
    grads[pre + "w2"] += np.einsum("bsf,bsd->fd", r, df)
    dr = df @ p[pre + "w2"].T
    du = dr * (u > 0)
    grads[pre + "w1"] += np.einsum("bsd,bsf->df", h, du)
    return du @ p[pre + "w1"].T


def tfm_fwd_bwd(c: TfmCfg, params: dict, tokens, hp, want_grads=True):
    """tokens (B, S+1) int32.  hp: [attn, out, emb, b1, b2, eps, wd, step].
    Returns (loss, grads|None, probes)."""
    attn_scale = np.asarray(hp[0], params["embed"].dtype)
    output_scale = np.asarray(hp[1], params["embed"].dtype)
    embed_scale = np.asarray(hp[2], params["embed"].dtype)
    tin = tokens[:, : c.seq]
    tgt = tokens[:, 1 : c.seq + 1]
    pre_ln = c.ln == "pre"

    emb = params["embed"][tin]  # (B,S,D)
    x = (emb + params["pos_embed"][None, : c.seq]) * embed_scale
    probes = {"embed_out": x}

    caches = []
    alog0 = None
    for i in range(c.n_layer):
        p = f"block{i}."
        if pre_ln:
            h1, ln1c = layernorm_fwd(x, params[p + "ln1_g"], params[p + "ln1_b"])
            a, alog, ac = _attn_fwd(c, params, p, h1, attn_scale)
            x1 = x + a
            h2, ln2c = layernorm_fwd(x1, params[p + "ln2_g"], params[p + "ln2_b"])
            f, fc = _ffn_fwd(params, p, h2)
            x2 = x1 + f
            caches.append((ln1c, ac, x1, ln2c, fc))
        else:
            a, alog, ac = _attn_fwd(c, params, p, x, attn_scale)
            y1 = x + a
            x1, ln1c = layernorm_fwd(y1, params[p + "ln1_g"], params[p + "ln1_b"])
            f, fc = _ffn_fwd(params, p, x1)
            y2 = x1 + f
            x2, ln2c = layernorm_fwd(y2, params[p + "ln2_g"], params[p + "ln2_b"])
            caches.append((ac, ln1c, x1, fc, ln2c))
        if i == 0:
            alog0 = alog
        x = x2

    if pre_ln:
        xf, lnfc = layernorm_fwd(x, params["lnf_g"], params["lnf_b"])
    else:
        xf = x
    probes["attn_logits_l0"] = alog0
    probes["block_out"] = xf
    logits = (xf @ params["unembed"]) * output_scale
    probes["logits"] = logits

    loss, dlogits = xent_fwd(logits, tgt)
    if not want_grads:
        return loss, None, probes

    grads = {k: np.zeros_like(v) for k, v in params.items()}
    dlogits = dlogits * output_scale
    grads["unembed"] += np.einsum("bsd,bsv->dv", xf, dlogits)
    dxf = dlogits @ params["unembed"].T
    if pre_ln:
        dx, dg, db = layernorm_bwd(dxf, params["lnf_g"], lnfc)
        grads["lnf_g"] += dg
        grads["lnf_b"] += db
    else:
        dx = dxf

    for i in reversed(range(c.n_layer)):
        p = f"block{i}."
        if pre_ln:
            ln1c, ac, x1, ln2c, fc = caches[i]
            dx1 = dx.copy()
            dh2 = _ffn_bwd(params, p, dx, fc, grads)
            d, dg, db = layernorm_bwd(dh2, params[p + "ln2_g"], ln2c)
            grads[p + "ln2_g"] += dg
            grads[p + "ln2_b"] += db
            dx1 += d
            dx = dx1.copy()
            dh1 = _attn_bwd(c, params, p, dx1, np.asarray(hp[0], dx.dtype), ac, grads)
            d, dg, db = layernorm_bwd(dh1, params[p + "ln1_g"], ln1c)
            grads[p + "ln1_g"] += dg
            grads[p + "ln1_b"] += db
            dx += d
        else:
            ac, ln1c, x1, fc, ln2c = caches[i]
            dy2, dg, db = layernorm_bwd(dx, params[p + "ln2_g"], ln2c)
            grads[p + "ln2_g"] += dg
            grads[p + "ln2_b"] += db
            dx1 = dy2 + _ffn_bwd(params, p, dy2, fc, grads)
            dy1, dg, db = layernorm_bwd(dx1, params[p + "ln1_g"], ln1c)
            grads[p + "ln1_g"] += dg
            grads[p + "ln1_b"] += db
            dx = dy1 + _attn_bwd(c, params, p, dy1, np.asarray(hp[0], dx.dtype), ac, grads)

    dsum = dx * np.asarray(hp[2], dx.dtype)  # d(emb + pos)
    grads["pos_embed"][: c.seq] += dsum.sum(axis=0)
    np.add.at(grads["embed"], tin, dsum)
    return loss, grads, probes


# ---------------------------------------------------------------------------
# MLP + ResMLP (SGD family) — model.py mlp_fwd / resmlp_fwd
# ---------------------------------------------------------------------------


class MlpCfg:
    def __init__(self, d_in=256, width=128, d_out=10, batch=64, act="relu", loss="xent"):
        self.d_in, self.width, self.d_out, self.batch = d_in, width, d_out, batch
        self.act, self.loss = act, loss


def mlp_param_specs(c: MlpCfg):
    n = c.width
    return [
        ("w1", (c.d_in, n), "normal"), ("b1", (n,), "zeros"),
        ("w2", (n, n), "normal"), ("b2", (n,), "zeros"),
        ("w3", (n, c.d_out), "zeros"),
    ]


def mlp_fwd_bwd(c: MlpCfg, params, x, y, hp, want_grads=True):
    """x (B, d_in) f32, y (B,) int32.  hp[0] = output scale."""
    scale = np.asarray(hp[0], x.dtype)
    tanh = c.act == "tanh"
    u1 = x @ params["w1"] + params["b1"]
    h1 = np.tanh(u1) if tanh else np.maximum(u1, np.asarray(0.0, u1.dtype))
    u2 = h1 @ params["w2"] + params["b2"]
    h2 = np.tanh(u2) if tanh else np.maximum(u2, np.asarray(0.0, u2.dtype))
    logits = (h2 @ params["w3"]) * scale
    if c.loss == "xent":
        loss, dlogits = xent_fwd(logits, y)
    else:  # mse vs one-hot, mean over B*d_out elements
        onehot = np.zeros_like(logits)
        np.put_along_axis(onehot, y[:, None], np.asarray(1.0, logits.dtype), axis=-1)
        diff = logits - onehot
        n = float(diff.size)
        loss = float((diff.astype(np.float64) ** 2).sum() / n)
        dlogits = diff * np.asarray(2.0 / n, diff.dtype)
    if not want_grads:
        return loss, None, {"logits": logits}
    grads = {}
    dlogits = dlogits * scale
    grads["w3"] = h2.T @ dlogits
    dh2 = dlogits @ params["w3"].T
    du2 = dh2 * (1.0 - h2 * h2) if tanh else dh2 * (u2 > 0)
    grads["w2"] = h1.T @ du2
    grads["b2"] = du2.sum(axis=0)
    dh1 = du2 @ params["w2"].T
    du1 = dh1 * (1.0 - h1 * h1) if tanh else dh1 * (u1 > 0)
    grads["w1"] = x.T @ du1
    grads["b1"] = du1.sum(axis=0)
    return loss, grads, {"logits": logits}


class ResMlpCfg:
    def __init__(self, d_in=256, width=128, n_block=4, d_out=10, batch=64):
        self.d_in, self.width, self.n_block, self.d_out, self.batch = (
            d_in, width, n_block, d_out, batch,
        )


def resmlp_param_specs(c: ResMlpCfg):
    n = c.width
    specs = [("w_in", (c.d_in, n), "normal")]
    for i in range(c.n_block):
        p = f"block{i}."
        specs += [
            (p + "ln_g", (n,), "ones"), (p + "ln_b", (n,), "zeros"),
            (p + "w1", (n, n), "normal"), (p + "w2", (n, n), "normal"),
        ]
    specs += [("ln_f_g", (n,), "ones"), ("ln_f_b", (n,), "zeros"),
              ("w_out", (n, c.d_out), "zeros")]
    return specs


def resmlp_fwd_bwd(c: ResMlpCfg, params, x, y, hp, want_grads=True):
    scale = np.asarray(hp[0], x.dtype)
    h = x @ params["w_in"]
    caches = []
    for i in range(c.n_block):
        p = f"block{i}."
        z, lnc = layernorm_fwd(h, params[p + "ln_g"], params[p + "ln_b"])
        u = z @ params[p + "w1"]
        r = np.maximum(u, np.asarray(0.0, u.dtype))
        h = h + r @ params[p + "w2"]
        caches.append((z, lnc, u, r))
    hf, lnfc = layernorm_fwd(h, params["ln_f_g"], params["ln_f_b"])
    logits = (hf @ params["w_out"]) * scale
    loss, dlogits = xent_fwd(logits, y)
    if not want_grads:
        return loss, None, {"logits": logits}
    grads = {k: np.zeros_like(v) for k, v in params.items()}
    dlogits = dlogits * scale
    grads["w_out"] += hf.T @ dlogits
    dhf = dlogits @ params["w_out"].T
    dh, dg, db = layernorm_bwd(dhf, params["ln_f_g"], lnfc)
    grads["ln_f_g"] += dg
    grads["ln_f_b"] += db
    for i in reversed(range(c.n_block)):
        p = f"block{i}."
        z, lnc, u, r = caches[i]
        grads[p + "w2"] += r.T @ dh
        dr = dh @ params[p + "w2"].T
        du = dr * (u > 0)
        grads[p + "w1"] += z.T @ du
        dz = du @ params[p + "w1"].T
        d, dg, db = layernorm_bwd(dz, params[p + "ln_g"], lnc)
        grads[p + "ln_g"] += dg
        grads[p + "ln_b"] += db
        dh = dh + d
    grads["w_in"] += x.T @ dh
    return loss, grads, {"logits": logits}
