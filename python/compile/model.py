"""L2: the paper's model family as JAX graphs calling the L1 Pallas kernels.

Three architectures, matching the paper's experimental surface:

- ``transformer`` — decoder-only LM, pre- or post-layernorm (Sections 3-8);
  Adam with fused per-tensor-LR updates.
- ``mlp`` — the 2-hidden-layer MLP of Section 3/Fig. 3 (SGD, relu/tanh,
  xent/mse) on the synthetic vision task.
- ``resmlp`` — deep residual MLP standing in for the ResNet experiments
  (Appendix G.1; substitution documented in DESIGN.md §2), SGD+momentum.

Every hyperparameter the paper transfers is a *runtime input* to the
lowered graph — per-tensor effective learning rates (``lr_vec``), the
attention logit scale, output/embedding multipliers, Adam betas/eps, weight
decay and the step counter ride in ``hp_vec`` — so a single HLO artifact per
shape serves the entire HP search space and both SP and μP.  The Rust
coordinator (L3) owns the μP rules that decide what values to feed.

Input/output calling convention (mirrored in artifacts/manifest.json and
rust/src/runtime/manifest.rs):

  train:  (data..., params[P], opt_state[S*P], lr_vec[P], hp_vec[8])
          -> (loss, params'[P], opt_state'[S*P])
  eval:   (data..., params[P], hp_vec[8]) -> (loss,)
  coord:  train inputs -> train outputs + probe tensors (Fig. 5)

where S = 2 moment buffers for Adam, 1 momentum buffer for SGD.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import adam_update, attention, layernorm, linear, sgd_update

HP_LEN = 8

# hp_vec slots (transformer / adam)
HP_ATTN_SCALE = 0
HP_OUTPUT_SCALE = 1
HP_EMBED_SCALE = 2
HP_BETA1 = 3
HP_BETA2 = 4
HP_EPS = 5
HP_WD = 6
HP_STEP = 7

# hp_vec slots (mlp, resmlp / sgd)
HP_SGD_OUTPUT_SCALE = 0
HP_SGD_MOMENTUM = 1
HP_SGD_WD = 2


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter tensor: canonical name, shape, and μP role.

    ``role`` is one of:
      - ``input``  — maps a finite dim to an infinite one (embeddings, first
        layer); Table 8 column 1
      - ``hidden`` — infinite -> infinite; Table 8 column 3
      - ``output`` — infinite -> finite (readout); Table 8 column 2
      - ``vector`` — biases / layernorm gains: fan_in is 1, treated like
        input weights (Table 8 caption)
    ``fan_in``/``fan_out`` follow Table 3's convention (bias fan_in = 1,
    fan_out = its dimension).
    """

    name: str
    shape: tuple
    role: str
    fan_in: int
    fan_out: int
    init: str = "normal"  # "normal" | "zeros" | "ones"


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 64
    seq: int = 32
    batch: int = 16
    d_model: int = 128
    n_layer: int = 2
    n_head: int = 4
    d_head: int = 32  # decoupled from d_model (App. D.4 / E.2)
    d_ffn: int = 512
    ln: str = "pre"  # "pre" | "post"

    @property
    def d_attn(self) -> int:
        return self.n_head * self.d_head


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_in: int = 256
    width: int = 128
    d_out: int = 10
    batch: int = 64
    act: str = "relu"  # "relu" | "tanh"
    loss: str = "xent"  # "xent" | "mse"


@dataclasses.dataclass(frozen=True)
class ResMlpConfig:
    d_in: int = 256
    width: int = 128
    n_block: int = 4
    d_out: int = 10
    batch: int = 64


# ---------------------------------------------------------------------------
# parameter layouts (the canonical ordering every layer of the stack shares)
# ---------------------------------------------------------------------------


def transformer_param_specs(cfg: TransformerConfig) -> list:
    d, da, f, v, s = cfg.d_model, cfg.d_attn, cfg.d_ffn, cfg.vocab, cfg.seq
    specs = [
        ParamSpec("embed", (v, d), "input", v, d),
        ParamSpec("pos_embed", (s, d), "input", s, d),
    ]
    for i in range(cfg.n_layer):
        p = f"block{i}."
        specs += [
            ParamSpec(p + "ln1_g", (d,), "vector", 1, d, init="ones"),
            ParamSpec(p + "ln1_b", (d,), "vector", 1, d, init="zeros"),
            # wq is zero-initialized per App. D.2 (attention logits are then
            # exactly 0 at init at every width, removing the initial-GP
            # mismatch between proxy and target).
            ParamSpec(p + "wq", (d, da), "hidden", d, da, init="zeros"),
            ParamSpec(p + "wk", (d, da), "hidden", d, da),
            ParamSpec(p + "wv", (d, da), "hidden", d, da),
            ParamSpec(p + "wo", (da, d), "hidden", da, d),
            ParamSpec(p + "ln2_g", (d,), "vector", 1, d, init="ones"),
            ParamSpec(p + "ln2_b", (d,), "vector", 1, d, init="zeros"),
            ParamSpec(p + "w1", (d, f), "hidden", d, f),
            ParamSpec(p + "w2", (f, d), "hidden", f, d),
        ]
    if cfg.ln == "pre":
        specs += [
            ParamSpec("lnf_g", (d,), "vector", 1, d, init="ones"),
            ParamSpec("lnf_b", (d,), "vector", 1, d, init="zeros"),
        ]
    # Output layer zero-init per App. D.2 (also enables the §8
    # wider-is-better check from step 0).
    specs.append(ParamSpec("unembed", (d, v), "output", d, v, init="zeros"))
    return specs


def mlp_param_specs(cfg: MlpConfig) -> list:
    n = cfg.width
    return [
        ParamSpec("w1", (cfg.d_in, n), "input", cfg.d_in, n),
        ParamSpec("b1", (n,), "vector", 1, n, init="zeros"),
        ParamSpec("w2", (n, n), "hidden", n, n),
        ParamSpec("b2", (n,), "vector", 1, n, init="zeros"),
        ParamSpec("w3", (n, cfg.d_out), "output", n, cfg.d_out, init="zeros"),
    ]


def resmlp_param_specs(cfg: ResMlpConfig) -> list:
    n = cfg.width
    specs = [ParamSpec("w_in", (cfg.d_in, n), "input", cfg.d_in, n)]
    for i in range(cfg.n_block):
        p = f"block{i}."
        specs += [
            ParamSpec(p + "ln_g", (n,), "vector", 1, n, init="ones"),
            ParamSpec(p + "ln_b", (n,), "vector", 1, n, init="zeros"),
            ParamSpec(p + "w1", (n, n), "hidden", n, n),
            ParamSpec(p + "w2", (n, n), "hidden", n, n),
        ]
    specs += [
        ParamSpec("ln_f_g", (n,), "vector", 1, n, init="ones"),
        ParamSpec("ln_f_b", (n,), "vector", 1, n, init="zeros"),
        ParamSpec("w_out", (n, cfg.d_out), "output", n, cfg.d_out, init="zeros"),
    ]
    return specs


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _split_heads(x, n_head, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n_head, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def transformer_fwd(cfg: TransformerConfig, params: dict, tokens, hp_vec):
    """Token logits + coordinate-check probes.

    ``tokens``: int32 (B, S).  Probes mirror Fig. 5's three measured
    quantities (word embedding, attention logits, output logits) plus the
    final block output.
    """
    attn_scale = hp_vec[HP_ATTN_SCALE]
    output_scale = hp_vec[HP_OUTPUT_SCALE]
    embed_scale = hp_vec[HP_EMBED_SCALE]

    emb = jnp.take(params["embed"], tokens, axis=0)  # (B, S, D)
    pos = params["pos_embed"][None, : tokens.shape[1]]
    x = (emb + pos) * embed_scale
    probes = {"embed_out": x}

    for i in range(cfg.n_layer):
        p = f"block{i}."

        def attn_sublayer(h):
            q = linear(h, params[p + "wq"])
            k = linear(h, params[p + "wk"])
            v = linear(h, params[p + "wv"])
            ctx, attn_logits = attention(
                _split_heads(q, cfg.n_head, cfg.d_head),
                _split_heads(k, cfg.n_head, cfg.d_head),
                _split_heads(v, cfg.n_head, cfg.d_head),
                attn_scale,
            )
            return linear(_merge_heads(ctx), params[p + "wo"]), attn_logits

        def ffn_sublayer(h):
            return linear(jax.nn.relu(linear(h, params[p + "w1"])), params[p + "w2"])

        if cfg.ln == "pre":
            a, attn_logits = attn_sublayer(
                layernorm(x, params[p + "ln1_g"], params[p + "ln1_b"])
            )
            x = x + a
            x = x + ffn_sublayer(layernorm(x, params[p + "ln2_g"], params[p + "ln2_b"]))
        else:  # post-LN (original Transformer; Fig. 1 uses this)
            a, attn_logits = attn_sublayer(x)
            x = layernorm(x + a, params[p + "ln1_g"], params[p + "ln1_b"])
            x = layernorm(
                x + ffn_sublayer(x), params[p + "ln2_g"], params[p + "ln2_b"]
            )
        if i == 0:
            probes["attn_logits_l0"] = attn_logits

    if cfg.ln == "pre":
        x = layernorm(x, params["lnf_g"], params["lnf_b"])
    probes["block_out"] = x
    logits = linear(x, params["unembed"]) * output_scale
    probes["logits"] = logits
    return logits, probes


def lm_loss(logits, targets):
    """Mean next-token cross-entropy; targets int32 (B, S)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def mlp_fwd(cfg: MlpConfig, params: dict, x, hp_vec):
    act = jax.nn.relu if cfg.act == "relu" else jnp.tanh
    h = act(linear(x, params["w1"]) + params["b1"])
    h = act(linear(h, params["w2"]) + params["b2"])
    logits = linear(h, params["w3"]) * hp_vec[HP_SGD_OUTPUT_SCALE]
    return logits, {"hidden": h, "logits": logits}


def mlp_loss(cfg: MlpConfig, logits, y):
    if cfg.loss == "xent":
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)
    onehot = jax.nn.one_hot(y, cfg.d_out, dtype=jnp.float32)
    return jnp.mean((logits - onehot) ** 2)


def resmlp_fwd(cfg: ResMlpConfig, params: dict, x, hp_vec):
    h = linear(x, params["w_in"])
    for i in range(cfg.n_block):
        p = f"block{i}."
        z = layernorm(h, params[p + "ln_g"], params[p + "ln_b"])
        h = h + linear(jax.nn.relu(linear(z, params[p + "w1"])), params[p + "w2"])
    h = layernorm(h, params["ln_f_g"], params["ln_f_b"])
    logits = linear(h, params["w_out"]) * hp_vec[HP_SGD_OUTPUT_SCALE]
    return logits, {"hidden": h, "logits": logits}


# ---------------------------------------------------------------------------
# train / eval / coord-check step builders (flat-argument calling convention)
# ---------------------------------------------------------------------------


def _pack(specs, flat):
    return {spec.name: t for spec, t in zip(specs, flat)}


def make_transformer_steps(cfg: TransformerConfig):
    """Returns (train_step, eval_step, coord_step) with flat signatures."""
    specs = transformer_param_specs(cfg)
    n = len(specs)

    def fwd_loss(plist, tokens_in, targets, hp_vec):
        logits, probes = transformer_fwd(cfg, _pack(specs, plist), tokens_in, hp_vec)
        return lm_loss(logits, targets), probes

    def _train(tokens, *rest, with_probes: bool):
        plist = list(rest[:n])
        ms = list(rest[n : 2 * n])
        vs = list(rest[2 * n : 3 * n])
        lr_vec = rest[3 * n]
        hp_vec = rest[3 * n + 1]
        tokens_in = tokens[:, : cfg.seq]
        targets = tokens[:, 1 : cfg.seq + 1]
        (loss, probes), grads = jax.value_and_grad(
            lambda pl_: fwd_loss(pl_, tokens_in, targets, hp_vec), has_aux=True
        )(plist)
        new_p, new_m, new_v = [], [], []
        for i in range(n):
            p2, m2, v2 = adam_update(
                plist[i],
                grads[i],
                ms[i],
                vs[i],
                lr_vec[i],
                hp_vec[HP_BETA1],
                hp_vec[HP_BETA2],
                hp_vec[HP_EPS],
                hp_vec[HP_WD],
                hp_vec[HP_STEP],
            )
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        outs = [loss] + new_p + new_m + new_v
        if with_probes:
            outs += [
                probes["embed_out"],
                probes["attn_logits_l0"],
                probes["block_out"],
                probes["logits"],
            ]
        return tuple(outs)

    def train_step(tokens, *rest):
        return _train(tokens, *rest, with_probes=False)

    def coord_step(tokens, *rest):
        return _train(tokens, *rest, with_probes=True)

    def eval_step(tokens, *rest):
        plist = list(rest[:n])
        hp_vec = rest[n]
        loss, _ = fwd_loss(plist, tokens[:, : cfg.seq], tokens[:, 1 : cfg.seq + 1], hp_vec)
        return (loss,)

    return train_step, eval_step, coord_step


def _make_sgd_steps(specs, fwd, loss_fn):
    n = len(specs)

    def fwd_loss(plist, x, y, hp_vec):
        logits, probes = fwd(_pack(specs, plist), x, hp_vec)
        return loss_fn(logits, y), probes

    def train_step(x, y, *rest):
        plist = list(rest[:n])
        ms = list(rest[n : 2 * n])
        lr_vec = rest[2 * n]
        hp_vec = rest[2 * n + 1]
        (loss, _), grads = jax.value_and_grad(
            lambda pl_: fwd_loss(pl_, x, y, hp_vec), has_aux=True
        )(plist)
        new_p, new_m = [], []
        for i in range(n):
            p2, m2 = sgd_update(
                plist[i],
                grads[i],
                ms[i],
                lr_vec[i],
                hp_vec[HP_SGD_MOMENTUM],
                hp_vec[HP_SGD_WD],
            )
            new_p.append(p2)
            new_m.append(m2)
        return tuple([loss] + new_p + new_m)

    def eval_step(x, y, *rest):
        plist = list(rest[:n])
        hp_vec = rest[n]
        loss, _ = fwd_loss(plist, x, y, hp_vec)
        return (loss,)

    return train_step, eval_step


def make_mlp_steps(cfg: MlpConfig):
    specs = mlp_param_specs(cfg)
    return _make_sgd_steps(
        specs,
        lambda p, x, hp: mlp_fwd(cfg, p, x, hp),
        lambda logits, y: mlp_loss(cfg, logits, y),
    )


def make_resmlp_steps(cfg: ResMlpConfig):
    specs = resmlp_param_specs(cfg)

    def loss_fn(logits, y):
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    return _make_sgd_steps(
        specs,
        lambda p, x, hp: resmlp_fwd(cfg, p, x, hp),
        loss_fn,
    )


# ---------------------------------------------------------------------------
# deterministic fill — shared golden-value scheme with the Rust side
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1


def splitmix64(x: int) -> int:
    """The exact splitmix64 used by rust/src/init/rng.rs; goldens depend on
    bit-for-bit agreement between the two implementations."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return (z ^ (z >> 31)) & _M64


def _splitmix64_vec(x):
    """Vectorized splitmix64 over a numpy uint64 array (same bits as the
    scalar version above)."""
    import numpy as np

    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        z = x
        z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
        z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
        return z ^ (z >> np.uint64(31))


def det_fill(shape, seed: int, scale: float = 0.02):
    """Deterministic pseudo-random tensor both sides can reproduce exactly:
    elem[i] = (splitmix64(seed*2^32 + i) -> uniform [0,1) - 0.5) * 2 * scale."""
    import numpy as np

    n = 1
    for d in shape:
        n *= int(d)
    base = np.uint64((seed << 32) & _M64)
    idx = np.arange(n, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = _splitmix64_vec(base + idx)
    u = (z >> np.uint64(11)).astype(np.float64) * (2.0**-53)
    out = (u - 0.5) * 2.0 * scale
    return jnp.asarray(out.reshape(shape), dtype=jnp.float32)


def det_tokens(batch: int, seq: int, vocab: int, seed: int):
    import numpy as np

    n = batch * seq
    base = np.uint64((seed << 32) & _M64)
    idx = np.arange(n, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = _splitmix64_vec(base + idx)
    out = (z % np.uint64(vocab)).astype(np.int64)
    return jnp.asarray(out.reshape(batch, seq), dtype=jnp.int32)
