"""AOT compiler: lower every model variant to HLO text + manifest.json.

This is the *only* place Python touches the lifecycle: ``make artifacts``
runs it once, producing ``artifacts/<variant>.hlo.txt`` files plus a
``manifest.json`` describing each variant's calling convention (parameter
layout, μP roles, fan-in/out, data inputs, probe outputs and golden
values).  The Rust coordinator loads the manifest and never imports Python.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from ``python/``):
    python -m compile.aot --out-dir ../artifacts [--only REGEX] [--list]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp

from . import model as M

# ---------------------------------------------------------------------------
# variant registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    arch: str  # transformer | mlp | resmlp
    kind: str  # train | eval | coord
    cfg: object
    golden_seed: int = 0  # >0: embed golden loss values in the manifest


def _tfm(name, golden=0, **kw):
    cfg = M.TransformerConfig(**kw)
    out = [
        Variant(name, "transformer", "train", cfg, golden_seed=golden),
        Variant(name + "__eval", "transformer", "eval", cfg),
    ]
    return out


def _tfm_coord(name, **kw):
    return [Variant(name + "__coord", "transformer", "coord", M.TransformerConfig(**kw))]


def _mlp(name, golden=0, **kw):
    cfg = M.MlpConfig(**kw)
    return [
        Variant(name, "mlp", "train", cfg, golden_seed=golden),
        Variant(name + "__eval", "mlp", "eval", cfg),
    ]


def _resmlp(name, **kw):
    cfg = M.ResMlpConfig(**kw)
    return [
        Variant(name, "resmlp", "train", cfg),
        Variant(name + "__eval", "resmlp", "eval", cfg),
    ]


def build_registry() -> list:
    """The full artifact set, keyed to DESIGN.md §4's experiment index.

    Width sweeps keep n_head fixed and scale d_head (the paper's default
    width definition), except the `nh` family which fixes d_head and scales
    n_head (Fig. 13).  d_ffn = 4·d_model unless overridden (Fig. 12).
    """
    v: list = []

    def tfm_dims(w):
        return dict(d_model=w, n_head=4, d_head=w // 4, d_ffn=4 * w)

    # Post-LN width family (Fig. 1 / Fig. 5 / Fig. 7 / Tab. 4 style)
    for w in [32, 64, 128, 256, 512]:
        v += _tfm(f"tfm_post_w{w}_d2", ln="post", n_layer=2, golden=(7 if w == 32 else 0), **tfm_dims(w))
        v += _tfm_coord(f"tfm_post_w{w}_d2", ln="post", n_layer=2, **tfm_dims(w))
    # Pre-LN width family (Fig. 4 / Fig. 6 / Fig. 19 / Tab. 7 proxy)
    for w in [32, 64, 128, 256, 512]:
        v += _tfm(f"tfm_pre_w{w}_d2", ln="pre", n_layer=2, **tfm_dims(w))
    v += _tfm_coord("tfm_pre_w128_d2", ln="pre", n_layer=2, **tfm_dims(128))
    # Depth coord family at w32 (coord-check invariants for the depth axis)
    for d in [2, 4, 8]:
        v += _tfm_coord(f"tfm_pre_w32_d{d}", ln="pre", n_layer=d, **tfm_dims(32))
    # Depth family at w128 (Fig. 4 depth transfer; pre-LN only — §6.1)
    for d in [4, 8]:
        v += _tfm(f"tfm_pre_w128_d{d}", ln="pre", n_layer=d, **tfm_dims(128))
    # Sequence-length / batch-size transfer (Fig. 19)
    for s in [16, 64]:
        v += _tfm(f"tfm_pre_w128_d2_s{s}", ln="pre", n_layer=2, seq=s, **tfm_dims(128))
    for b in [8, 32]:
        v += _tfm(f"tfm_pre_w128_d2_b{b}", ln="pre", n_layer=2, batch=b, **tfm_dims(128))
    # d_head ablation (Fig. 10): tiny d_head at fixed width
    v += _tfm("tfm_pre_w128_d2_hd4", ln="pre", n_layer=2, d_model=128, n_head=4, d_head=4, d_ffn=512)
    # n_head-as-width family (Fig. 13): fix d_head=16, scale n_head
    for nh in [2, 4, 8, 16]:
        v += _tfm(
            f"tfm_pre_nh{nh}_hd16",
            ln="pre",
            n_layer=2,
            d_model=16 * nh,
            n_head=nh,
            d_head=16,
            d_ffn=64 * nh,
        )
    # d_ffn-ratio family (Fig. 12): vary width ratio at fixed d_model
    for f in [128, 256, 1024, 2048]:
        v += _tfm(f"tfm_pre_w128_d2_f{f}", ln="pre", n_layer=2, d_model=128, n_head=4, d_head=32, d_ffn=f)
    # Tab. 6 (BERT-style) targets: scale width AND depth from the w64_d2 proxy
    v += _tfm("tfm_pre_w256_d4", ln="pre", n_layer=4, **tfm_dims(256))
    v += _tfm("tfm_pre_w512_d6", ln="pre", n_layer=6, **tfm_dims(512))
    # Tab. 7 (GPT-3-style) target + the end-to-end example model
    v += _tfm("tfm_pre_w512_d4", ln="pre", n_layer=4, **tfm_dims(512))

    # MLP family (Fig. 3 / Fig. 9)
    for w in [64, 128, 256, 512, 1024, 2048]:
        v += _mlp(f"mlp_w{w}", width=w, golden=(11 if w == 64 else 0))
    for w in [64, 256, 1024]:
        v += _mlp(f"mlp_tanh_w{w}", width=w, act="tanh")
        v += _mlp(f"mlp_tanhmse_w{w}", width=w, act="tanh", loss="mse")

    # ResMLP family (Tab. 12 ResNet substitute)
    for w in [32, 64, 128, 256]:
        v += _resmlp(f"resmlp_w{w}", width=w)
    # ResMLP depth pair at w32 (depth-transfer acceptance runs)
    for nb in [2, 8]:
        v += _resmlp(f"resmlp_w32_nb{nb}", width=32, n_block=nb)

    names = [x.name for x in v]
    assert len(names) == len(set(names)), "duplicate variant names"
    return v


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def variant_io(var: Variant):
    """(step_fn, input_specs, param_specs, data_inputs, n_state, probes)."""
    cfg = var.cfg
    if var.arch == "transformer":
        pspecs = M.transformer_param_specs(cfg)
        train, evl, coord = M.make_transformer_steps(cfg)
        data = [("tokens", "i32", (cfg.batch, cfg.seq + 1))]
        dspecs = [_spec((cfg.batch, cfg.seq + 1), jnp.int32)]
        n_state = 2
        fn = {"train": train, "eval": evl, "coord": coord}[var.kind]
        probes = (
            ["embed_out", "attn_logits_l0", "block_out", "logits"]
            if var.kind == "coord"
            else []
        )
    else:
        if var.arch == "mlp":
            pspecs = M.mlp_param_specs(cfg)
            train, evl = M.make_mlp_steps(cfg)
        else:
            pspecs = M.resmlp_param_specs(cfg)
            train, evl = M.make_resmlp_steps(cfg)
        data = [
            ("x", "f32", (cfg.batch, cfg.d_in)),
            ("y", "i32", (cfg.batch,)),
        ]
        dspecs = [
            _spec((cfg.batch, cfg.d_in)),
            _spec((cfg.batch,), jnp.int32),
        ]
        n_state = 1
        fn = {"train": train, "eval": evl}[var.kind]
        probes = []

    p = len(pspecs)
    arg_specs = list(dspecs) + [_spec(s.shape) for s in pspecs]
    if var.kind in ("train", "coord"):
        for _ in range(n_state):
            arg_specs += [_spec(s.shape) for s in pspecs]
        arg_specs += [_spec((p,)), _spec((M.HP_LEN,))]
    else:
        arg_specs += [_spec((M.HP_LEN,))]
    return fn, arg_specs, pspecs, data, n_state, probes


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def compute_golden(var: Variant, pspecs, n_state):
    """Run two train steps with deterministically-filled inputs and record
    the losses; the Rust integration tests replicate this exactly through
    the PJRT path (rust/tests/golden.rs)."""
    cfg = var.cfg
    seed = var.golden_seed
    params = [M.det_fill(s.shape, seed + i, 0.02) for i, s in enumerate(pspecs)]
    states = [jnp.zeros(s.shape, jnp.float32) for _ in range(n_state) for s in pspecs]
    p = len(pspecs)
    lr_vec = jnp.full((p,), 1e-2 if n_state == 1 else 1e-3, jnp.float32)
    if var.arch == "transformer":
        hp = jnp.array([0.125, 1.0, 1.0, 0.9, 0.999, 1e-8, 0.0, 1.0], jnp.float32)
        data = [M.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, seed + 100)]
        fn = M.make_transformer_steps(cfg)[0]
    else:
        hp = jnp.array([1.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], jnp.float32)
        x = M.det_fill((cfg.batch, cfg.d_in), seed + 100, 1.0)
        y = M.det_tokens(cfg.batch, 1, cfg.d_out, seed + 200).reshape(cfg.batch)
        data = [x, y]
        fn = (M.make_mlp_steps(cfg) if var.arch == "mlp" else M.make_resmlp_steps(cfg))[0]

    fn = jax.jit(fn)
    losses = []
    for step in range(2):
        if var.arch == "transformer":
            hp = hp.at[M.HP_STEP].set(float(step + 1))
        out = fn(*data, *params, *states, lr_vec, hp)
        losses.append(float(out[0]))
        params = list(out[1 : 1 + p])
        states = list(out[1 + p : 1 + p + n_state * p])
    return {"seed": seed, "losses": losses, "lr": float(lr_vec[0])}


def variant_manifest(var: Variant, pspecs, data, n_state, probes, hlo_file, golden):
    cfg = dataclasses.asdict(var.cfg)
    return {
        "name": var.name,
        "arch": var.arch,
        "kind": var.kind,
        "opt": "adam" if var.arch == "transformer" else "sgd",
        "hlo": hlo_file,
        "config": cfg,
        "data_inputs": [
            {"name": n, "dtype": d, "shape": list(s)} for n, d, s in data
        ],
        "n_state": n_state,
        "probes": probes,
        "params": [
            {
                "name": s.name,
                "shape": list(s.shape),
                "role": s.role,
                "fan_in": s.fan_in,
                "fan_out": s.fan_out,
                "init": s.init,
            }
            for s in pspecs
        ],
        "golden": golden,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on variant names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true", help="re-lower even if fresh")
    args = ap.parse_args(argv)

    registry = build_registry()
    rx = re.compile(args.only) if args.only else None
    if args.list:
        for v in registry:
            if rx is None or rx.search(v.name):
                print(f"{v.name:40s} {v.arch:12s} {v.kind}")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    # Incrementality: reuse existing manifest entries whose HLO file is
    # newer than every compile/ source file.
    src_mtime = max(
        os.path.getmtime(os.path.join(root, f))
        for root, _, files in os.walk(os.path.dirname(__file__))
        for f in files
        if f.endswith(".py")
    )
    old = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as fh:
            old = {e["name"]: e for e in json.load(fh)["variants"]}

    entries = []
    t_total = time.time()
    for var in registry:
        hlo_file = f"{var.name}.hlo.txt"
        hlo_path = os.path.join(args.out_dir, hlo_file)
        requested = rx is None or rx.search(var.name)
        fresh = (
            var.name in old
            and os.path.exists(hlo_path)
            and os.path.getmtime(hlo_path) >= src_mtime
        )
        if not requested:
            # Keep whatever we already have for unrequested variants so a
            # filtered run never shrinks the manifest.
            if var.name in old and os.path.exists(hlo_path):
                entries.append(old[var.name])
            continue
        if fresh and not args.force:
            entries.append(old[var.name])
            continue
        t0 = time.time()
        fn, arg_specs, pspecs, data, n_state, probes = variant_io(var)
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(hlo_path, "w") as fh:
            fh.write(text)
        golden = None
        if var.golden_seed and var.kind == "train":
            golden = compute_golden(var, pspecs, n_state)
        entries.append(
            variant_manifest(var, pspecs, data, n_state, probes, hlo_file, golden)
        )
        print(
            f"lowered {var.name:40s} {len(text) / 1e6:6.2f} MB  "
            f"{time.time() - t0:5.1f}s",
            flush=True,
        )

    with open(manifest_path, "w") as fh:
        json.dump({"version": 1, "variants": entries}, fh, indent=1)
    print(f"manifest: {manifest_path} ({len(entries)} variants, "
          f"{time.time() - t_total:.0f}s total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
