"""Fused optimizer-update Pallas kernels (Adam/AdamW and SGD+momentum).

μP lives or dies on *per-tensor* learning rates (Table 3/8: hidden weights
get η/fan_in-style scaling under Adam while vector-like tensors get η).
The per-tensor effective LR is computed host-side (Rust) / graph-side and
arrives here as a scalar operand, so a single compiled artifact serves any
point of the HP search space, any LR schedule, and both parametrizations.

Layout: every parameter tensor is viewed as a 2-D (rows, cols) plane and
the grid walks row blocks; param/grad/moment tiles stream through VMEM
exactly once (the update is bandwidth-bound, so blocks are sized for full
VMEM lines, not MXU occupancy — see DESIGN.md §Hardware-Adaptation).
Scalars ride in a tiny (1, 8) VMEM tile broadcast to every grid step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

# scalar-pack layout shared by both kernels (slot meanings differ per opt)
N_SCAL = 8


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, s_ref, po_ref, mo_ref, vo_ref):
    s = s_ref[0]
    lr, b1, b2, eps, wd, c1, c2 = s[0], s[1], s[2], s[3], s[4], s[5], s[6]
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m * c1
    vhat = v * c2
    upd = mhat / (jnp.sqrt(vhat) + eps)
    # AdamW-style decoupled weight decay (App. B.3: wd must NOT be scaled
    # with width; it is compatible with μP only in decoupled form).
    po_ref[...] = p_ref[...] - lr * upd - lr * wd * p_ref[...]
    mo_ref[...] = m
    vo_ref[...] = v


def _sgd_kernel(p_ref, g_ref, m_ref, s_ref, po_ref, mo_ref):
    s = s_ref[0]
    lr, mu, wd = s[0], s[1], s[2]
    mom = mu * m_ref[...] + g_ref[...]
    po_ref[...] = p_ref[...] - lr * (mom + wd * p_ref[...])
    mo_ref[...] = mom


def _as2d(a):
    if a.ndim == 2:
        return a, a.shape
    n = a.size
    return a.reshape(1, n), a.shape


def _rowspec(br, c):
    return pl.BlockSpec((br, c), lambda i: (i, 0))


def _scalspec():
    return pl.BlockSpec((1, N_SCAL), lambda i: (0, 0))


def adam_update(p, g, m, v, lr, beta1, beta2, eps, wd, count):
    """One fused Adam/AdamW step for a single tensor.

    ``lr`` is the *effective per-tensor* LR (master LR x μP scale x
    schedule), a traced scalar.  ``count`` is the 1-based step number used
    for bias correction, also traced so one artifact serves every step.
    Returns (p', m', v').
    """
    c1 = 1.0 / (1.0 - beta1**count)
    c2 = 1.0 / (1.0 - beta2**count)
    scal = jnp.stack(
        [lr, beta1, beta2, eps, wd, c1, c2, jnp.zeros_like(lr)]
    ).reshape(1, N_SCAL)
    p2, shape = _as2d(p)
    g2, _ = _as2d(g)
    m2, _ = _as2d(m)
    v2, _ = _as2d(v)
    r, c = p2.shape
    br = pick_block(r, 1024)
    out_shape = jax.ShapeDtypeStruct((r, c), jnp.float32)
    po, mo, vo = pl.pallas_call(
        _adam_kernel,
        grid=(r // br,),
        in_specs=[_rowspec(br, c)] * 4 + [_scalspec()],
        out_specs=[_rowspec(br, c)] * 3,
        out_shape=[out_shape] * 3,
        interpret=INTERPRET,
    )(p2, g2, m2, v2, scal)
    return po.reshape(shape), mo.reshape(shape), vo.reshape(shape)


def sgd_update(p, g, m, lr, momentum, wd):
    """One fused SGD(+momentum, +wd) step for a single tensor.

    Returns (p', momentum_buf').  Matches PyTorch SGD semantics
    (buf = mu*buf + grad; p -= lr*(buf + wd*p)) — the convention the
    paper's MLP/ResNet experiments (Fig. 3, Tab. 12/13) assume.
    """
    zero = jnp.zeros_like(lr)
    scal = jnp.stack([lr, momentum, wd, zero, zero, zero, zero, zero]).reshape(
        1, N_SCAL
    )
    p2, shape = _as2d(p)
    g2, _ = _as2d(g)
    m2, _ = _as2d(m)
    r, c = p2.shape
    br = pick_block(r, 1024)
    out_shape = jax.ShapeDtypeStruct((r, c), jnp.float32)
    po, mo = pl.pallas_call(
        _sgd_kernel,
        grid=(r // br,),
        in_specs=[_rowspec(br, c)] * 3 + [_scalspec()],
        out_specs=[_rowspec(br, c)] * 2,
        out_shape=[out_shape] * 2,
        interpret=INTERPRET,
    )(p2, g2, m2, scal)
    return po.reshape(shape), mo.reshape(shape)
