"""Pallas tiled matmul with custom VJP.

This is the workhorse of every linear layer in the L2 graphs.  Forward and
backward are both Pallas kernels: the backward pass reuses the same tiled
kernel on the transposed operands (dx = dy @ w^T, dw = x^T @ dy), so the
whole train-step graph lowers through Pallas.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is (M/bm, N/bn,
K/bk) with the K dimension innermost; each (i, j) output tile stays
resident in VMEM across the K loop and accumulates partial MXU products —
the same schedule a CUDA kernel expresses with a threadblock looping over
K-tiles staged through shared memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; accumulates over the K grid dimension.

    The output BlockSpec index map ignores k, so the same VMEM tile is
    revisited for every k step ("arbitrary" grid semantics): initialize at
    k == 0, accumulate afterwards.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_fwd_pallas(x: jax.Array, w: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) tiled Pallas matmul (no autodiff rule)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    # Interpret-mode profile: coarser tiles amortize per-grid-step
    # dispatch (perf iter 3, EXPERIMENTS.md §Perf).  On a real TPU set the
    # caps back to MXU_TILE=128; the schedule is unchanged.
    bm, bk, bn = pick_block(m, 256), pick_block(k, 512), pick_block(n, 256)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w)


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable tiled matmul; both passes are Pallas kernels."""
    return matmul_fwd_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_fwd_pallas(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    # dx = dy @ w^T ; dw = x^T @ dy.  Transposes are layout changes XLA
    # fuses into the kernel's operand reads.
    dx = matmul_fwd_pallas(dy, w.T)
    dw = matmul_fwd_pallas(x.T, dy)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    """Apply a weight of shape (fan_in, fan_out) to x of shape (..., fan_in).

    Collapses leading dims to a single M so the 2-D tiled kernel serves
    every call site (token matrices, flattened images, ...).
    """
    lead = x.shape[:-1]
    m = 1
    for d in lead:
        m *= d
    y = matmul(x.reshape(m, x.shape[-1]), w)
    return y.reshape(*lead, w.shape[1])
