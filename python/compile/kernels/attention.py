"""Fused causal self-attention as Pallas kernels, with custom VJP.

The μP-critical piece of the whole model: Definition 4.1 replaces the
standard 1/sqrt(d) attention-logit scaling with 1/d (times the tunable
α_attn and the base-width compatibility factor sqrt(d_head,0)).  The scale
is a *runtime scalar input* to the lowered graph — the same compiled
artifact serves SP (1/sqrt(d)) and μP (1/d) by feeding a different value —
so here the kernel takes pre-scaled queries and is parametrization-agnostic.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over (batch*heads); each
step stages the whole (S, d_head) q/k/v tiles plus the (S, S) logit tile in
VMEM and runs two MXU matmuls around a row softmax.  At our sizes
(S <= 128, d_head <= 192) that is <= 0.4 MiB resident — a flash-style
S-blocked online softmax is unnecessary (documented VMEM check in
tests/test_kernels.py::test_attention_vmem_budget).

Forward returns the (masked, pre-softmax) attention logits as a secondary
output: the coordinate-checking experiments (Fig. 5) probe exactly this
tensor, and the backward kernel consumes the saved probabilities.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

NEG_INF = -1e30


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, p_ref, l_ref):
    # Block carries G heads at once: (G, S, dh).  Batched MXU contractions
    # via dot_general keep each grid step coarse (perf iter 2 in
    # EXPERIMENTS.md §Perf: one head per step left the interpret-mode grid
    # dominated by dispatch).
    q = q_ref[...]  # (G, S, dh) — queries arrive pre-scaled
    k = k_ref[...]
    v = v_ref[...]
    s = q.shape[1]
    logits = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )  # (G, S, S)
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    causal = (col <= row)[None]
    masked = jnp.where(causal, logits, NEG_INF)
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.exp(masked - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    p_ref[...] = p
    # Emit 0 (not -inf) on masked entries so coordinate statistics over the
    # logit tensor are finite; Fig. 5 measures the causal (live) entries'
    # scale and the zeros dilute uniformly across widths.
    l_ref[...] = jnp.where(causal, logits, 0.0)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, p_ref, do_ref, dl_ref, dq_ref, dk_ref, dv_ref):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    p = p_ref[...]
    do = do_ref[...]
    dl_direct = dl_ref[...]  # cotangent of the emitted logits output (usually 0)
    s = q.shape[1]

    bmm = lambda a, b, dims: jax.lax.dot_general(
        a, b, (dims, ((0,), (0,))), preferred_element_type=jnp.float32
    )
    # dv = p^T @ do per head: contract over the query axis
    dv_ref[...] = bmm(p, do, ((1,), (1,)))
    dp = bmm(do, v, ((2,), (2,)))
    # softmax jacobian: dlogits = p * (dp - sum(dp * p, axis=-1))
    dl = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    causal = (col <= row)[None]
    dl = dl + jnp.where(causal, dl_direct, 0.0)
    dq_ref[...] = bmm(dl, k, ((2,), (1,)))
    dk_ref[...] = bmm(dl, q, ((1,), (1,)))


def _flatten(q):
    b, h, s, dh = q.shape
    return q.reshape(b * h, s, dh), (b, h, s, dh)


def _attn_call_fwd(qs, k, v):
    q2, (b, h, s, dh) = _flatten(qs)
    k2, _ = _flatten(k)
    v2, _ = _flatten(v)
    bh = b * h
    g = pick_block(bh, 16)
    spec_qkv = pl.BlockSpec((g, s, dh), lambda i: (i, 0, 0))
    spec_ss = pl.BlockSpec((g, s, s), lambda i: (i, 0, 0))
    out, p, logits = pl.pallas_call(
        _attn_fwd_kernel,
        in_specs=[spec_qkv, spec_qkv, spec_qkv],
        out_specs=[spec_qkv, spec_ss, spec_ss],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, s, s), jnp.float32),
            jax.ShapeDtypeStruct((bh, s, s), jnp.float32),
        ],
        interpret=INTERPRET,
        grid=(bh // g,),
    )(q2, k2, v2)
    shape4 = (b, h, s, dh)
    return out.reshape(shape4), p.reshape(b, h, s, s), logits.reshape(b, h, s, s)


@jax.custom_vjp
def attention_core(qs, k, v):
    """Causal attention on pre-scaled queries.

    Returns (context, attn_logits).  ``attn_logits`` is the masked
    pre-softmax logit tensor used by coordinate checking; it participates
    in autodiff (zero cotangent when unused).
    """
    out, _p, logits = _attn_call_fwd(qs, k, v)
    return out, logits


def _attention_fwd(qs, k, v):
    out, p, logits = _attn_call_fwd(qs, k, v)
    return (out, logits), (qs, k, v, p)


def _attention_bwd(res, cts):
    do, dlogits = cts
    qs, k, v, p = res
    b, h, s, dh = qs.shape
    bh = b * h
    g = pick_block(bh, 16)
    spec_qkv = pl.BlockSpec((g, s, dh), lambda i: (i, 0, 0))
    spec_ss = pl.BlockSpec((g, s, s), lambda i: (i, 0, 0))
    dq, dk, dv = pl.pallas_call(
        _attn_bwd_kernel,
        grid=(bh // g,),
        in_specs=[spec_qkv, spec_qkv, spec_qkv, spec_ss, spec_qkv, spec_ss],
        out_specs=[spec_qkv, spec_qkv, spec_qkv],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
        ],
        interpret=INTERPRET,
    )(
        qs.reshape(bh, s, dh),
        k.reshape(bh, s, dh),
        v.reshape(bh, s, dh),
        p.reshape(bh, s, s),
        do.reshape(bh, s, dh),
        dlogits.reshape(bh, s, s),
    )
    shape4 = (b, h, s, dh)
    return dq.reshape(shape4), dk.reshape(shape4), dv.reshape(shape4)


attention_core.defvjp(_attention_fwd, _attention_bwd)


def attention(q, k, v, scale):
    """Causal multi-head attention with runtime logit scale.

    q, k, v: (B, H, S, d_head); ``scale`` is a traced scalar — α_attn·√d₀/d
    under μP (Definition 4.1) or 1/√d under SP, computed host-side by the
    Rust coordinator and fed as part of the hp vector.
    """
    return attention_core(q * scale, k, v)
