"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (python/tests/test_kernels.py)
asserts kernel == oracle to fp32 tolerance under hypothesis-driven shape
sweeps, and the L2 model tests rebuild whole train steps against these to
catch integration drift.  Nothing here is ever lowered into artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LN_EPS = 1e-5
NEG_INF = -1e30


def matmul_ref(x, w):
    return jnp.matmul(x, w)


def layernorm_ref(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    return xc * jax.lax.rsqrt(var + LN_EPS) * g + b


def attention_ref(q, k, v, scale):
    """Causal attention oracle; returns (context, masked_logits)."""
    s = q.shape[-2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q * scale, k)
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    causal = col <= row
    masked = jnp.where(causal, logits, NEG_INF)
    p = jax.nn.softmax(masked, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return out, jnp.where(causal, logits, 0.0)


def adam_update_ref(p, g, m, v, lr, beta1, beta2, eps, wd, count):
    m2 = beta1 * m + (1 - beta1) * g
    v2 = beta2 * v + (1 - beta2) * g * g
    mhat = m2 / (1 - beta1**count)
    vhat = v2 / (1 - beta2**count)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps)) - lr * wd * p
    return p2, m2, v2


def sgd_update_ref(p, g, m, lr, momentum, wd):
    m2 = momentum * m + g
    p2 = p - lr * (m2 + wd * p)
    return p2, m2
