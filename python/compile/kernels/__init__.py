"""L1: Pallas kernels for the μTransfer reproduction.

Public surface used by the L2 graphs (``compile.model``):

- :func:`matmul.linear` / :func:`matmul.matmul` — tiled MXU matmul (custom VJP)
- :func:`attention.attention` — fused causal attention with runtime logit
  scale (the μP 1/d vs SP 1/sqrt(d) switch of Definition 4.1)
- :func:`layernorm.layernorm` — row-blocked layernorm (custom VJP)
- :func:`optim.adam_update` / :func:`optim.sgd_update` — fused per-tensor-LR
  optimizer steps

All kernels lower with ``interpret=True`` (CPU PJRT has no Mosaic); see
``common.INTERPRET``.
"""

from .attention import attention, attention_core  # noqa: F401
from .layernorm import layernorm  # noqa: F401
from .matmul import linear, matmul  # noqa: F401
from .optim import adam_update, sgd_update  # noqa: F401
