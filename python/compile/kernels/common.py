"""Shared helpers for the Pallas kernels (L1).

All kernels in this package are written for the TPU mental model (VMEM
tiles, MXU-shaped matmuls) but are lowered with ``interpret=True`` so they
execute as plain HLO on the CPU PJRT backend (see /opt/xla-example/README.md:
real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run).

Because of that, the *structure* (BlockSpecs, grids, accumulation pattern)
is what we optimize; wall-clock on CPU is not a TPU proxy.  The VMEM
footprint estimators at the bottom feed DESIGN.md / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax.numpy as jnp

# Every pallas_call in this repo goes through this flag so the whole stack
# can be flipped to compiled mode on a real TPU by changing one constant.
INTERPRET = True

# Preferred MXU-friendly tile edge.  The TPU MXU is a 128x128 systolic
# array; the lane dimension of VMEM tiles is 128 wide.  We tile down to
# smaller powers of two when a dimension is smaller than 128 (common in the
# proxy models: d_head can be as small as 4 in the fig10 ablation).
MXU_TILE = 128

# VMEM budget per core in bytes (v4/v5-class part); used only for the
# static footprint checks, never at runtime.
VMEM_BYTES = 16 * 1024 * 1024


def pick_block(dim: int, preferred: int = MXU_TILE) -> int:
    """Largest power-of-two tile <= ``preferred`` that divides ``dim``.

    Falls back to ``dim`` itself when no power of two divides it (e.g. the
    10-class readout of the vision MLP).  All model dimensions in this repo
    are chosen to be powers of two or small, so this keeps every grid exact
    (no masking needed) while still producing real multi-tile grids for the
    large widths.
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    b = preferred
    while b > 1:
        if dim % b == 0:
            return b
        b //= 2
    return dim if dim % 1 == 0 and dim < preferred else 1


def grid_dims(m: int, bm: int) -> int:
    """Number of grid steps for a dimension tiled by ``bm`` (must divide)."""
    if m % bm != 0:
        raise ValueError(f"block {bm} does not divide dim {m}")
    return m // bm


def vmem_bytes(*shapes_dtypes) -> int:
    """Static VMEM footprint estimate for a set of resident tiles.

    ``shapes_dtypes`` is a sequence of (shape_tuple, dtype) pairs; returns
    total bytes.  Used by tests to assert each kernel's working set fits the
    16 MiB VMEM budget at every model size we ship artifacts for.
    """
    total = 0
    for shape, dtype in shapes_dtypes:
        n = 1
        for d in shape:
            n *= int(d)
        total += n * jnp.dtype(dtype).itemsize
    return total


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of the 128x128x8 MXU pass actually filled by a (bm,bk)x(bk,bn)
    tile matmul.  1.0 means perfectly MXU-shaped tiles.  This is the static
    efficiency estimate recorded in DESIGN.md §Perf (interpret=True gives no
    hardware counters)."""
    eff_m = min(bm, MXU_TILE) / MXU_TILE
    eff_n = min(bn, MXU_TILE) / MXU_TILE
    eff_k = min(bk, MXU_TILE) / MXU_TILE
    return eff_m * eff_n * eff_k
