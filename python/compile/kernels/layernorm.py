"""Pallas layernorm with custom VJP.

Row-blocked: the grid walks blocks of rows; each block's (bm, D) tile is
normalized entirely in VMEM.  The feature dimension is kept whole inside
the kernel (layernorm is a row reduction; splitting D would need a
two-pass scheme for no benefit at our widths: D <= 1024 -> a (8, 1024) f32
tile is 32 KiB, far under the VMEM budget).

Backward uses the standard closed-form layernorm gradient, also as a
Pallas kernel, recomputing mean/var from the residual x (cheaper than
storing normalized activations at our sizes; rematerialization choice
recorded in DESIGN.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

EPS = 1e-5


def _ln_fwd_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    o_ref[...] = xc * inv * g_ref[...] + b_ref[...]


def _ln_bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, dg_ref, db_ref, *, nrows: int):
    """Gradient tile for a row block.

    dg/db are reductions over *all* rows; each grid step owns a disjoint
    row block, and the (1, D) dg/db output tiles are revisited by every
    step (index map is constant), so we initialize at step 0 and
    accumulate — the same revisit pattern as the matmul K loop.
    """
    i = pl.program_id(0)
    x = x_ref[...]
    g = g_ref[...]
    dy = dy_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = xc * inv

    dxhat = dy * g
    d = x.shape[-1]
    # dx = inv/D * (D*dxhat - sum(dxhat) - xhat * sum(dxhat*xhat))
    s1 = jnp.sum(dxhat, axis=-1, keepdims=True)
    s2 = jnp.sum(dxhat * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (inv / d) * (d * dxhat - s1 - xhat * s2)

    @pl.when(i == 0)
    def _init():
        dg_ref[...] = jnp.zeros_like(dg_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    dg_ref[...] += jnp.sum(dy * xhat, axis=0, keepdims=True)
    db_ref[...] += jnp.sum(dy, axis=0, keepdims=True)


def _rows(x):
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    return m, x.shape[-1]


def layernorm_fwd_pallas(x, g, b):
    m, d = _rows(x)
    x2 = x.reshape(m, d)
    bm = pick_block(m, 128)
    y = pl.pallas_call(
        _ln_fwd_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=INTERPRET,
    )(x2, g.reshape(1, d), b.reshape(1, d))
    return y.reshape(x.shape)


@jax.custom_vjp
def layernorm(x, g, b):
    """y = (x - mean) / sqrt(var + eps) * g + b over the last axis."""
    return layernorm_fwd_pallas(x, g, b)


def _layernorm_fwd(x, g, b):
    return layernorm_fwd_pallas(x, g, b), (x, g)


def _layernorm_bwd(res, dy):
    x, g = res
    m, d = _rows(x)
    x2 = x.reshape(m, d)
    dy2 = dy.reshape(m, d)
    bm = pick_block(m, 128)
    import functools

    dx, dg, db = pl.pallas_call(
        functools.partial(_ln_bwd_kernel, nrows=m // bm),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x2, g.reshape(1, d), dy2)
    return dx.reshape(x.shape), dg.reshape(g.shape), db.reshape(g.shape)


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
