"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (and scalar HPs for the optimizer kernels); each
comparison covers both the forward value and the custom-VJP gradients.
Shapes are kept modest because interpret-mode Pallas executes eagerly here,
but they cross tile boundaries (dims both below and above the 128 MXU tile
and the 8/256-row blocks) so the grid logic is genuinely exercised.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    adam_update,
    attention,
    layernorm,
    matmul,
    sgd_update,
)
from compile.kernels import ref
from compile.kernels.common import MXU_TILE, VMEM_BYTES, mxu_utilization, pick_block, vmem_bytes

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# tiling helpers
# ---------------------------------------------------------------------------


@given(dim=st.integers(min_value=1, max_value=4096))
@settings(max_examples=60, deadline=None)
def test_pick_block_divides(dim):
    b = pick_block(dim)
    assert dim % b == 0
    assert b <= max(dim, MXU_TILE)


@pytest.mark.parametrize("dim,expect", [(128, 128), (256, 128), (96, 32), (10, 2), (1, 1), (384, 128)])
def test_pick_block_values(dim, expect):
    assert pick_block(dim) == expect


def test_mxu_utilization_full_tile():
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(64, 128, 128) == 0.5


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

DIMS = st.sampled_from([1, 2, 4, 8, 10, 16, 32, 48, 64, 96, 128, 160, 256])


@given(m=DIMS, k=DIMS, n=DIMS)
@settings(**SETTINGS)
def test_matmul_matches_ref(m, k, n):
    x = _rand(m * 1000 + k, (m, k))
    w = _rand(n, (k, n))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@given(m=st.sampled_from([4, 16, 48]), k=st.sampled_from([8, 32, 96]), n=st.sampled_from([8, 24, 64]))
@settings(**SETTINGS)
def test_matmul_grads_match_ref(m, k, n):
    x = _rand(1, (m, k))
    w = _rand(2, (k, n))

    def f(mm):
        return lambda a, b: jnp.sum(jnp.sin(mm(a, b)))

    gx, gw = jax.grad(f(matmul), (0, 1))(x, w)
    rx, rw = jax.grad(f(ref.matmul_ref), (0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


def test_matmul_large_tiled_grid():
    # 256x256x256 -> 2x2x2 grid of 128-tiles: exercises k-accumulation.
    x = _rand(3, (256, 256))
    w = _rand(4, (256, 256))
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@given(rows=st.sampled_from([1, 4, 8, 24, 64]), d=st.sampled_from([8, 32, 128, 512]))
@settings(**SETTINGS)
def test_layernorm_matches_ref(rows, d):
    x = _rand(rows, (rows, d))
    g = _rand(d, (d,)) * 0.1 + 1.0
    b = _rand(d + 1, (d,)) * 0.1
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-4, atol=1e-4
    )


@given(rows=st.sampled_from([4, 16]), d=st.sampled_from([16, 64]))
@settings(**SETTINGS)
def test_layernorm_grads_match_ref(rows, d):
    x = _rand(rows * 7, (rows, d))
    g = _rand(d, (d,)) * 0.1 + 1.0
    b = jnp.zeros((d,))

    def f(ln):
        return lambda x_, g_, b_: jnp.sum(jnp.cos(ln(x_, g_, b_)))

    got = jax.grad(f(layernorm), (0, 1, 2))(x, g, b)
    want = jax.grad(f(ref.layernorm_ref), (0, 1, 2))(x, g, b)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_layernorm_3d_input():
    x = _rand(9, (2, 8, 32))
    g = jnp.ones((32,))
    b = jnp.zeros((32,))
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 8, 16, 32]),
    dh=st.sampled_from([4, 8, 16, 32]),
    scale=st.sampled_from([1.0, 0.25, 0.03125]),
)
@settings(**SETTINGS)
def test_attention_matches_ref(b, h, s, dh, scale):
    q = _rand(1, (b, h, s, dh))
    k = _rand(2, (b, h, s, dh))
    v = _rand(3, (b, h, s, dh))
    o, lg = attention(q, k, v, scale)
    ro, rlg = ref.attention_ref(q, k, v, scale)
    np.testing.assert_allclose(o, ro, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(lg, rlg, rtol=1e-4, atol=1e-4)


def test_attention_is_causal():
    """Output at position t must not depend on tokens after t."""
    b, h, s, dh = 1, 1, 8, 4
    q = _rand(1, (b, h, s, dh))
    k = _rand(2, (b, h, s, dh))
    v = _rand(3, (b, h, s, dh))
    o1, _ = attention(q, k, v, 0.5)
    # perturb the last key/value: earlier outputs must be identical
    k2 = k.at[..., -1, :].add(100.0)
    v2 = v.at[..., -1, :].add(-50.0)
    o2, _ = attention(q, k2, v2, 0.5)
    np.testing.assert_allclose(o1[..., :-1, :], o2[..., :-1, :], rtol=1e-6, atol=1e-6)
    assert not np.allclose(o1[..., -1, :], o2[..., -1, :])


@given(s=st.sampled_from([4, 16]), dh=st.sampled_from([4, 16]))
@settings(**SETTINGS)
def test_attention_grads_match_ref(s, dh):
    q = _rand(11, (1, 2, s, dh))
    k = _rand(12, (1, 2, s, dh))
    v = _rand(13, (1, 2, s, dh))

    def f(attn):
        return lambda q_, k_, v_: jnp.sum(jnp.tanh(attn(q_, k_, v_, 0.2)[0]))

    got = jax.grad(f(attention), (0, 1, 2))(q, k, v)
    want = jax.grad(f(ref.attention_ref), (0, 1, 2))(q, k, v)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_attention_logit_probe_grads():
    """Gradients flow correctly when the logits output itself is used."""
    q = _rand(21, (1, 1, 8, 8))
    k = _rand(22, (1, 1, 8, 8))
    v = _rand(23, (1, 1, 8, 8))

    def f(attn):
        def g(q_, k_, v_):
            o, lg = attn(q_, k_, v_, 0.3)
            return jnp.sum(o) + jnp.sum(lg**2)

        return g

    got = jax.grad(f(attention), (0, 1, 2))(q, k, v)
    want = jax.grad(f(ref.attention_ref), (0, 1, 2))(q, k, v)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_attention_vmem_budget():
    """The fused attention working set fits VMEM at every shipped shape
    (DESIGN.md §Hardware-Adaptation)."""
    for s, dh in [(32, 8), (32, 128), (64, 32), (128, 192)]:
        resident = vmem_bytes(
            ((s, dh), jnp.float32),  # q
            ((s, dh), jnp.float32),  # k
            ((s, dh), jnp.float32),  # v
            ((s, s), jnp.float32),  # logits
            ((s, s), jnp.float32),  # probs
            ((s, dh), jnp.float32),  # out
        )
        assert resident < VMEM_BYTES, (s, dh, resident)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@given(
    shape=st.sampled_from([(8, 16), (64,), (256, 8), (3, 3)]),
    lr=st.sampled_from([1e-4, 1e-2, 0.5]),
    wd=st.sampled_from([0.0, 0.01]),
    count=st.sampled_from([1.0, 2.0, 100.0]),
)
@settings(**SETTINGS)
def test_adam_matches_ref(shape, lr, wd, count):
    p = _rand(1, shape)
    g = _rand(2, shape)
    m = _rand(3, shape) * 0.1
    v = jnp.abs(_rand(4, shape)) * 0.01
    args = (jnp.float32(lr), jnp.float32(0.9), jnp.float32(0.999), jnp.float32(1e-8), jnp.float32(wd), jnp.float32(count))
    got = adam_update(p, g, m, v, *args)
    want = ref.adam_update_ref(p, g, m, v, lr, 0.9, 0.999, 1e-8, wd, count)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


@given(
    shape=st.sampled_from([(8, 16), (64,), (10,)]),
    lr=st.sampled_from([1e-3, 0.1, 1.0]),
    mu=st.sampled_from([0.0, 0.9]),
    wd=st.sampled_from([0.0, 1e-4]),
)
@settings(**SETTINGS)
def test_sgd_matches_ref(shape, lr, mu, wd):
    p = _rand(5, shape)
    g = _rand(6, shape)
    m = _rand(7, shape) * 0.1
    got = sgd_update(p, g, m, jnp.float32(lr), jnp.float32(mu), jnp.float32(wd))
    want = ref.sgd_update_ref(p, g, m, lr, mu, wd)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-5, atol=1e-6)


def test_adam_zero_state_first_step():
    """First step from zero moments must equal signed-gradient-ish update."""
    p = jnp.zeros((4, 4))
    g = jnp.ones((4, 4))
    out = adam_update(
        p, g, jnp.zeros_like(p), jnp.zeros_like(p),
        jnp.float32(1e-3), jnp.float32(0.9), jnp.float32(0.999),
        jnp.float32(1e-8), jnp.float32(0.0), jnp.float32(1.0),
    )
    # mhat = g, vhat = g^2 -> update = g/|g| = 1 -> p' = -lr
    np.testing.assert_allclose(out[0], -1e-3 * jnp.ones((4, 4)), rtol=1e-4)


def test_sgd_is_pure_gd_without_momentum():
    p = _rand(8, (16,))
    g = _rand(9, (16,))
    got = sgd_update(p, g, jnp.zeros_like(p), jnp.float32(0.1), jnp.float32(0.0), jnp.float32(0.0))
    np.testing.assert_allclose(got[0], p - 0.1 * g, rtol=1e-6, atol=1e-7)
