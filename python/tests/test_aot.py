"""AOT pipeline tests: registry sanity, calling-convention arithmetic,
manifest schema, and HLO-text lowering of a tiny variant."""

import json

import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_registry_unique_and_complete():
    reg = aot.build_registry()
    names = [v.name for v in reg]
    assert len(names) == len(set(names))
    # every train variant ships an eval twin
    trains = {v.name for v in reg if v.kind == "train"}
    evals = {v.name for v in reg if v.kind == "eval"}
    for t in trains:
        assert f"{t}__eval" in evals, t
    # the experiment index needs these
    for required in [
        "tfm_post_w32_d2",
        "tfm_post_w128_d2__coord",
        "tfm_pre_w128_d2_f1024",
        "tfm_pre_nh8_hd16",
        "tfm_pre_w256_d4",
        "mlp_w1024",
        "mlp_tanhmse_w256",
        "resmlp_w128",
    ]:
        assert any(v.name == required for v in reg), required


@pytest.mark.parametrize("kind", ["train", "eval", "coord"])
def test_variant_io_arity(kind):
    cfg = M.TransformerConfig(vocab=8, seq=4, batch=2, d_model=8, n_layer=1, n_head=2, d_head=4, d_ffn=16)
    var = aot.Variant("t", "transformer", kind, cfg)
    fn, arg_specs, pspecs, data, n_state, probes = aot.variant_io(var)
    p = len(pspecs)
    if kind == "eval":
        assert len(arg_specs) == 1 + p + 1
        assert probes == []
    else:
        assert len(arg_specs) == 1 + p * (1 + n_state) + 2
    if kind == "coord":
        assert probes == ["embed_out", "attn_logits_l0", "block_out", "logits"]
    # specs must actually be consumable by the step function
    out = fn(*[jnp.zeros(s.shape, s.dtype) for s in arg_specs])
    n_out = {"train": 1 + 3 * p, "coord": 1 + 3 * p + 4, "eval": 1}[kind]
    assert len(out) == n_out


def test_hlo_text_lowering_tiny():
    import jax

    cfg = M.MlpConfig(d_in=4, width=8, d_out=3, batch=2)
    var = aot.Variant("m", "mlp", "train", cfg)
    fn, arg_specs, *_ = aot.variant_io(var)
    text = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_entry_schema():
    cfg = M.TransformerConfig(vocab=8, seq=4, batch=2, d_model=8, n_layer=1, n_head=2, d_head=4, d_ffn=16)
    var = aot.Variant("t", "transformer", "train", cfg)
    _, _, pspecs, data, n_state, probes = aot.variant_io(var)
    entry = aot.variant_manifest(var, pspecs, data, n_state, probes, "t.hlo.txt", None)
    # round-trips through json and has the fields the Rust loader requires
    entry = json.loads(json.dumps(entry))
    for key in ["name", "arch", "kind", "opt", "hlo", "config", "data_inputs", "n_state", "probes", "params", "golden"]:
        assert key in entry, key
    p0 = entry["params"][0]
    for key in ["name", "shape", "role", "fan_in", "fan_out", "init"]:
        assert key in p0, key
    assert entry["config"]["ln"] in ("pre", "post")


def test_golden_reproducible():
    cfg = M.MlpConfig(d_in=4, width=8, d_out=3, batch=2)
    var = aot.Variant("m", "mlp", "train", cfg, golden_seed=5)
    _, _, pspecs, _, n_state, _ = aot.variant_io(var)
    g1 = aot.compute_golden(var, pspecs, n_state)
    g2 = aot.compute_golden(var, pspecs, n_state)
    assert g1["losses"] == g2["losses"]
    assert len(g1["losses"]) == 2
    assert all(abs(x) < 100 for x in g1["losses"])
