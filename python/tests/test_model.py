"""L2 correctness: whole train/eval steps against an independent pure-jnp
reference implementation (built only from ref.py oracles + jnp), plus
μP-relevant behavioural checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.TransformerConfig(vocab=16, seq=8, batch=2, d_model=16, n_layer=2, n_head=2, d_head=8, d_ffn=32)


# ---------------------------------------------------------------------------
# independent reference transformer (no Pallas anywhere)
# ---------------------------------------------------------------------------


def ref_transformer_fwd(cfg, params, tokens, hp):
    attn_scale, output_scale, embed_scale = hp[0], hp[1], hp[2]
    x = (jnp.take(params["embed"], tokens, axis=0) + params["pos_embed"][None, : tokens.shape[1]]) * embed_scale

    def split(t):
        b, s, _ = t.shape
        return t.reshape(b, s, cfg.n_head, cfg.d_head).transpose(0, 2, 1, 3)

    for i in range(cfg.n_layer):
        p = f"block{i}."

        def attn(h):
            q, k, v = (h @ params[p + w] for w in ("wq", "wk", "wv"))
            ctx, _ = ref.attention_ref(split(q), split(k), split(v), attn_scale)
            b, nh, s, dh = ctx.shape
            return ctx.transpose(0, 2, 1, 3).reshape(b, s, nh * dh) @ params[p + "wo"]

        def ffn(h):
            return jax.nn.relu(h @ params[p + "w1"]) @ params[p + "w2"]

        if cfg.ln == "pre":
            x = x + attn(ref.layernorm_ref(x, params[p + "ln1_g"], params[p + "ln1_b"]))
            x = x + ffn(ref.layernorm_ref(x, params[p + "ln2_g"], params[p + "ln2_b"]))
        else:
            x = ref.layernorm_ref(x + attn(x), params[p + "ln1_g"], params[p + "ln1_b"])
            x = ref.layernorm_ref(x + ffn(x), params[p + "ln2_g"], params[p + "ln2_b"])
    if cfg.ln == "pre":
        x = ref.layernorm_ref(x, params["lnf_g"], params["lnf_b"])
    return (x @ params["unembed"]) * output_scale


def ref_train_step(cfg, specs, data, params, ms, vs, lr_vec, hp):
    tokens = data[0]
    x_in, y = tokens[:, : cfg.seq], tokens[:, 1 : cfg.seq + 1]

    def loss_fn(plist):
        logits = ref_transformer_fwd(cfg, {s.name: t for s, t in zip(specs, plist)}, x_in, hp)
        return M.lm_loss(logits, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = [
        ref.adam_update_ref(p, g, m, v, lr_vec[i], hp[3], hp[4], hp[5], hp[6], hp[7])
        for i, (p, g, m, v) in enumerate(zip(params, grads, ms, vs))
    ]
    return loss, [t[0] for t in new], [t[1] for t in new], [t[2] for t in new]


def _init(cfg, seed=3):
    specs = M.transformer_param_specs(cfg)
    params = []
    for i, s in enumerate(specs):
        if s.init == "ones":
            params.append(jnp.ones(s.shape, jnp.float32))
        elif s.init == "zeros":
            # use nonzero values anyway so gradients flow through every path
            params.append(M.det_fill(s.shape, seed + i, 0.05))
        else:
            params.append(M.det_fill(s.shape, seed + i, 0.1))
    return specs, params


HP = jnp.array([0.2, 1.0, 1.0, 0.9, 0.999, 1e-8, 0.01, 1.0], jnp.float32)


@pytest.mark.parametrize("ln", ["pre", "post"])
def test_transformer_train_step_matches_reference(ln):
    cfg = dataclasses.replace(CFG, ln=ln)
    specs, params = _init(cfg)
    n = len(specs)
    ms = [jnp.zeros(s.shape, jnp.float32) for s in specs]
    vs = [jnp.zeros(s.shape, jnp.float32) for s in specs]
    lr_vec = jnp.full((n,), 1e-3, jnp.float32)
    tokens = M.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 77)

    train, _, _ = M.make_transformer_steps(cfg)
    out = jax.jit(train)(tokens, *params, *ms, *vs, lr_vec, HP)
    loss = out[0]
    new_p = out[1 : 1 + n]

    rloss, rp, _, _ = ref_train_step(cfg, specs, [tokens], params, ms, vs, lr_vec, HP)
    np.testing.assert_allclose(loss, rloss, rtol=1e-4, atol=1e-5)
    for a, e in zip(new_p, rp):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-5)


def test_transformer_eval_matches_fwd_loss():
    cfg = CFG
    specs, params = _init(cfg)
    tokens = M.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 5)
    _, evl, _ = M.make_transformer_steps(cfg)
    loss = jax.jit(evl)(tokens, *params, HP)[0]
    rlogits = ref_transformer_fwd(
        cfg, {s.name: t for s, t in zip(specs, params)}, tokens[:, : cfg.seq], HP
    )
    rloss = M.lm_loss(rlogits, tokens[:, 1 : cfg.seq + 1])
    np.testing.assert_allclose(loss, rloss, rtol=1e-4, atol=1e-5)


def test_transformer_loss_decreases_over_steps():
    cfg = CFG
    specs, params = _init(cfg)
    n = len(specs)
    ms = [jnp.zeros(s.shape) for s in specs]
    vs = [jnp.zeros(s.shape) for s in specs]
    lr_vec = jnp.full((n,), 3e-3, jnp.float32)
    train, _, _ = M.make_transformer_steps(cfg)
    train = jax.jit(train)
    tokens = M.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 9)
    losses = []
    hp = HP
    for t in range(8):
        hp = hp.at[M.HP_STEP].set(float(t + 1))
        out = train(tokens, *params, *ms, *vs, lr_vec, hp)
        losses.append(float(out[0]))
        params = list(out[1 : 1 + n])
        ms = list(out[1 + n : 1 + 2 * n])
        vs = list(out[1 + 2 * n : 1 + 3 * n])
    assert losses[-1] < losses[0], losses


def test_coord_step_probe_shapes():
    cfg = CFG
    specs, params = _init(cfg)
    n = len(specs)
    ms = [jnp.zeros(s.shape) for s in specs]
    vs = [jnp.zeros(s.shape) for s in specs]
    _, _, coord = M.make_transformer_steps(cfg)
    tokens = M.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 1)
    out = jax.jit(coord)(tokens, *params, *ms, *vs, jnp.full((n,), 1e-3), HP)
    assert len(out) == 1 + 3 * n + 4
    embed_out, attn_logits, block_out, logits = out[-4:]
    assert embed_out.shape == (cfg.batch, cfg.seq, cfg.d_model)
    assert attn_logits.shape == (cfg.batch, cfg.n_head, cfg.seq, cfg.seq)
    assert block_out.shape == (cfg.batch, cfg.seq, cfg.d_model)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)


def test_output_zero_init_gives_uniform_loss():
    """With the App. D.2 zero-initialized readout the initial loss is
    exactly log(vocab) at every width — the basis of the §8 check."""
    for w in [16, 32]:
        cfg = dataclasses.replace(CFG, d_model=w, d_head=w // 2, d_ffn=2 * w)
        specs = M.transformer_param_specs(cfg)
        params = [
            jnp.ones(s.shape) if s.init == "ones"
            else jnp.zeros(s.shape) if s.init == "zeros"
            else M.det_fill(s.shape, 3, 0.1)
            for s in specs
        ]
        _, evl, _ = M.make_transformer_steps(cfg)
        tokens = M.det_tokens(cfg.batch, cfg.seq + 1, cfg.vocab, 2)
        loss = jax.jit(evl)(tokens, *params, HP)[0]
        np.testing.assert_allclose(loss, np.log(cfg.vocab), rtol=1e-5)


# ---------------------------------------------------------------------------
# MLP / ResMLP
# ---------------------------------------------------------------------------


def _mlp_ref_step(cfg, specs, x, y, params, ms, lr_vec, hp):
    def loss_fn(plist):
        d = {s.name: t for s, t in zip(specs, plist)}
        act = jax.nn.relu if cfg.act == "relu" else jnp.tanh
        h = act(x @ d["w1"] + d["b1"])
        h = act(h @ d["w2"] + d["b2"])
        logits = (h @ d["w3"]) * hp[0]
        if cfg.loss == "xent":
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
            return jnp.mean(logz - gold)
        onehot = jax.nn.one_hot(y, cfg.d_out, dtype=jnp.float32)
        return jnp.mean((logits - onehot) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = [
        ref.sgd_update_ref(p, g, m, lr_vec[i], hp[1], hp[2])
        for i, (p, g, m) in enumerate(zip(params, grads, ms))
    ]
    return loss, [t[0] for t in new]


@pytest.mark.parametrize("act,loss", [("relu", "xent"), ("tanh", "xent"), ("tanh", "mse")])
def test_mlp_train_step_matches_reference(act, loss):
    cfg = M.MlpConfig(d_in=12, width=16, d_out=4, batch=6, act=act, loss=loss)
    specs = M.mlp_param_specs(cfg)
    params = [M.det_fill(s.shape, 50 + i, 0.2) for i, s in enumerate(specs)]
    ms = [jnp.zeros(s.shape) for s in specs]
    lr_vec = jnp.full((len(specs),), 0.05, jnp.float32)
    hp = jnp.array([1.5, 0.9, 0.01, 0, 0, 0, 0, 0], jnp.float32)
    x = M.det_fill((cfg.batch, cfg.d_in), 99, 1.0)
    y = M.det_tokens(cfg.batch, 1, cfg.d_out, 98).reshape(cfg.batch)

    train, _ = M.make_mlp_steps(cfg)
    out = jax.jit(train)(x, y, *params, *ms, lr_vec, hp)
    rloss, rp = _mlp_ref_step(cfg, specs, x, y, params, ms, lr_vec, hp)
    np.testing.assert_allclose(out[0], rloss, rtol=1e-4, atol=1e-5)
    for a, e in zip(out[1 : 1 + len(specs)], rp):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-5)


def test_resmlp_learns():
    cfg = M.ResMlpConfig(d_in=12, width=16, n_block=2, d_out=4, batch=8)
    specs = M.resmlp_param_specs(cfg)
    params = [
        jnp.ones(s.shape) if s.init == "ones"
        else jnp.zeros(s.shape) if s.init == "zeros"
        else M.det_fill(s.shape, 60 + i, 0.2)
        for i, s in enumerate(specs)
    ]
    ms = [jnp.zeros(s.shape) for s in specs]
    lr_vec = jnp.full((len(specs),), 0.05, jnp.float32)
    hp = jnp.array([1.0, 0.9, 0.0, 0, 0, 0, 0, 0], jnp.float32)
    x = M.det_fill((cfg.batch, cfg.d_in), 1, 1.0)
    y = M.det_tokens(cfg.batch, 1, cfg.d_out, 2).reshape(cfg.batch)
    train, _ = M.make_resmlp_steps(cfg)
    train = jax.jit(train)
    losses = []
    for _ in range(6):
        out = train(x, y, *params, *ms, lr_vec, hp)
        losses.append(float(out[0]))
        params = list(out[1 : 1 + len(specs)])
        ms = list(out[1 + len(specs) :])
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# deterministic-fill golden stability (cross-language contract)
# ---------------------------------------------------------------------------


def test_splitmix64_known_values():
    # Anchors for the Rust implementation (rust/src/init/rng.rs tests use
    # the same constants).
    assert M.splitmix64(0) == 0xE220A8397B1DCDAF
    assert M.splitmix64(1) == 0x910A2DEC89025CC1
    assert M.splitmix64(0xDEADBEEF) == 0x4ADFB90F68C9EB9B


def test_det_fill_deterministic_and_scaled():
    a = M.det_fill((4, 8), 7, 0.02)
    b = M.det_fill((4, 8), 7, 0.02)
    np.testing.assert_array_equal(a, b)
    assert float(jnp.max(jnp.abs(a))) <= 0.02
    c = M.det_fill((4, 8), 8, 0.02)
    assert not np.allclose(a, c)


def test_det_tokens_in_range():
    t = M.det_tokens(4, 16, 11, 3)
    assert int(t.min()) >= 0 and int(t.max()) < 11
