//! The lint passes.  Each pass is a token-pattern scan over
//! [`SourceFile::code`] with path-based scoping; `run_all` applies
//! suppressions and returns the merged, sorted finding list.
//!
//! The six lints (contracts documented in DESIGN.md §11 and §12):
//!
//! | lint              | contract                                              |
//! |-------------------|-------------------------------------------------------|
//! | `nan-cmp`         | no `partial_cmp` / `f32::max`-style float compares on |
//! |                   | loss-like paths — `total_cmp`/`nan_last` only (PR-3)  |
//! | `atomic-write`    | durable state under `serve/`, `report/`, `ckpt/`, the |
//! |                   | runtime manifest goes through `fsio::write_atomic`    |
//! | `no-panic-serve`  | no `unwrap`/`expect`/slice-index in serve paths       |
//! |                   | reachable from untrusted bytes                        |
//! | `bus-only-output` | daemon output goes through the `EventSink` bus, not   |
//! |                   | raw `eprintln!`/`println!`                            |
//! | `mup-coverage`    | every `Role` variant maps through `abc_for`, and      |
//! |                   | `model/` only uses declared roles                     |
//! | `metric-names`    | metric registrations take static `mutransfer_`-prefixed |
//! |                   | snake_case names; record sites in serve/ and the      |
//! |                   | native runtime never build strings (PR-9, §12)        |
//!
//! Plus the meta-lint `suppression` (reason-less `mutlint: allow` —
//! cannot itself be suppressed).

use super::lexer::{Tok, TokKind};
use super::{Finding, SourceFile};
use std::collections::BTreeSet;

/// All lint names, for CLI help and the self-tests.
pub const LINTS: &[&str] = &[
    "nan-cmp",
    "atomic-write",
    "no-panic-serve",
    "bus-only-output",
    "mup-coverage",
    "metric-names",
    "suppression",
];

/// Run every pass over the loaded tree.  Findings come back sorted by
/// (file, line, lint); adjacent reasoned suppressions mark findings
/// `suppressed` rather than dropping them, so callers can report both
/// counts.
pub fn run_all(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for sf in files {
        file_passes(sf, &mut out);
    }
    mup_coverage(files, &mut out);
    out.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    out
}

fn file_passes(sf: &SourceFile, out: &mut Vec<Finding>) {
    // The suppression meta-lint applies everywhere, test code included: a
    // reason-less allow is a broken contract no matter where it sits.
    for &line in sf.bad_suppression_lines() {
        out.push(Finding {
            file: sf.rel.clone(),
            line,
            lint: "suppression",
            msg: "mutlint: allow(..) without a reason string suppresses nothing; \
                  write allow(<lint>, \"<why>\")"
                .into(),
            suppressed: false,
        });
    }
    if sf.whole_exempt {
        return;
    }
    nan_cmp(sf, out);
    atomic_write(sf, out);
    no_panic_serve(sf, out);
    bus_only_output(sf, out);
    metric_names(sf, out);
}

/// Emit one finding, honoring same-line / line-above suppressions.
fn emit(sf: &SourceFile, out: &mut Vec<Finding>, lint: &'static str, line: u32, msg: String) {
    out.push(Finding {
        file: sf.rel.clone(),
        line,
        lint,
        msg,
        suppressed: sf.is_suppressed(lint, line),
    });
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

/// `path :: name` — tokens `i`, `i+1`, `i+2`.
fn is_path(code: &[Tok], i: usize, head: &str, tail: &str) -> bool {
    is_ident(&code[i], head)
        && code.get(i + 1).is_some_and(|t| is_punct(t, "::"))
        && code.get(i + 2).is_some_and(|t| is_ident(t, tail))
}

// ---------------------------------------------------------------- nan-cmp

/// PR-3 contract: losses can be NaN (divergent trials), and ordering them
/// with `partial_cmp`/`f32::max` either panics or silently ranks a
/// diverged run best.  `stats/` and the native tensor kernels are the
/// whitelist — they operate on finite data by construction and own the
/// `total_cmp`/`nan_last` helpers everyone else must use.
fn nan_cmp(sf: &SourceFile, out: &mut Vec<Finding>) {
    let in_scope = sf.rel.starts_with("rust/src/")
        && !sf.rel.starts_with("rust/src/stats/")
        && !sf.rel.starts_with("rust/src/runtime/native/");
    if !in_scope {
        return;
    }
    let code = &sf.code;
    for (i, t) in code.iter().enumerate() {
        if sf.in_test(t.line) {
            continue;
        }
        if is_ident(t, "partial_cmp") {
            emit(sf, out, "nan-cmp", t.line,
                "partial_cmp is NaN-unsound on loss-like paths; use total_cmp or stats::nan_last"
                    .into());
        } else if is_path(code, i, "f32", "max") || is_path(code, i, "f32", "min")
            || is_path(code, i, "f64", "max") || is_path(code, i, "f64", "min")
        {
            emit(sf, out, "nan-cmp", t.line,
                format!("{}::{} drops NaN silently; use total_cmp-based ordering",
                    t.text, code[i + 2].text));
        }
    }
}

// ------------------------------------------------------------ atomic-write

/// PR-5 contract: anything a `kill -9` may interrupt mid-write must go
/// through `util::fsio::write_atomic` (tmp + rename + fsync).  Direct
/// `File::create` / `fs::write` / `OpenOptions` in the durable-state
/// directories can tear `state.json`, reports, checkpoints, or the
/// runtime manifest.
fn atomic_write(sf: &SourceFile, out: &mut Vec<Finding>) {
    let in_scope = sf.rel.starts_with("rust/src/serve/")
        || sf.rel.starts_with("rust/src/report/")
        || sf.rel.starts_with("rust/src/ckpt/")
        || sf.rel == "rust/src/runtime/manifest.rs";
    if !in_scope {
        return;
    }
    let code = &sf.code;
    for (i, t) in code.iter().enumerate() {
        if sf.in_test(t.line) {
            continue;
        }
        let hit = if is_path(code, i, "File", "create") {
            Some("File::create")
        } else if is_path(code, i, "fs", "write") {
            Some("fs::write")
        } else if is_ident(t, "OpenOptions") {
            Some("OpenOptions")
        } else {
            None
        };
        if let Some(api) = hit {
            emit(sf, out, "atomic-write", t.line,
                format!("{api} in a durable-state path can tear under kill -9; \
                         use util::fsio::write_atomic"));
        }
    }
}

// ---------------------------------------------------------- no-panic-serve

/// Keywords that legitimately precede `[` without forming an index
/// expression (`let [a, b] = …`, `return [x]`, `in [..]`, …).
const NON_INDEX_KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "do", "dyn", "else",
    "enum", "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod",
    "move", "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type",
    "union", "unsafe", "use", "where", "while", "yield",
];

/// The serve daemon handles untrusted bytes; a panic in a request path
/// kills the worker and (pre-PR-6) could poison shared state.  Production
/// serve code returns typed errors — no `unwrap()`, no `expect()`, no
/// panicking slice-index.
fn no_panic_serve(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !sf.rel.starts_with("rust/src/serve/") {
        return;
    }
    let code = &sf.code;
    for (i, t) in code.iter().enumerate() {
        if sf.in_test(t.line) {
            continue;
        }
        // `.unwrap(` / `.expect(` — method calls only, so idents named
        // unwrap_or_else etc. never match (distinct ident tokens).
        if (is_ident(t, "unwrap") || is_ident(t, "expect"))
            && i > 0
            && is_punct(&code[i - 1], ".")
            && code.get(i + 1).is_some_and(|n| is_punct(n, "("))
        {
            emit(sf, out, "no-panic-serve", t.line,
                format!(".{}() can panic on untrusted input; return a typed error", t.text));
        }
        // Index expression: `expr[` where expr ends in a non-keyword
        // ident, `)`, or `]`.  Type positions (`buf: [u8; N]`), macros
        // (`vec![`), attributes (`#[`), and slices (`&[`) all have punct
        // or keyword predecessors and never match.
        if is_punct(t, "[") && i > 0 {
            let p = &code[i - 1];
            let indexes = match p.kind {
                TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&p.text.as_str()),
                TokKind::Punct => p.text == ")" || p.text == "]",
                _ => false,
            };
            if indexes {
                emit(sf, out, "no-panic-serve", t.line,
                    "slice indexing can panic on untrusted input; use .get()".into());
            }
        }
    }
}

// --------------------------------------------------------- bus-only-output

/// PR-5 contract: the daemon's observable output is the typed event bus;
/// `StderrSink` is the one component that turns events back into stderr
/// lines.  Raw print macros anywhere else bypass replay, SSE streaming,
/// and quiet mode.  CLI `main`, `rust/src/bin/`, and the sink itself are
/// structurally exempt.
fn bus_only_output(sf: &SourceFile, out: &mut Vec<Finding>) {
    let in_scope = sf.rel.starts_with("rust/src/")
        && sf.rel != "rust/src/main.rs"
        && !sf.rel.starts_with("rust/src/bin/")
        && sf.rel != "rust/src/serve/events.rs";
    if !in_scope {
        return;
    }
    let code = &sf.code;
    for (i, t) in code.iter().enumerate() {
        if sf.in_test(t.line) {
            continue;
        }
        if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "println" | "eprintln" | "print" | "eprint")
            && code.get(i + 1).is_some_and(|n| is_punct(n, "!"))
        {
            emit(sf, out, "bus-only-output", t.line,
                format!("{}! bypasses the event bus; emit an Event via an EventSink", t.text));
        }
    }
}

// ----------------------------------------------------------- metric-names

/// Record sites that are allowed to appear between a `metrics::` head and
/// the end of its statement must not allocate: per-request `format!` /
/// `to_string` at a hot counter defeats the "observability is nearly
/// free" contract (DESIGN.md §12).
const METRIC_HOT_SCOPES: &[&str] = &["rust/src/serve/", "rust/src/runtime/native/"];

/// PR-9 contract, two halves.  (1) Everywhere: `Counter::new` /
/// `Gauge::new` / `Histogram::new` take a *static string literal* name
/// that is `mutransfer_`-prefixed snake_case — the Prometheus page is
/// greppable and collision-free by construction.  (2) In the serve and
/// native-runtime hot paths: no string building (`format!`, `to_string`,
/// `to_owned`, `String::from`) inside a `metrics::…` statement — record
/// sites stay allocation-free.
fn metric_names(sf: &SourceFile, out: &mut Vec<Finding>) {
    if !sf.rel.starts_with("rust/src/") {
        return;
    }
    let hot = METRIC_HOT_SCOPES.iter().any(|p| sf.rel.starts_with(p));
    let code = &sf.code;
    for (i, t) in code.iter().enumerate() {
        if sf.in_test(t.line) {
            continue;
        }
        // (1) registration sites, project-wide
        if matches!(t.text.as_str(), "Counter" | "Gauge" | "Histogram")
            && t.kind == TokKind::Ident
            && code.get(i + 1).is_some_and(|n| is_punct(n, "::"))
            && code.get(i + 2).is_some_and(|n| is_ident(n, "new"))
            && code.get(i + 3).is_some_and(|n| is_punct(n, "("))
        {
            match code.get(i + 4) {
                Some(arg) if arg.kind == TokKind::Str => {
                    let name = arg.text.trim_matches('"');
                    let ok = name.starts_with("mutransfer_")
                        && name.bytes().all(|b| matches!(b, b'a'..=b'z' | b'0'..=b'9' | b'_'));
                    if !ok {
                        emit(sf, out, "metric-names", arg.line,
                            format!("metric name {} must be mutransfer_-prefixed snake_case \
                                     ([a-z0-9_] only)", arg.text));
                    }
                }
                _ => emit(sf, out, "metric-names", t.line,
                    format!("{}::new needs a static string-literal name; a computed \
                             name defeats the static registry", t.text)),
            }
        }
        // (2) allocation-free record sites in the hot scopes
        if hot && is_ident(t, "metrics") && code.get(i + 1).is_some_and(|n| is_punct(n, "::")) {
            let mut depth = 0i32;
            for j in (i + 2)..code.len().min(i + 202) {
                let tj = &code[j];
                if tj.kind == TokKind::Punct {
                    match tj.text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth -= 1,
                        "," | ";" if depth == 0 => break,
                        _ => {}
                    }
                    if depth < 0 {
                        break;
                    }
                    continue;
                }
                let alloc = (is_ident(tj, "format")
                        && code.get(j + 1).is_some_and(|n| is_punct(n, "!")))
                    || is_ident(tj, "to_string")
                    || is_ident(tj, "to_owned")
                    || is_path(code, j, "String", "from");
                if alloc {
                    emit(sf, out, "metric-names", tj.line,
                        format!("{} inside a metrics record statement allocates per \
                                 event; record with static names and integer/float \
                                 values only", tj.text));
                }
            }
        }
    }
}

// ----------------------------------------------------------- mup-coverage

/// The μTransfer guarantee is only as strong as its weakest tensor: one
/// role left out of `abc_for` and that layer trains in SP, which is
/// exactly the silent-transfer-failure mode of Lingle 2024.  Project-wide
/// pass: every `Role` variant declared in `mup/rules.rs` must be matched
/// inside `abc_for`, and `model/` may only name declared variants.
fn mup_coverage(files: &[SourceFile], out: &mut Vec<Finding>) {
    let Some(rules) = files.iter().find(|f| f.rel == "rust/src/mup/rules.rs") else {
        // Nothing to check against (e.g. a partial fixture tree with no
        // model/ either); only complain if model code exists.
        if let Some(m) = files.iter().find(|f| f.rel.starts_with("rust/src/model/")) {
            out.push(Finding {
                file: m.rel.clone(),
                line: 1,
                lint: "mup-coverage",
                msg: "model/ present but rust/src/mup/rules.rs not found; \
                      cannot verify abc coverage"
                    .into(),
                suppressed: false,
            });
        }
        return;
    };
    let variants = role_variants(&rules.code);
    let handled = abc_for_roles(&rules.code);
    for (name, line) in &variants {
        if !handled.contains(name) {
            emit(rules, out, "mup-coverage", *line,
                format!("Role::{name} is never mapped by abc_for; \
                         tensors with this role would silently train in SP"));
        }
    }
    let declared: BTreeSet<&str> = variants.iter().map(|(n, _)| n.as_str()).collect();
    for sf in files.iter().filter(|f| f.rel.starts_with("rust/src/model/")) {
        let code = &sf.code;
        for i in 0..code.len() {
            if is_ident(&code[i], "Role")
                && code.get(i + 1).is_some_and(|t| is_punct(t, "::"))
            {
                if let Some(v) = code.get(i + 2) {
                    if v.kind == TokKind::Ident && !declared.contains(v.text.as_str()) {
                        emit(sf, out, "mup-coverage", v.line,
                            format!("Role::{} is not declared in mup::rules::Role", v.text));
                    }
                }
            }
        }
    }
}

/// Unit variants of `pub enum Role { … }`: idents at brace depth 1
/// immediately followed by `,` or the closing `}`.
fn role_variants(code: &[Tok]) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        if is_ident(&code[i], "enum")
            && code.get(i + 1).is_some_and(|t| is_ident(t, "Role"))
            && code.get(i + 2).is_some_and(|t| is_punct(t, "{"))
        {
            let mut j = i + 3;
            let mut depth = 1usize;
            while j < code.len() && depth > 0 {
                match code[j].text.as_str() {
                    "{" | "(" | "[" => depth += 1,
                    "}" | ")" | "]" => depth -= 1,
                    _ => {
                        if depth == 1
                            && code[j].kind == TokKind::Ident
                            && code.get(j + 1).is_some_and(|n| {
                                is_punct(n, ",") || is_punct(n, "}")
                            })
                        {
                            out.push((code[j].text.clone(), code[j].line));
                        }
                    }
                }
                j += 1;
            }
            return out;
        }
        i += 1;
    }
    out
}

/// `Role::X` idents inside the body of `fn abc_for`.
fn abc_for_roles(code: &[Tok]) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut i = 0usize;
    while i < code.len() {
        if is_ident(&code[i], "fn") && code.get(i + 1).is_some_and(|t| is_ident(t, "abc_for")) {
            // scan to the body's opening brace, then brace-match
            let mut j = i + 2;
            while j < code.len() && !is_punct(&code[j], "{") {
                j += 1;
            }
            let mut depth = 0usize;
            while j < code.len() {
                match code[j].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            return out;
                        }
                    }
                    _ => {
                        if is_ident(&code[j], "Role")
                            && code.get(j + 1).is_some_and(|t| is_punct(t, "::"))
                        {
                            if let Some(v) = code.get(j + 2) {
                                if v.kind == TokKind::Ident {
                                    out.insert(v.text.clone());
                                }
                            }
                        }
                    }
                }
                j += 1;
            }
            return out;
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let sf = SourceFile::parse(rel.into(), src);
        let mut out = Vec::new();
        file_passes(&sf, &mut out);
        out
    }

    fn unsuppressed(rel: &str, src: &str) -> Vec<Finding> {
        findings(rel, src).into_iter().filter(|f| !f.suppressed).collect()
    }

    #[test]
    fn nan_cmp_flags_and_whitelists() {
        let bad = "fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
        assert_eq!(unsuppressed("rust/src/train/mod.rs", bad).len(), 1);
        assert_eq!(unsuppressed("rust/src/stats/mod.rs", bad).len(), 0);
        assert_eq!(unsuppressed("rust/src/runtime/native/tensor.rs", bad).len(), 0);
        let path_form = "fn f(a: f32, b: f32) -> f32 { f32::max(a, b) }";
        assert_eq!(unsuppressed("rust/src/train/mod.rs", path_form).len(), 1);
        // method .max is integer-safe and never flagged; strings/comments invisible
        let ok = "fn f(a: usize) { a.max(3); } // partial_cmp\nconst S: &str = \"partial_cmp\";";
        assert_eq!(unsuppressed("rust/src/train/mod.rs", ok).len(), 0);
        // test regions are exempt
        let in_test = "#[cfg(test)]\nmod tests { fn f(a: f64, b: f64) { a.partial_cmp(&b); } }";
        assert_eq!(unsuppressed("rust/src/train/mod.rs", in_test).len(), 0);
    }

    #[test]
    fn atomic_write_scoped_to_durable_dirs() {
        let bad = "fn f() { std::fs::write(\"x\", b\"y\").ok(); File::create(\"x\").ok(); }";
        assert_eq!(unsuppressed("rust/src/serve/daemon.rs", bad).len(), 2);
        assert_eq!(unsuppressed("rust/src/runtime/manifest.rs", bad).len(), 2);
        // out of scope: util owns write_atomic itself
        assert_eq!(unsuppressed("rust/src/util/fsio.rs", bad).len(), 0);
        let oo = "fn f() { let o = OpenOptions::new(); }";
        assert_eq!(unsuppressed("rust/src/ckpt/format.rs", oo).len(), 1);
    }

    #[test]
    fn no_panic_serve_unwrap_and_index() {
        let bad = "fn f(v: &[u8]) { v.first().unwrap(); let x = v[0]; }";
        assert_eq!(unsuppressed("rust/src/serve/http.rs", bad).len(), 2);
        // other modules may unwrap
        assert_eq!(unsuppressed("rust/src/train/mod.rs", bad).len(), 0);
        // non-index brackets: patterns, types, macros, attributes, slices
        let ok = "fn f(v: Vec<u8>) -> [u8; 2] { let [a, b] = [v.len() as u8, 0]; \
                  let _s: &[u8] = &v; let _m = vec![1]; [a, b] }";
        assert_eq!(unsuppressed("rust/src/serve/http.rs", ok).len(), 0);
        // unwrap_or_else is a distinct ident and never matches
        let ok2 = "fn f(r: Result<u8, u8>) -> u8 { r.unwrap_or_else(|e| e) }";
        assert_eq!(unsuppressed("rust/src/serve/http.rs", ok2).len(), 0);
    }

    #[test]
    fn bus_only_output_whitelists() {
        let bad = "fn f() { eprintln!(\"x\"); }";
        assert_eq!(unsuppressed("rust/src/serve/daemon.rs", bad).len(), 1);
        assert_eq!(unsuppressed("rust/src/main.rs", bad).len(), 0);
        assert_eq!(unsuppressed("rust/src/bin/mutlint.rs", bad).len(), 0);
        assert_eq!(unsuppressed("rust/src/serve/events.rs", bad).len(), 0);
        assert_eq!(unsuppressed("rust/tests/serve_e2e.rs", bad).len(), 0);
    }

    #[test]
    fn metric_names_prefix_literal_and_hot_path_alloc() {
        // bad prefix / bad charset / computed name — flagged anywhere in src
        let bad_prefix = "static C: Counter = Counter::new(\"requests_total\", \"h\");";
        assert_eq!(unsuppressed("rust/src/obs/metrics.rs", bad_prefix).len(), 1);
        let bad_chars = "static C: Gauge = Gauge::new(\"mutransfer_Conns\", \"h\");";
        assert_eq!(unsuppressed("rust/src/obs/metrics.rs", bad_chars).len(), 1);
        let dynamic = "fn f(n: &str) { let h = Histogram::new(name_for(n), \"h\"); }";
        assert_eq!(unsuppressed("rust/src/obs/metrics.rs", dynamic).len(), 1);
        let ok = "static C: Counter = Counter::new(\"mutransfer_http_sheds_total\", \"h\");";
        assert_eq!(unsuppressed("rust/src/obs/metrics.rs", ok).len(), 0);
        // tests may register scratch metrics under any name
        let in_test = "#[cfg(test)]\nmod tests { \
                       static C: Counter = Counter::new(\"scratch\", \"h\"); }";
        assert_eq!(unsuppressed("rust/src/obs/metrics.rs", in_test).len(), 0);

        // record sites in serve/ and runtime/native/ must not build strings
        let alloc = "fn f(r: &str) { metrics::route_by_name(format!(\"{r}\")).hits(); }";
        assert_eq!(unsuppressed("rust/src/serve/api.rs", alloc).len(), 1);
        assert_eq!(unsuppressed("rust/src/runtime/native/tensor.rs", alloc).len(), 1);
        // same code outside the hot scopes is fine
        assert_eq!(unsuppressed("rust/src/train/mod.rs", alloc).len(), 0);
        // allocation in the same fn but a *different* statement is fine
        let ok2 = "fn f(n: u64) { metrics::HTTP_SHEDS.add(n); let s = n.to_string(); }";
        assert_eq!(unsuppressed("rust/src/serve/api.rs", ok2).len(), 0);
        // depth tracking: a comma inside the call does not end the scan
        let alloc2 = "fn f() { metrics::X.set(g(1, 2.to_string().len() as i64)); }";
        assert_eq!(unsuppressed("rust/src/serve/api.rs", alloc2).len(), 1);
    }

    #[test]
    fn suppression_with_reason_marks_finding() {
        let src = "// mutlint: allow(nan-cmp, \"ranks over finite ints\")\n\
                   fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
        let all = findings("rust/src/train/mod.rs", src);
        assert_eq!(all.len(), 1);
        assert!(all[0].suppressed);
        // reason-less: finding stays live AND the allow itself is flagged
        let src2 = "// mutlint: allow(nan-cmp)\nfn f(a: f64, b: f64) { a.partial_cmp(&b); }";
        let all2 = findings("rust/src/train/mod.rs", src2);
        let lints: Vec<_> = all2.iter().map(|f| (f.lint, f.suppressed)).collect();
        assert!(lints.contains(&("suppression", false)));
        assert!(lints.contains(&("nan-cmp", false)));
    }

    #[test]
    fn mup_coverage_missing_variant_and_undeclared_use() {
        let rules = SourceFile::parse(
            "rust/src/mup/rules.rs".into(),
            "pub enum Role { Input, Hidden, Frozen }\n\
             impl P { pub fn abc_for(&self) { match r { \
             Role::Input => 1, Role::Hidden => 2 }; } }",
        );
        let model = SourceFile::parse(
            "rust/src/model/mod.rs".into(),
            "fn build() { reg(Role::Input); reg(Role::Ghost); }",
        );
        let mut out = Vec::new();
        mup_coverage(&[rules, model], &mut out);
        let msgs: Vec<_> = out.iter().map(|f| f.msg.as_str()).collect();
        assert_eq!(out.len(), 2, "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("Role::Frozen")));
        assert!(msgs.iter().any(|m| m.contains("Role::Ghost")));
    }

    #[test]
    fn mup_coverage_clean_when_all_variants_handled() {
        let rules = SourceFile::parse(
            "rust/src/mup/rules.rs".into(),
            "pub enum Role { Input, Output }\n\
             pub fn abc_for() { match r { Role::Input | Role::Output => 1 }; }",
        );
        let mut out = Vec::new();
        mup_coverage(&[rules], &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}
