//! A small hand-rolled Rust lexer — just enough syntax awareness for
//! `mutlint`'s token-pattern passes (DESIGN.md §11).
//!
//! The lints match identifier/punct *token* sequences, so the lexer's one
//! job is to never misclassify text: a `partial_cmp` inside a string
//! literal, a `File::create` inside a doc comment, or a lint name inside
//! this very module must not trip a pass.  That requires getting the
//! genuinely tricky corners of Rust's lexical grammar right:
//!
//! * raw strings `r"…"` / `r#"…"#` / `r##"…"##` (terminator = quote plus
//!   the opening hash count, quotes inside are data);
//! * byte and raw-byte strings `b"…"`, `br#"…"#`, byte chars `b'x'`;
//! * **nested** block comments `/* /* */ */` (Rust block comments nest,
//!   unlike C);
//! * char literal vs lifetime disambiguation: `'a'` is a char, `'a` is a
//!   lifetime, `'\n'` escapes, `b'\''` is a byte char;
//! * raw identifiers `r#type`.
//!
//! Everything else (numbers, multi-char operators) is lexed loosely: the
//! passes never interpret numeric values, and the only compound operator
//! they match is `::`, which is fused into one token.

/// Token classification.  String-like kinds are kept distinct so the
/// golden tests can pin the tricky-corpus behavior precisely; the passes
/// themselves mostly care about `Ident` vs everything-else.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Char,
    ByteChar,
    Str,
    ByteStr,
    RawStr,
    RawByteStr,
    Num,
    Punct,
    LineComment,
    BlockComment,
}

impl TokKind {
    pub fn is_comment(self) -> bool {
        matches!(self, TokKind::LineComment | TokKind::BlockComment)
    }
}

/// One lexed token: kind, the exact source slice, and the 1-based line of
/// its first character (findings are reported as `file:line`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Lexer<'a> {
    src: &'a str,
    /// (byte offset, char) pairs — indexed by char position
    cs: Vec<(usize, char)>,
    i: usize,
    line: u32,
    toks: Vec<Tok>,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Lexer<'a> {
        Lexer { src, cs: src.char_indices().collect(), i: 0, line: 1, toks: Vec::new() }
    }

    /// Char at position `i` (`'\0'` past the end — NUL never appears in
    /// source we lint, so it doubles as an EOF sentinel).
    fn at(&self, i: usize) -> char {
        self.cs.get(i).map(|&(_, c)| c).unwrap_or('\0')
    }

    /// Byte offset of char position `i`.
    fn off(&self, i: usize) -> usize {
        self.cs.get(i).map(|&(o, _)| o).unwrap_or(self.src.len())
    }

    /// Advance one char, counting newlines.
    fn bump(&mut self) {
        if self.at(self.i) == '\n' {
            self.line += 1;
        }
        self.i += 1;
    }

    fn push(&mut self, kind: TokKind, start: usize, line: u32) {
        let text = self.src[self.off(start)..self.off(self.i)].to_string();
        self.toks.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Vec<Tok> {
        while self.i < self.cs.len() {
            let start = self.i;
            let line = self.line;
            let c = self.at(self.i);
            match c {
                _ if c.is_whitespace() => self.bump(),
                '/' if self.at(self.i + 1) == '/' => {
                    while self.i < self.cs.len() && self.at(self.i) != '\n' {
                        self.i += 1;
                    }
                    self.push(TokKind::LineComment, start, line);
                }
                '/' if self.at(self.i + 1) == '*' => {
                    self.block_comment();
                    self.push(TokKind::BlockComment, start, line);
                }
                '\'' => self.char_or_lifetime(),
                '"' => {
                    self.string_body();
                    self.push(TokKind::Str, start, line);
                }
                'r' | 'b' => self.r_or_b(),
                _ if is_ident_start(c) => {
                    self.ident_body();
                    self.push(TokKind::Ident, start, line);
                }
                _ if c.is_ascii_digit() => {
                    self.num_body();
                    self.push(TokKind::Num, start, line);
                }
                ':' if self.at(self.i + 1) == ':' => {
                    self.i += 2;
                    self.push(TokKind::Punct, start, line);
                }
                _ => {
                    self.bump();
                    self.push(TokKind::Punct, start, line);
                }
            }
        }
        self.toks
    }

    /// Nested block comment; `self.i` is on the opening `/`.
    fn block_comment(&mut self) {
        let mut depth = 0usize;
        while self.i < self.cs.len() {
            if self.at(self.i) == '/' && self.at(self.i + 1) == '*' {
                depth += 1;
                self.i += 2;
            } else if self.at(self.i) == '*' && self.at(self.i + 1) == '/' {
                depth -= 1;
                self.i += 2;
                if depth == 0 {
                    return;
                }
            } else {
                self.bump();
            }
        }
    }

    /// `self.i` is on a `'`: char literal, lifetime, or (degenerate) a
    /// lone-quote punct.
    fn char_or_lifetime(&mut self) {
        let start = self.i;
        let line = self.line;
        if self.at(self.i + 1) == '\\' {
            // escaped char literal: ' \ … '
            self.i += 2; // past quote and backslash
            self.i += 1; // the escaped char itself (n, ', \, u, …)
            // \u{…} payload, then scan to the closing quote
            while self.i < self.cs.len() && self.at(self.i) != '\'' {
                self.bump();
            }
            self.i += 1; // closing quote
            self.push(TokKind::Char, start, line);
        } else if self.at(self.i + 2) == '\'' && self.at(self.i + 1) != '\'' {
            // exactly one char between quotes: 'a', '1', 'λ'
            self.i += 3;
            self.push(TokKind::Char, start, line);
        } else if is_ident_start(self.at(self.i + 1)) {
            // 'a, 'static, 'label — a lifetime (or loop label)
            self.i += 2;
            while is_ident_cont(self.at(self.i)) {
                self.i += 1;
            }
            self.push(TokKind::Lifetime, start, line);
        } else {
            self.i += 1;
            self.push(TokKind::Punct, start, line);
        }
    }

    /// Body of a non-raw string; `self.i` on the opening quote.
    fn string_body(&mut self) {
        self.i += 1;
        while self.i < self.cs.len() {
            match self.at(self.i) {
                '\\' => self.i += 2,
                '"' => {
                    self.i += 1;
                    return;
                }
                _ => self.bump(),
            }
        }
    }

    /// Raw-string body starting at the first `#`-or-quote after the
    /// `r`/`br` introducer; returns false if this is not a raw string
    /// (caller falls back to ident lexing).
    fn raw_string_body(&mut self, intro: usize) -> bool {
        let mut hashes = 0usize;
        while self.at(intro + hashes) == '#' {
            hashes += 1;
        }
        if self.at(intro + hashes) != '"' {
            return false;
        }
        self.i = intro + hashes + 1;
        while self.i < self.cs.len() {
            if self.at(self.i) == '"' {
                let mut k = 0usize;
                while k < hashes && self.at(self.i + 1 + k) == '#' {
                    k += 1;
                }
                if k == hashes {
                    self.i += 1 + hashes;
                    return true;
                }
            }
            self.bump();
        }
        true
    }

    /// Disambiguate tokens starting with `r` or `b`: raw strings, byte
    /// strings, byte chars, raw identifiers, or plain identifiers.
    fn r_or_b(&mut self) {
        let start = self.i;
        let line = self.line;
        let c = self.at(self.i);
        if c == 'b' {
            if self.at(self.i + 1) == '\'' {
                // byte char b'x' / b'\n'
                self.i += 1;
                self.char_or_lifetime();
                // re-tag what char_or_lifetime pushed
                let text = self.src[self.off(start)..self.off(self.i)].to_string();
                if let Some(t) = self.toks.last_mut() {
                    t.kind = TokKind::ByteChar;
                    t.text = text;
                    t.line = line;
                }
                return;
            }
            if self.at(self.i + 1) == '"' {
                self.i += 1;
                self.string_body();
                self.push(TokKind::ByteStr, start, line);
                return;
            }
            if self.at(self.i + 1) == 'r' && self.raw_string_body(start + 2) {
                self.push(TokKind::RawByteStr, start, line);
                return;
            }
        } else {
            // c == 'r'
            if self.at(self.i + 1) == '#' && is_ident_start(self.at(self.i + 2)) {
                // raw identifier r#type
                self.i += 2;
                self.ident_body();
                self.push(TokKind::Ident, start, line);
                return;
            }
            if self.raw_string_body(start + 1) {
                self.push(TokKind::RawStr, start, line);
                return;
            }
        }
        self.ident_body();
        self.push(TokKind::Ident, start, line);
    }

    fn ident_body(&mut self) {
        while is_ident_cont(self.at(self.i)) {
            self.i += 1;
        }
    }

    /// Loose number: digits/letters/underscores, plus `.` only when a
    /// digit follows (so `0..n` and `1.max(2)` terminate correctly).
    fn num_body(&mut self) {
        while self.i < self.cs.len() {
            let c = self.at(self.i);
            if is_ident_cont(c) {
                self.i += 1;
            } else if c == '.' && self.at(self.i + 1).is_ascii_digit() {
                self.i += 1;
            } else {
                return;
            }
        }
    }
}

/// Lex a whole source file.  Comments are kept as tokens (suppressions
/// live in them); passes that only want code filter them out.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer::new(src).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds_texts(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn golden_raw_strings() {
        use TokKind::*;
        // quotes and hash-short terminators inside raw strings are data
        let got = kinds_texts(r###"let s = r#"quote " and "# done; x"###);
        assert_eq!(
            got,
            vec![
                (Ident, "let".into()),
                (Ident, "s".into()),
                (Punct, "=".into()),
                (RawStr, r##"r#"quote " and "#"##.into()),
                (Ident, "done".into()),
                (Punct, ";".into()),
                (Ident, "x".into()),
            ]
        );
        // r"" with no hashes, and a ## terminator ignoring a lone "#
        let got = kinds_texts("r\"a\\\" + r##\"b\"# c\"##");
        assert_eq!(got[0], (RawStr, "r\"a\\\"".into())); // backslash is data in raw strings
        assert_eq!(got[1], (Punct, "+".into()));
        assert_eq!(got[2], (RawStr, "r##\"b\"# c\"##".into()));
    }

    #[test]
    fn golden_nested_block_comments() {
        use TokKind::*;
        let got = kinds_texts("a /* outer /* inner */ still comment */ b");
        assert_eq!(
            got,
            vec![
                (Ident, "a".into()),
                (BlockComment, "/* outer /* inner */ still comment */".into()),
                (Ident, "b".into()),
            ]
        );
        // the classic trap: an unwrap() inside a comment must not be code
        let got = lex("/* .unwrap() */ safe");
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].text, "safe");
    }

    #[test]
    fn golden_char_vs_lifetime() {
        use TokKind::*;
        let got = kinds_texts("fn f<'a>(x: &'a str) -> char { 'a' }");
        let lifetimes: Vec<_> = got.iter().filter(|(k, _)| *k == Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(lifetimes.iter().all(|(_, t)| t == "'a"));
        let chars: Vec<_> = got.iter().filter(|(k, _)| *k == Char).collect();
        assert_eq!(chars, vec![&(Char, "'a'".to_string())]);
        // escapes, unicode payloads, quote-escape, static lifetime
        let got = kinds_texts(r"'\n' '\u{1F600}' '\'' 'static 'λ'");
        assert_eq!(
            got,
            vec![
                (Char, r"'\n'".into()),
                (Char, r"'\u{1F600}'".into()),
                (Char, r"'\''".into()),
                (Lifetime, "'static".into()),
                (Char, "'λ'".into()),
            ]
        );
    }

    #[test]
    fn golden_byte_strings_and_chars() {
        use TokKind::*;
        let got = kinds_texts(r##"b"bytes" br#"raw bytes "q" "# b'x' b'\'' plain"##);
        assert_eq!(
            got,
            vec![
                (ByteStr, r#"b"bytes""#.into()),
                (RawByteStr, r##"br#"raw bytes "q" "#"##.into()),
                (ByteChar, "b'x'".into()),
                (ByteChar, r"b'\''".into()),
                (Ident, "plain".into()),
            ]
        );
    }

    #[test]
    fn golden_raw_idents_and_lookalikes() {
        use TokKind::*;
        // r#type is an ident; rate/break_even start with r/b but are plain
        let got = kinds_texts("r#type rate break_even b r");
        assert_eq!(
            got,
            vec![
                (Ident, "r#type".into()),
                (Ident, "rate".into()),
                (Ident, "break_even".into()),
                (Ident, "b".into()),
                (Ident, "r".into()),
            ]
        );
    }

    #[test]
    fn golden_numbers_and_ranges() {
        use TokKind::*;
        let got = kinds_texts("0..n 1.0f64.max(x) 0x1F 1e-5 1_000");
        assert_eq!(got[0], (Num, "0".into()));
        assert_eq!(got[1], (Punct, ".".into()));
        assert_eq!(got[2], (Punct, ".".into()));
        assert_eq!(got[3], (Ident, "n".into()));
        assert_eq!(got[4], (Num, "1.0f64".into()));
        assert_eq!(got[5], (Punct, ".".into()));
        assert_eq!(got[6], (Ident, "max".into()));
        assert!(got.contains(&(Num, "0x1F".into())));
        assert!(got.contains(&(Num, "1e".into()))); // loose: exponent sign splits
        assert!(got.contains(&(Num, "1_000".into())));
    }

    #[test]
    fn golden_paths_and_strings_hide_idents() {
        use TokKind::*;
        let got = kinds_texts(r#"File::create "File::create" // File::create"#);
        assert_eq!(got[0], (Ident, "File".into()));
        assert_eq!(got[1], (Punct, "::".into()));
        assert_eq!(got[2], (Ident, "create".into()));
        assert_eq!(got[3].0, Str);
        assert_eq!(got[4].0, LineComment);
        assert_eq!(got.len(), 5);
    }

    #[test]
    fn line_numbers_across_multiline_tokens() {
        let src = "a\n\"two\nline\"\n/* c\nc */\nr#\"raw\nraw\"#\nz";
        let toks = lex(src);
        let find = |text: &str| toks.iter().find(|t| t.text.contains(text)).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("two"), 2); // string starts on line 2
        assert_eq!(find("/* c"), 4);
        assert_eq!(find("raw"), 6);
        assert_eq!(toks.last().unwrap().line, 8);
    }

    #[test]
    fn unterminated_inputs_do_not_hang_or_panic() {
        // torn files must lex to *something*; mutlint runs pre-compile
        for src in ["\"open", "/* open", "r#\"open", "'", "b'", "r#"] {
            let _ = lex(src);
        }
    }
}
