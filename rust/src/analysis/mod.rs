//! `mutlint` — project-invariant static analysis (DESIGN.md §11).
//!
//! PRs 3–7 accumulated correctness contracts that lived only in prose:
//! NaN-worst ordering via `total_cmp`, tmp-then-rename crash consistency,
//! event-bus-only output from the daemon, no-panic serve paths, and the
//! μP guarantee that every registered tensor maps to an abc-triple.  This
//! module machine-checks them on every push: a hand-rolled lexer
//! ([`lexer`]) feeds token-pattern passes ([`passes`]) that are
//! deny-by-default and suppressable only with an in-source reason:
//!
//! ```text
//! // mutlint: allow(<lint>, "<why this site is exempt>")
//! ```
//!
//! A suppression covers findings on its own line or the line directly
//! below.  A suppression *without* a reason string does not suppress
//! anything and is itself reported (lint `suppression`, which cannot be
//! suppressed) — the reason is the contract.
//!
//! Run it as `cargo run --release --bin mutlint` (CI does, exit 1 on any
//! unsuppressed finding; `MUTLINT_NO_ASSERT=1` downgrades to report-only,
//! matching the bench-gate convention).

pub mod lexer;
pub mod passes;

use lexer::{lex, Tok, TokKind};
use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint hit.  `suppressed` is true when an adjacent reasoned
/// `mutlint: allow` covers it — such findings are counted but do not fail
/// the build.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Root-relative path with `/` separators (stable across platforms).
    pub file: String,
    pub line: u32,
    pub lint: &'static str,
    pub msg: String,
    pub suppressed: bool,
}

impl Finding {
    pub fn render(&self) -> String {
        let tag = if self.suppressed { " (suppressed)" } else { "" };
        format!("{}:{}: {}: {}{}", self.file, self.line, self.lint, self.msg, tag)
    }
}

/// A lexed source file plus the line-level metadata passes need:
/// suppression comments and `#[cfg(test)]` regions.
pub struct SourceFile {
    /// Root-relative path with `/` separators — all pass scoping matches
    /// against this.
    pub rel: String,
    /// All tokens, comments included (suppressions live in comments).
    pub toks: Vec<Tok>,
    /// Code tokens only (comments stripped) — what the passes scan.
    pub code: Vec<Tok>,
    /// True for files that are test/bench/example code in their entirety
    /// (`rust/tests/`, `benches/`, `examples/`): lints with a
    /// production-code scope skip them wholesale.
    pub whole_exempt: bool,
    /// `(lint name, line of the allow comment)` for reasoned suppressions.
    suppressions: Vec<(String, u32)>,
    /// Lines of `mutlint: allow` comments missing a reason string.
    bad_suppressions: Vec<u32>,
    /// Inclusive line ranges of `#[cfg(test)]` / `#[test]` items.
    test_regions: Vec<(u32, u32)>,
}

impl SourceFile {
    pub fn parse(rel: String, src: &str) -> SourceFile {
        let toks = lex(src);
        let code: Vec<Tok> = toks.iter().filter(|t| !t.kind.is_comment()).cloned().collect();
        let mut suppressions = Vec::new();
        let mut bad_suppressions = Vec::new();
        for t in toks.iter().filter(|t| t.kind.is_comment()) {
            match parse_allow(&t.text) {
                Some((lint, true)) => suppressions.push((lint, t.line)),
                Some((_, false)) => bad_suppressions.push(t.line),
                None => {}
            }
        }
        let test_regions = find_test_regions(&code);
        let whole_exempt = rel.starts_with("rust/tests/")
            || rel.starts_with("benches/")
            || rel.starts_with("examples/");
        SourceFile { rel, toks, code, whole_exempt, suppressions, bad_suppressions, test_regions }
    }

    /// Is a finding of `lint` at `line` covered by a reasoned allow on the
    /// same line or the line above?
    pub fn is_suppressed(&self, lint: &str, line: u32) -> bool {
        self.suppressions
            .iter()
            .any(|(l, sl)| l == lint && (*sl == line || *sl + 1 == line))
    }

    /// Is `line` inside a `#[cfg(test)]` module or `#[test]` item?
    pub fn in_test(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// Lines carrying a reason-less `mutlint: allow` comment.
    pub fn bad_suppression_lines(&self) -> &[u32] {
        &self.bad_suppressions
    }
}

/// Parse a `mutlint: allow(<lint>, "<reason>")` marker out of a comment.
/// Returns `(lint, has_nonempty_reason)`, or `None` when the comment
/// carries no marker at all.
fn parse_allow(comment: &str) -> Option<(String, bool)> {
    let marker = comment.find("mutlint:")?;
    let rest = comment[marker + "mutlint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let name_end = rest.find([',', ')'])?;
    let lint = rest[..name_end].trim().to_string();
    if lint.is_empty() {
        return None;
    }
    // A valid reason is a non-empty double-quoted string after the comma.
    let tail = &rest[name_end..];
    let has_reason = tail.strip_prefix(',').is_some_and(|after| {
        let after = after.trim_start();
        match after.strip_prefix('"') {
            Some(inner) => inner.find('"').is_some_and(|close| close > 0),
            None => false,
        }
    });
    Some((lint, has_reason))
}

/// Locate `#[cfg(test)]` (and bare `#[test]`) attributed items and return
/// the inclusive line span from the attribute to the item's closing brace.
/// Brace matching runs over code tokens, so braces inside strings and
/// comments can't desynchronize it.
fn find_test_regions(code: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let is = |t: Option<&Tok>, k: TokKind, s: &str| t.is_some_and(|t| t.kind == k && t.text == s);
    let mut i = 0usize;
    while i < code.len() {
        let attr_test = is(code.get(i), TokKind::Punct, "#")
            && is(code.get(i + 1), TokKind::Punct, "[")
            && ((is(code.get(i + 2), TokKind::Ident, "cfg")
                && is(code.get(i + 3), TokKind::Punct, "(")
                && is(code.get(i + 4), TokKind::Ident, "test")
                && is(code.get(i + 5), TokKind::Punct, ")")
                && is(code.get(i + 6), TokKind::Punct, "]"))
                || (is(code.get(i + 2), TokKind::Ident, "test")
                    && is(code.get(i + 3), TokKind::Punct, "]")));
        if !attr_test {
            i += 1;
            continue;
        }
        let start_line = code[i].line;
        // Scan to the item's opening brace, then match it.  A semicolon
        // first means a brace-less item (e.g. `#[cfg(test)] use …;`).
        let mut j = i + 1;
        while j < code.len() && code[j].text != "{" && code[j].text != ";" {
            j += 1;
        }
        if j >= code.len() || code[j].text == ";" {
            out.push((start_line, code.get(j).map_or(start_line, |t| t.line)));
            i = j + 1;
            continue;
        }
        let mut depth = 0usize;
        while j < code.len() {
            match code[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let end_line = code.get(j).map_or(u32::MAX, |t| t.line);
        out.push((start_line, end_line));
        i = j + 1;
    }
    out
}

/// Walk the lintable tree under `root`: `rust/src`, `rust/tests`,
/// `benches`, `examples`.  Lint *fixtures* (seeded-violation corpora under
/// `rust/tests/fixtures/`) are skipped — they are linted explicitly by the
/// negative tests, never as part of the real tree.
pub fn load_tree(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut files: BTreeMap<String, PathBuf> = BTreeMap::new();
    for sub in ["rust/src", "rust/tests", "benches", "examples"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files, root)?;
        }
    }
    let mut out = Vec::with_capacity(files.len());
    for (rel, path) in files {
        if rel.starts_with("rust/tests/fixtures/") {
            continue;
        }
        let src = fs::read_to_string(&path)?;
        out.push(SourceFile::parse(rel, &src));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, files: &mut BTreeMap<String, PathBuf>, root: &Path) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, files, root)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            files.insert(rel, path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_parsing() {
        assert_eq!(
            parse_allow(r#"// mutlint: allow(nan-cmp, "ranks, not losses")"#),
            Some(("nan-cmp".to_string(), true))
        );
        // no reason → recognized but invalid
        assert_eq!(parse_allow("// mutlint: allow(nan-cmp)"), Some(("nan-cmp".into(), false)));
        // empty reason string is not a reason
        assert_eq!(parse_allow(r#"// mutlint: allow(x, "")"#), Some(("x".into(), false)));
        // unrelated comments carry no marker
        assert_eq!(parse_allow("// plain comment about mutlint"), None);
        assert_eq!(parse_allow("/* mutlint: allow(atomic-write, \"block form\") */"),
            Some(("atomic-write".into(), true)));
    }

    #[test]
    fn suppression_covers_same_and_next_line() {
        let src = "// mutlint: allow(demo, \"r\")\nfn f() {}\nfn g() {}\n";
        let sf = SourceFile::parse("rust/src/x.rs".into(), src);
        assert!(sf.is_suppressed("demo", 1));
        assert!(sf.is_suppressed("demo", 2));
        assert!(!sf.is_suppressed("demo", 3));
        assert!(!sf.is_suppressed("other", 2));
    }

    #[test]
    fn reasonless_suppression_is_recorded_not_honored() {
        let src = "// mutlint: allow(demo)\nfn f() {}\n";
        let sf = SourceFile::parse("rust/src/x.rs".into(), src);
        assert!(!sf.is_suppressed("demo", 2));
        assert_eq!(sf.bad_suppression_lines(), &[1]);
    }

    #[test]
    fn test_region_detection() {
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn helper() { let s = \"}\"; }\n\
                       #[test]\n\
                       fn t() {}\n\
                   }\n\
                   fn prod2() {}\n";
        let sf = SourceFile::parse("rust/src/x.rs".into(), src);
        assert!(!sf.in_test(1));
        assert!(sf.in_test(2));
        assert!(sf.in_test(4)); // brace inside string must not end the region
        assert!(sf.in_test(6));
        assert!(sf.in_test(7));
        assert!(!sf.in_test(8));
    }

    #[test]
    fn whole_exemption_by_path() {
        for (rel, exempt) in [
            ("rust/tests/golden.rs", true),
            ("benches/step_latency.rs", true),
            ("examples/quickstart.rs", true),
            ("rust/src/serve/daemon.rs", false),
        ] {
            let sf = SourceFile::parse(rel.into(), "fn f() {}");
            assert_eq!(sf.whole_exempt, exempt, "{rel}");
        }
    }
}
