//! `mutransfer` — the coordinator CLI.
//!
//! Subcommands:
//!   exp <id>            regenerate a paper table/figure (DESIGN.md §4)
//!   train               one training run with explicit HPs
//!   transfer            Algorithm 1 end-to-end (tune proxy → run target)
//!   coord-check         verify a μP implementation (App. D.1)
//!   list-artifacts      show the variant inventory (built-in registry by
//!                       default; artifacts manifest under the pjrt feature)
//!
//! Common flags: --artifacts DIR --results DIR --preset ci|paper|smoke
//!
//! Execution backend: native (pure Rust) unless the binary was built with
//! the `pjrt` feature AND an artifacts manifest exists, in which case the
//! AOT-lowered XLA path is used.  Enabling `pjrt` needs the two Cargo.toml
//! edits described there (uncomment `xla`, set `pjrt = ["dep:xla"]`) —
//! see rust/src/runtime/mod.rs and DESIGN.md §2.

use anyhow::{bail, Context, Result};

use mutransfer::exp::{self, Scale};
use mutransfer::model::BaseShape;
use mutransfer::mup::{HyperParams, Optimizer, Parametrization};
use mutransfer::report::Reporter;
use mutransfer::runtime::Runtime;
use mutransfer::train::{run_ckpt as train_run_ckpt, CkptConfig, RunSpec, Schedule};
use mutransfer::transfer::TunerKind;
use mutransfer::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: mutransfer <exp|train|transfer|coord-check|list-artifacts|journal-canon> [flags]
  exp <id>|all        --preset ci|paper|smoke [--workers N]
  train               --variant NAME --scheme mup|sp --lr F --steps N [--base-width W]
                      [--checkpoint FILE --checkpoint-every N]  (auto-resumes from FILE)
  transfer            --proxy NAME --target NAME --base-width W --samples N --steps N --target-steps N [--workers N]
                      [--tuner random|grid|sha [--eta K --rung0 R]]
                      [--checkpoint-dir DIR --checkpoint-every N] [--resume-from JOURNAL]
  coord-check         --variant NAME(__coord) --scheme mup|sp [--base-width W] [--steps N]
  list-artifacts
  journal-canon FILE  print a sweep journal canonicalized (wall_secs
                      stripped, records sorted) for bit-exact comparison
common: --artifacts DIR  --results DIR
--workers: sweep worker threads (default: MUTRANSFER_WORKERS or half the
cores; needs a Send-capable backend — native yes, pjrt falls back to 1)
--tuner sha: successive halving (eta default 2, rung0 default steps/4);
checkpoints let promoted trials resume instead of retraining, so sha
executes strictly fewer train steps than random at equal final budget
--resume-from: reuse JOURNAL as the sweep journal (completed trials skip,
interrupted trials resume mid-flight when --checkpoint-dir matches)";

fn real_main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let artifacts = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(mutransfer::artifacts_dir);
    let results = args
        .get("results")
        .map(Into::into)
        .unwrap_or_else(mutransfer::results_dir);
    let preset = args.str_or("preset", "ci");

    match cmd.as_str() {
        "exp" => {
            let id = args
                .positional
                .get(1)
                .context("exp needs an id (e.g. fig1); see DESIGN.md §4")?
                .clone();
            let mut scale = Scale::by_name(&preset)
                .with_context(|| format!("unknown preset {preset}"))?;
            scale.workers = args.workers_or(mutransfer::util::pool::default_workers());
            let rt = Runtime::new(&artifacts)?;
            let rep = Reporter::new(results);
            exp::run(&id, &rt, &rep, &scale)?;
        }
        "train" => {
            // Flags, optionally seeded from a TOML config (--config FILE;
            // explicit flags win).
            let cfg = match args.get("config") {
                Some(p) => mutransfer::config::Config::load(std::path::Path::new(p))?,
                None => mutransfer::config::Config::default(),
            };
            let variant = args.str_or("variant", &cfg.str_or("run", "variant", "tfm_post_w64_d2"));
            let scheme = args.str_or("scheme", "mup");
            let steps = args.usize_or("steps", cfg.usize_or("run", "steps", 100));
            let seed = args.u64_or("seed", cfg.usize_or("run", "seed", 0) as u64);
            let base_width = args.usize_or("base-width", cfg.usize_or("mup", "base_d_model", 0));
            let mut hp = cfg.hyperparams();
            hp.lr = args.f64_or("lr", hp.lr);
            hp.sigma = args.f64_or("sigma", hp.sigma);
            let lr = hp.lr;
            // durable single-run state: snapshot to FILE every N steps and
            // auto-resume from it when the file already exists
            let ckpt = args.get("checkpoint").map(|p| CkptConfig {
                every: 0,
                path: p.into(),
            });
            let ckpt_every = args.usize_or("checkpoint-every", 25);
            let ckpt = ckpt.map(|mut c| {
                c.every = ckpt_every;
                c
            });
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let v = rt.manifest().get(&variant)?;
            let opt = if v.opt == "adam" { Optimizer::Adam } else { Optimizer::Sgd };
            let (par, base) = parse_scheme(&scheme, opt, v, base_width)?;
            let mut spec = RunSpec::new(&variant, par, hp, base);
            spec.steps = steps;
            spec.seed = seed;
            spec.eval_every = (steps / 4).max(1);
            spec.schedule = cfg.schedule();
            let data = mutransfer::data::source_for(v, seed);
            if let Some(c) = &ckpt {
                if c.path.exists() {
                    eprintln!("resuming from checkpoint {}", c.path.display());
                }
            }
            let r = train_run_ckpt(&rt, &spec, data.as_ref(), ckpt.as_ref())?;
            println!(
                "variant={variant} scheme={scheme} lr={lr:.3e} steps={} diverged={} final_train={:.4} best_val={:.4} ({:.2}s, {:.2} GFLOPs)",
                r.steps_done,
                r.diverged,
                r.final_train_loss(),
                r.best_val_loss(),
                r.wall_secs,
                r.flops / 1e9,
            );
            for (s, l) in &r.val_losses {
                println!("  val @ step {s}: {l:.4}");
            }
        }
        "transfer" => {
            let proxy = args.str_or("proxy", "tfm_post_w64_d2");
            let target = args.str_or("target", "tfm_post_w256_d2");
            let base_width = args.usize_or("base-width", 64);
            let samples = args.usize_or("samples", 12);
            let steps = args.usize_or("steps", 40);
            let target_steps = args.usize_or("target-steps", 120);
            let seed = args.u64_or("seed", 0);
            let workers = args.workers_or(mutransfer::util::pool::default_workers());
            let tuner_name = args.str_or("tuner", "random");
            let eta = args.usize_or("eta", 2);
            let rung0 = args.usize_or("rung0", (steps / 4).max(1));
            let ckpt_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
            let ckpt_every = args.usize_or("checkpoint-every", 0);
            let resume_from = args.get("resume-from").map(std::path::PathBuf::from);
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let tuner = match tuner_name.as_str() {
                "random" => TunerKind::Random,
                "grid" => TunerKind::Grid,
                "sha" => TunerKind::Sha { eta, rung0 },
                other => bail!("--tuner must be random|grid|sha, got {other}"),
            };
            let rt = Runtime::new(&artifacts)?;
            let rep = Reporter::new(results);
            let journal = resume_from.unwrap_or_else(|| rep.path("transfer-cli.journal"));
            let mut sweep = mutransfer::sweep::Sweep::new(&rt)
                .with_workers(workers)
                .with_journal(&journal)?;
            // SHA needs durable trial state to realize its savings; give
            // it a default checkpoint dir when none was requested
            let ckpt_dir = ckpt_dir.or_else(|| {
                matches!(tuner, TunerKind::Sha { .. }).then(|| rep.path("ckpt"))
            });
            if let Some(d) = &ckpt_dir {
                sweep = sweep.with_checkpoints(d, ckpt_every)?;
            }
            sweep.verbose = true;
            let setup = mutransfer::transfer::TransferSetup {
                proxy_variant: proxy.clone(),
                target_variant: target.clone(),
                base: BaseShape::Tfm {
                    d_model: base_width,
                    n_head: 4,
                    d_head: base_width / 4,
                    d_ffn: 4 * base_width,
                },
                optimizer: Optimizer::Adam,
                space: mutransfer::tuner::SearchSpace::iwslt_like(),
                proxy_steps: steps,
                target_steps,
                n_samples: samples,
                seed,
                eval_every: (steps / 2).max(2),
                schedule: Schedule::Constant,
                tuner,
            };
            let out = mutransfer::transfer::mu_transfer(&rt, &mut sweep, &setup, "cli")?;
            match (&out.best, &out.target) {
                (Some(best), Some(t)) => println!(
                    "best proxy HPs: {:?}\ntarget val loss: {:.4} (diverged={})\ntuning cost ratio: {:.1}%",
                    best.values,
                    t.trial.val_loss,
                    t.trial.diverged,
                    100.0 * out.tuning_cost_ratio(),
                ),
                _ => println!("all proxy trials diverged — widen the search space"),
            }
        }
        "journal-canon" => {
            // canonical journal view for bit-exact comparisons across runs:
            // wall_secs (the only legitimately nondeterministic field) and
            // ckpt records (paths differ per run dir) are dropped, records
            // sort lexicographically.  Used by the CI crash/resume check.
            let path = args
                .positional
                .get(1)
                .context("journal-canon needs a journal path")?
                .clone();
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {path}"))?;
            let mut lines: Vec<String> = Vec::new();
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                let Ok(mut j) = mutransfer::util::json::parse(line) else {
                    continue; // torn tail — with_journal would truncate it
                };
                if j.get("ckpt").is_some() {
                    continue;
                }
                if let mutransfer::util::json::Json::Obj(m) = &mut j {
                    m.remove("wall_secs");
                }
                lines.push(j.to_string());
            }
            lines.sort();
            for l in lines {
                println!("{l}");
            }
        }
        "coord-check" => {
            let variant = args.str_or("variant", "tfm_post_w64_d2__coord");
            let scheme = args.str_or("scheme", "mup");
            let steps = args.usize_or("steps", 4);
            let base_width = args.usize_or("base-width", 0);
            let lr = args.f64_or("lr", 2f64.powi(-7));
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let v = rt.manifest().get(&variant)?;
            let (par, base) = parse_scheme(&scheme, Optimizer::Adam, v, base_width)?;
            let hp = HyperParams { lr, ..HyperParams::default() };
            let mut spec = RunSpec::new(&variant, par, hp, base);
            spec.seed = 1;
            let data = mutransfer::data::source_for(v, 1);
            let rec = mutransfer::coordcheck::coord_check(&rt, &spec, data.as_ref(), steps)?;
            println!("width {}:", rec.width);
            for (probe, deltas) in &rec.deltas {
                println!(
                    "  {probe:<16} init_rms={:.3e}  Δrms(t)={}",
                    rec.init_rms.get(probe).copied().unwrap_or(f64::NAN),
                    deltas
                        .iter()
                        .map(|d| format!("{d:.3e}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                );
            }
        }
        "list-artifacts" => {
            let rt = Runtime::new(&artifacts)?;
            println!("{:<42} {:<12} {:<6} {:>10} {:>14}", "variant", "arch", "kind", "params", "GFLOPs/step");
            for name in rt.manifest().names() {
                let v = rt.manifest().get(name)?;
                println!(
                    "{:<42} {:<12} {:<6} {:>10} {:>14.3}",
                    v.name,
                    format!("{:?}", v.arch),
                    format!("{:?}", v.kind),
                    v.total_numel(),
                    v.flops_per_step() / 1e9,
                );
            }
        }
        _ => bail!("{USAGE}"),
    }
    Ok(())
}

fn parse_scheme(
    scheme: &str,
    opt: Optimizer,
    v: &mutransfer::runtime::Variant,
    base_width: usize,
) -> Result<(Parametrization, BaseShape)> {
    let par = match scheme {
        "mup" => Parametrization::mup(opt),
        "sp" => Parametrization::standard(opt),
        other => bail!("scheme must be mup|sp, got {other}"),
    };
    let base = if scheme == "sp" || base_width == 0 {
        BaseShape::SameAsTarget
    } else {
        match v.arch {
            mutransfer::runtime::Arch::Transformer => BaseShape::Tfm {
                d_model: base_width,
                n_head: v.config.get("n_head").unwrap_or(4),
                d_head: base_width / v.config.get("n_head").unwrap_or(4).max(1),
                d_ffn: 4 * base_width,
            },
            _ => BaseShape::Width(base_width),
        }
    };
    Ok((par, base))
}
