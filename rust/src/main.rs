//! `mutransfer` — the coordinator CLI.
//!
//! Subcommands:
//!   exp <id>            regenerate a paper table/figure (DESIGN.md §4)
//!   train               one training run with explicit HPs
//!   transfer            Algorithm 1 end-to-end (tune proxy → run target)
//!   coord-check         verify a μP implementation (App. D.1)
//!   list-artifacts      show the variant inventory (built-in registry by
//!                       default; artifacts manifest under the pjrt feature)
//!   serve               run the tuning service daemon (DESIGN.md §9)
//!   submit/status/results/watch/hp
//!                       HTTP clients against a running daemon
//!
//! Common flags: --artifacts DIR --results DIR --preset ci|paper|smoke
//!
//! Execution backend: native (pure Rust) unless the binary was built with
//! the `pjrt` feature AND an artifacts manifest exists, in which case the
//! AOT-lowered XLA path is used.  Enabling `pjrt` needs the two Cargo.toml
//! edits described there (uncomment `xla`, set `pjrt = ["dep:xla"]`) —
//! see rust/src/runtime/mod.rs and DESIGN.md §2.

use anyhow::{bail, Context, Result};

use mutransfer::exp::{self, Scale};
use mutransfer::model::BaseShape;
use mutransfer::mup::{HyperParams, Optimizer, Parametrization, Scheme};
use mutransfer::report::Reporter;
use mutransfer::runtime::Runtime;
use mutransfer::serve::{self, JobKind, JobSpec};
use mutransfer::train::{run_ckpt as train_run_ckpt, CkptConfig, RunSpec};
use mutransfer::transfer::TunerKind;
use mutransfer::util::cli::Args;
use mutransfer::util::json;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: mutransfer <exp|train|transfer|coord-check|list-artifacts|journal-canon|serve|submit|status|results|watch|hp|profile|bench-diff> [flags]
  exp <id>|all        --preset ci|paper|smoke [--workers N]
  train               --variant NAME --param sp|mup|umup --lr F --steps N [--base-width W]
                      [--base-depth L --base-batch B]  (depth/batch transfer axes)
                      [--checkpoint FILE --checkpoint-every N]  (auto-resumes from FILE)
                      [--trace-out FILE]  (Chrome trace-event dump of the run's spans)
                      [--profile-out FILE]  (perf-attribution JSON for the run, §13)
                      [--coords]  (live mu-coordinate telemetry lines on stderr)
  transfer            --proxy NAME --target NAME --base-width W --samples N --steps N --target-steps N [--workers N]
                      [--param sp|mup|umup] [--base-depth L --base-batch B]
                      [--tuner random|grid|sha [--eta K --rung0 R]]
                      [--checkpoint-dir DIR --checkpoint-every N] [--resume-from JOURNAL]
                      [--results-json FILE]  (canonical outcome dump, byte-identical
                      to a serve job's GET /jobs/:id/results)
  coord-check         --variant NAME(__coord) --param sp|mup|umup [--base-width W]
                      [--base-depth L --base-batch B] [--steps N]
  list-artifacts
  journal-canon FILE  print a sweep journal canonicalized (wall_secs
                      stripped, records sorted) for bit-exact comparison
  serve               --state-dir DIR [--addr HOST:PORT]  run the tuning daemon
                      (REST + SSE; a killed daemon resumes its queue on restart)
                      [--http-workers N]  connection pool size (default 8;
                      beyond-capacity connects get 503 + Retry-After)
                      [--exec-slots N]    concurrent jobs (default 2)
                      [--workers N]       shared trial-worker budget, split
                      fairly across running jobs (default: all cores)
                      [--max-conns N]     accepted-connection cap (default 1024)
                      [--cache-mb N]      results byte-cache budget (default 32)
                      [--trace-dir DIR]   dump DIR/serve-trace.json (Chrome
                      trace-event format) on graceful shutdown
  submit              --addr A [--name S --kind sweep|transfer] + transfer flags;
                      prints the new job id
  status              --addr A [JOB]     list jobs / show one job
  results             --addr A JOB       print a done job's canonical results JSON
  watch               --addr A JOB [--coords] [--profile]  stream a job's events
                      (SSE) to completion; --coords adds live mu-coordinate scale
                      lines (replays history past the ring via ?after= paging);
                      --profile polls /debug/profile for phase-share lines
  profile             --variant NAME --steps N [--param sp|mup|umup --lr F
                      --base-width W --out FILE]  run N profiled steps and emit
                      the perf-attribution report (JSON + aligned tables):
                      per-phase self-time shares, per-GEMM-shape GFLOP/s vs the
                      measured roofline, span-FLOPs vs model/flops.rs agreement
  bench-diff OLD NEW  compare two BENCH_*.json docs (or two directories of
                      them); exits nonzero when a lower-is-better row regresses
                      >10% (--threshold PCT; BENCH_DIFF_NO_ASSERT=1 reports
                      only; machine mismatch is report-only unless
                      BENCH_DIFF_FORCE=1)
  hp                  --addr A [--width W --depth L --batch B]  best transferred
                      HPs from any completed sweep (the muTransfer question, as
                      an endpoint; dims are echoed — muP makes the answer
                      shape-independent)
common: --artifacts DIR  --results DIR
--param (alias --scheme; --param wins): sp = standard parametrization (no
transfer), mup = Table-8 muP, umup = unit-scaled muP (unit init variance,
the scale lives in the multipliers)
--base-depth/--base-batch: base dims for the depth/batch transfer axes
(0/absent = same as target, i.e. width-only transfer)
--workers: sweep worker threads (default: MUTRANSFER_WORKERS or half the
cores; needs a Send-capable backend — native yes, pjrt falls back to 1)
--tuner sha: successive halving (eta default 2, rung0 default steps/4);
checkpoints let promoted trials resume instead of retraining, so sha
executes strictly fewer train steps than random at equal final budget
--resume-from: reuse JOURNAL as the sweep journal (completed trials skip,
interrupted trials resume mid-flight when --checkpoint-dir matches)";

fn real_main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let artifacts = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(mutransfer::artifacts_dir);
    let results = args
        .get("results")
        .map(Into::into)
        .unwrap_or_else(mutransfer::results_dir);
    let preset = args.str_or("preset", "ci");

    match cmd.as_str() {
        "exp" => {
            let id = args
                .positional
                .get(1)
                .context("exp needs an id (e.g. fig1); see DESIGN.md §4")?
                .clone();
            let mut scale = Scale::by_name(&preset)
                .with_context(|| format!("unknown preset {preset}"))?;
            scale.workers = args.workers_or(mutransfer::util::pool::default_workers());
            let rt = Runtime::new(&artifacts)?;
            let rep = Reporter::new(results);
            exp::run(&id, &rt, &rep, &scale)?;
        }
        "train" => {
            // Flags, optionally seeded from a TOML config (--config FILE;
            // explicit flags win).
            let cfg = match args.get("config") {
                Some(p) => mutransfer::config::Config::load(std::path::Path::new(p))?,
                None => mutransfer::config::Config::default(),
            };
            let variant = args.str_or("variant", &cfg.str_or("run", "variant", "tfm_post_w64_d2"));
            // --param is canonical, --scheme stays as an alias (--param wins)
            let scheme = {
                let alias = args.str_or("scheme", "mup");
                args.str_or("param", &alias)
            };
            let steps = args.usize_or("steps", cfg.usize_or("run", "steps", 100));
            let base_depth = args.usize_or("base-depth", 0);
            let base_batch = args.usize_or("base-batch", 0);
            let seed = args.u64_or("seed", cfg.usize_or("run", "seed", 0) as u64);
            let base_width = args.usize_or("base-width", cfg.usize_or("mup", "base_d_model", 0));
            let mut hp = cfg.hyperparams();
            hp.lr = args.f64_or("lr", hp.lr);
            hp.sigma = args.f64_or("sigma", hp.sigma);
            let lr = hp.lr;
            // durable single-run state: snapshot to FILE every N steps and
            // auto-resume from it when the file already exists
            let ckpt = args.get("checkpoint").map(|p| CkptConfig {
                every: 0,
                path: p.into(),
            });
            let ckpt_every = args.usize_or("checkpoint-every", 25);
            let ckpt = ckpt.map(|mut c| {
                c.every = ckpt_every;
                c
            });
            let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
            let profile_out = args.get("profile-out").map(std::path::PathBuf::from);
            let show_coords = args.flag("coords");
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let v = rt.manifest().get(&variant)?;
            let opt = if v.opt == "adam" { Optimizer::Adam } else { Optimizer::Sgd };
            let (par, base) = parse_scheme(&scheme, opt, v, base_width)?;
            let mut spec = RunSpec::new(&variant, par, hp, base);
            spec.steps = steps;
            spec.seed = seed;
            spec.eval_every = (steps / 4).max(1);
            spec.schedule = cfg.schedule();
            spec.base_depth = (base_depth > 0).then_some(base_depth);
            spec.base_batch = (base_batch > 0).then_some(base_batch);
            let data = mutransfer::data::source_for(v, seed);
            if let Some(c) = &ckpt {
                if c.path.exists() {
                    eprintln!("resuming from checkpoint {}", c.path.display());
                }
            }
            // Telemetry stays strictly opt-in on the offline CLI so the
            // default stdout/stderr bytes are unchanged (DESIGN.md §12).
            if trace_out.is_some() {
                mutransfer::obs::trace::enable();
            }
            if profile_out.is_some() {
                mutransfer::obs::profile::reset();
                mutransfer::obs::profile::enable();
            }
            let r = if show_coords {
                mutransfer::obs::coords::set_enabled(true);
                let sink = CoordStderr(serve::StderrSink::quiet());
                mutransfer::train::run_ckpt_with(
                    &rt,
                    &spec,
                    data.as_ref(),
                    ckpt.as_ref(),
                    &sink,
                    &variant,
                )?
            } else {
                train_run_ckpt(&rt, &spec, data.as_ref(), ckpt.as_ref())?
            };
            if let Some(p) = &trace_out {
                let n = mutransfer::obs::trace::write_chrome(p)?;
                mutransfer::obs::trace::disable();
                eprintln!("trace: {n} span(s) -> {}", p.display());
            }
            if let Some(p) = &profile_out {
                mutransfer::obs::profile::disable();
                let snap = mutransfer::obs::profile::snapshot();
                let peak = mutransfer::obs::profile::measured_peak_flops();
                let ctx = mutransfer::report::perf::ProfileCtx {
                    variant: Some(v),
                    steps: Some(r.steps_done),
                    peak_flops: peak,
                };
                let rep = mutransfer::report::perf::profile_report(&snap, &ctx);
                mutransfer::util::fsio::write_atomic(p, rep.json.to_string().as_bytes())?;
                eprintln!("profile: attribution -> {}", p.display());
            }
            println!(
                "variant={variant} scheme={scheme} lr={lr:.3e} steps={} diverged={} final_train={:.4} best_val={:.4} ({:.2}s, {:.2} GFLOPs)",
                r.steps_done,
                r.diverged,
                r.final_train_loss(),
                r.best_val_loss(),
                r.wall_secs,
                r.flops / 1e9,
            );
            for (s, l) in &r.val_losses {
                println!("  val @ step {s}: {l:.4}");
            }
        }
        "transfer" => {
            let workers = args.workers_or(mutransfer::util::pool::default_workers());
            let ckpt_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
            let resume_from = args.get("resume-from").map(std::path::PathBuf::from);
            let results_json = args.get("results-json").map(std::path::PathBuf::from);
            // the CLI and the serve daemon build their TransferSetup
            // through the SAME JobSpec::setup() mapping — that shared path
            // is what makes a daemon job bit-identical to an offline run
            let spec = parse_job_spec(&args, "transfer")?;
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let rep = Reporter::new(results);
            let journal = resume_from.unwrap_or_else(|| rep.path("transfer-cli.journal"));
            let mut sweep = mutransfer::sweep::Sweep::new(&rt)
                .with_workers(workers)
                .with_journal(&journal)?;
            // SHA needs durable trial state to realize its savings; give
            // it a default checkpoint dir when none was requested
            let ckpt_dir = ckpt_dir.or_else(|| {
                matches!(spec.tuner, TunerKind::Sha { .. }).then(|| rep.path("ckpt"))
            });
            if let Some(d) = &ckpt_dir {
                sweep = sweep.with_checkpoints(d, spec.ckpt_every)?;
            }
            sweep.verbose = true;
            let setup = spec.setup();
            let out = mutransfer::transfer::mu_transfer(
                &rt,
                &mut sweep,
                &setup,
                mutransfer::serve::daemon::JOB_LABEL,
            )?;
            if let Some(p) = &results_json {
                mutransfer::util::fsio::write_atomic(p, out.to_json().to_string().as_bytes())?;
            }
            match (&out.best, &out.target) {
                (Some(best), Some(t)) => println!(
                    "best proxy HPs: {:?}\ntarget val loss: {:.4} (diverged={})\ntuning cost ratio: {:.1}%",
                    best.values,
                    t.trial.val_loss,
                    t.trial.diverged,
                    100.0 * out.tuning_cost_ratio(),
                ),
                _ => println!("all proxy trials diverged — widen the search space"),
            }
        }
        "journal-canon" => {
            // canonical journal view for bit-exact comparisons across runs:
            // wall_secs (the only legitimately nondeterministic field) and
            // ckpt records (paths differ per run dir) are dropped, records
            // sort lexicographically.  Used by the CI crash/resume check.
            let path = args
                .positional
                .get(1)
                .context("journal-canon needs a journal path")?
                .clone();
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {path}"))?;
            let mut lines: Vec<String> = Vec::new();
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                let Ok(mut j) = mutransfer::util::json::parse(line) else {
                    continue; // torn tail — with_journal would truncate it
                };
                if j.get("ckpt").is_some() {
                    continue;
                }
                if let mutransfer::util::json::Json::Obj(m) = &mut j {
                    m.remove("wall_secs");
                }
                lines.push(j.to_string());
            }
            lines.sort();
            for l in lines {
                println!("{l}");
            }
        }
        "coord-check" => {
            let variant = args.str_or("variant", "tfm_post_w64_d2__coord");
            let scheme = {
                let alias = args.str_or("scheme", "mup");
                args.str_or("param", &alias)
            };
            let steps = args.usize_or("steps", 4);
            let base_width = args.usize_or("base-width", 0);
            let base_depth = args.usize_or("base-depth", 0);
            let base_batch = args.usize_or("base-batch", 0);
            let lr = args.f64_or("lr", 2f64.powi(-7));
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let v = rt.manifest().get(&variant)?;
            let (par, base) = parse_scheme(&scheme, Optimizer::Adam, v, base_width)?;
            let hp = HyperParams { lr, ..HyperParams::default() };
            let mut spec = RunSpec::new(&variant, par, hp, base);
            spec.seed = 1;
            spec.base_depth = (base_depth > 0).then_some(base_depth);
            spec.base_batch = (base_batch > 0).then_some(base_batch);
            let data = mutransfer::data::source_for(v, 1);
            let rec = mutransfer::coordcheck::coord_check(&rt, &spec, data.as_ref(), steps)?;
            println!("width {}:", rec.width);
            for (probe, deltas) in &rec.deltas {
                println!(
                    "  {probe:<16} init_rms={:.3e}  Δrms(t)={}",
                    rec.init_rms.get(probe).copied().unwrap_or(f64::NAN),
                    deltas
                        .iter()
                        .map(|d| format!("{d:.3e}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                );
            }
        }
        "serve" => {
            let addr = args.str_or("addr", "127.0.0.1:7077");
            let state_dir = std::path::PathBuf::from(
                args.get("state-dir")
                    .context("serve needs --state-dir DIR (durable job registry)")?,
            );
            let cfg = serve::ServeConfig {
                http_workers: args.usize_or("http-workers", 8),
                exec_slots: args.usize_or("exec-slots", 2),
                // 0 = auto (all cores); the FairBudget splits this across
                // however many jobs are running at once
                worker_budget: args.usize_or("workers", 0),
                max_conns: args.usize_or("max-conns", 1024),
                cache_bytes: args.usize_or("cache-mb", 32).saturating_mul(1 << 20),
            };
            let trace_dir = args.get("trace-dir").map(std::path::PathBuf::from);
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            if trace_dir.is_some() {
                mutransfer::obs::trace::enable();
            }
            let daemon =
                serve::Daemon::start_cfg(&addr, &state_dir, Some(artifacts.clone()), cfg)?;
            println!(
                "mutransfer serve: listening on http://{} (state-dir {}, {} job(s) resumed)",
                daemon.addr,
                state_dir.display(),
                daemon.registry.pending(),
            );
            use std::io::Write as _;
            std::io::stdout().flush().ok(); // scripts wait on this line
            daemon.join();
            // Reached on graceful shutdown only (SIGKILL'd daemons lose
            // the buffer — spans are in-memory by design, DESIGN.md §12).
            if let Some(d) = &trace_dir {
                std::fs::create_dir_all(d)
                    .with_context(|| format!("create --trace-dir {}", d.display()))?;
                let p = d.join("serve-trace.json");
                let n = mutransfer::obs::trace::write_chrome(&p)?;
                eprintln!("trace: {n} span(s) -> {}", p.display());
            }
        }
        "submit" => {
            let addr = args.str_or("addr", "127.0.0.1:7077");
            let spec = parse_job_spec(&args, &args.str_or("kind", "transfer"))?;
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let (status, body) =
                serve::http::rpc(&addr, "POST", "/jobs", Some(&spec.to_json().to_string()))?;
            if status != 201 {
                bail!("submit rejected ({status}): {body}");
            }
            let id = json::parse(&body)
                .map_err(|e| anyhow::anyhow!("bad submit response: {e}"))?
                .req("id")
                .as_str()
                .context("submit response has no id")?
                .to_string();
            // bare id on stdout so scripts can do id=$(mutransfer submit …)
            println!("{id}");
        }
        "status" => {
            let addr = args.str_or("addr", "127.0.0.1:7077");
            let id = args.positional.get(1).cloned();
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let path = match &id {
                Some(i) => format!("/jobs/{i}"),
                None => "/jobs".to_string(),
            };
            let (status, body) = serve::http::rpc(&addr, "GET", &path, None)?;
            if status != 200 {
                bail!("status failed ({status}): {body}");
            }
            let j = json::parse(&body).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
            let show = |v: &json::Json| {
                println!(
                    "{:<10} {:<10} {:<9} {}",
                    v.get("id").and_then(|x| x.as_str()).unwrap_or("?"),
                    v.get("state").and_then(|x| x.as_str()).unwrap_or("?"),
                    v.get("kind").and_then(|x| x.as_str()).unwrap_or("?"),
                    v.get("name").and_then(|x| x.as_str()).unwrap_or(""),
                );
                if let Some(err) = v.get("error").and_then(|x| x.as_str()) {
                    println!("  error: {err}");
                }
            };
            println!("{:<10} {:<10} {:<9} {}", "id", "state", "kind", "name");
            match j.get("jobs").and_then(|a| a.as_arr()) {
                Some(jobs) => jobs.iter().for_each(show),
                None => show(&j),
            }
        }
        "results" => {
            let addr = args.str_or("addr", "127.0.0.1:7077");
            let id = args
                .positional
                .get(1)
                .context("results needs a job id (see `mutransfer status`)")?;
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let (status, body) =
                serve::http::rpc(&addr, "GET", &format!("/jobs/{id}/results"), None)?;
            if status != 200 {
                bail!("results unavailable ({status}): {body}");
            }
            // raw passthrough, no trailing newline: `mutransfer results … >
            // f.json` is byte-identical to the daemon's results.json (and
            // to an offline --results-json dump)
            use std::io::Write as _;
            std::io::stdout().write_all(body.as_bytes())?;
            std::io::stdout().flush()?;
        }
        "watch" => {
            let addr = args.str_or("addr", "127.0.0.1:7077");
            let id = args
                .positional
                .get(1)
                .context("watch needs a job id (see `mutransfer status`)")?
                .clone();
            let show_coords = args.flag("coords");
            let show_profile = args.flag("profile");
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            // --coords replays the job's full persisted history first
            // (?after= paging escapes the 256-sample live ring), then the
            // SSE stream takes over with live samples
            if show_coords {
                let mut after = 0u64;
                loop {
                    let Ok((200, body)) = serve::http::rpc(
                        &addr,
                        "GET",
                        &format!("/jobs/{id}/metrics?after={after}"),
                        None,
                    ) else {
                        break;
                    };
                    let Ok(j) = json::parse(&body) else { break };
                    let samples = j.get("samples").and_then(|s| s.as_arr()).unwrap_or(&[]);
                    for s in samples {
                        let step = s.get("step").and_then(|x| x.as_usize()).unwrap_or(0);
                        for g in s.get("groups").and_then(|x| x.as_arr()).unwrap_or(&[]) {
                            let name = g.get("name").and_then(|x| x.as_str()).unwrap_or("?");
                            let w_rms = g.get("w_rms").and_then(|x| x.as_f64()).unwrap_or(f64::NAN);
                            let upd_rms =
                                g.get("upd_rms").and_then(|x| x.as_f64()).unwrap_or(f64::NAN);
                            println!(
                                "coords @{step} {name}: w_rms={w_rms:.3e} upd_rms={upd_rms:.3e}"
                            );
                        }
                    }
                    match j.get("next_after").and_then(|n| n.as_f64()) {
                        Some(n) if !samples.is_empty() => after = n as u64,
                        _ => break,
                    }
                }
            }
            let mut terminal: Option<String> = None;
            let mut last_profile = std::time::Instant::now();
            serve::http::sse(&addr, &format!("/jobs/{id}/events"), |_, data| {
                if show_profile && last_profile.elapsed().as_secs() >= 5 {
                    last_profile = std::time::Instant::now();
                    if let Ok((200, body)) = serve::http::rpc(&addr, "GET", "/debug/profile", None)
                    {
                        if let Ok(j) = json::parse(&body) {
                            let phases = j.get("phases").and_then(|p| p.as_arr()).unwrap_or(&[]);
                            let parts: Vec<String> = phases
                                .iter()
                                .filter_map(|p| {
                                    let name = p.get("name")?.as_str()?;
                                    let share = p.get("share_pct")?.as_f64()?;
                                    (share >= 0.05).then(|| format!("{name} {share:.1}%"))
                                })
                                .collect();
                            if !parts.is_empty() {
                                println!("profile: {}", parts.join("  "));
                            }
                        }
                    }
                }
                let Ok(j) = json::parse(data) else { return true };
                let Some(ev) = serve::Event::from_json(&j) else { return true };
                match &ev {
                    serve::Event::JobUpdate { state } => {
                        println!("job {id}: {state}");
                        if matches!(state.as_str(), "done" | "failed" | "cancelled") {
                            terminal = Some(state.clone());
                            return false;
                        }
                    }
                    serve::Event::TrialFinished {
                        key,
                        ordinal,
                        total,
                        train_loss,
                        val_loss,
                        diverged,
                        wall_secs,
                    } => println!(
                        "[{ordinal}/{total}] {key} -> train {train_loss:.4} val {val_loss:.4}{} ({wall_secs:.1}s)",
                        if *diverged { " DIVERGED" } else { "" },
                    ),
                    serve::Event::RungPromoted { budget, survivors, promoted } => {
                        println!("sha rung @{budget} steps: promoted {promoted}/{survivors}")
                    }
                    serve::Event::Warning { msg, .. } => println!("warning: {msg}"),
                    serve::Event::CoordStats { key, step, groups } if show_coords => {
                        for (name, w_rms, upd_rms) in groups {
                            println!(
                                "coords @{step} {key}/{name}: w_rms={w_rms:.3e} upd_rms={upd_rms:.3e}"
                            );
                        }
                    }
                    _ => {}
                }
                true
            })?;
            match terminal.as_deref() {
                Some("done") => {}
                Some(state) => bail!("job {id} finished as {state}"),
                None => bail!("event stream ended before job {id} reached a terminal state"),
            }
        }
        "hp" => {
            let addr = args.str_or("addr", "127.0.0.1:7077");
            let mut query: Vec<String> = Vec::new();
            for dim in ["width", "depth", "batch"] {
                if let Some(v) = args.get(dim) {
                    query.push(format!("{dim}={v}"));
                }
            }
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let path = if query.is_empty() {
                "/hp".to_string()
            } else {
                format!("/hp?{}", query.join("&"))
            };
            let (status, body) = serve::http::rpc(&addr, "GET", &path, None)?;
            if status != 200 {
                bail!("no transferred HPs available ({status}): {body}");
            }
            println!("{body}");
        }
        "list-artifacts" => {
            let rt = Runtime::new(&artifacts)?;
            println!("{:<42} {:<12} {:<6} {:>10} {:>14}", "variant", "arch", "kind", "params", "GFLOPs/step");
            for name in rt.manifest().names() {
                let v = rt.manifest().get(name)?;
                println!(
                    "{:<42} {:<12} {:<6} {:>10} {:>14.3}",
                    v.name,
                    format!("{:?}", v.arch),
                    format!("{:?}", v.kind),
                    v.total_numel(),
                    v.flops_per_step() / 1e9,
                );
            }
        }
        "profile" => {
            let want = args.str_or("variant", "tfm_post_w64_d2");
            let scheme = {
                let alias = args.str_or("scheme", "mup");
                args.str_or("param", &alias)
            };
            let steps = args.usize_or("steps", 20);
            let seed = args.u64_or("seed", 0);
            let base_width = args.usize_or("base-width", 0);
            let lr = args.f64_or("lr", HyperParams::default().lr);
            let out = args.get("out").map(std::path::PathBuf::from);
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let variant = resolve_variant(rt.manifest(), &want)?;
            let v = rt.manifest().get(&variant)?;
            let opt = if v.opt == "adam" { Optimizer::Adam } else { Optimizer::Sgd };
            let (par, base) = parse_scheme(&scheme, opt, v, base_width)?;
            let hp = HyperParams { lr, ..HyperParams::default() };
            let mut spec = RunSpec::new(&variant, par, hp, base);
            spec.steps = steps;
            spec.seed = seed;
            // no eval inside the window: eval forward passes issue GEMMs
            // outside the per-train-step inventory, which would skew the
            // span-FLOPs vs model/flops.rs agreement check past its 1% band
            spec.eval_every = 0;
            let data = mutransfer::data::source_for(v, seed);
            // roofline first: the FMA microbench must not sit inside the
            // profiled window
            let peak = mutransfer::obs::profile::measured_peak_flops();
            mutransfer::obs::profile::reset();
            mutransfer::obs::profile::enable();
            let r = train_run_ckpt(&rt, &spec, data.as_ref(), None)?;
            mutransfer::obs::profile::disable();
            let snap = mutransfer::obs::profile::snapshot();
            let ctx = mutransfer::report::perf::ProfileCtx {
                variant: Some(v),
                steps: Some(r.steps_done),
                peak_flops: peak,
            };
            let rep = mutransfer::report::perf::profile_report(&snap, &ctx);
            let out = out.unwrap_or_else(|| results.join(format!("profile_{variant}.json")));
            if let Some(d) = out.parent() {
                std::fs::create_dir_all(d)
                    .with_context(|| format!("creating {}", d.display()))?;
            }
            mutransfer::util::fsio::write_atomic(&out, rep.json.to_string().as_bytes())?;
            print!("{}", rep.text);
            println!("json      : {}", out.display());
        }
        "bench-diff" => {
            let old_p = std::path::PathBuf::from(
                args.positional
                    .get(1)
                    .context("bench-diff needs OLD and NEW (BENCH_*.json files or directories)")?,
            );
            let new_p = std::path::PathBuf::from(
                args.positional
                    .get(2)
                    .context("bench-diff needs OLD and NEW (BENCH_*.json files or directories)")?,
            );
            let threshold = args.f64_or("threshold", 10.0);
            args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
            let pairs: Vec<(std::path::PathBuf, std::path::PathBuf)> =
                if old_p.is_dir() && new_p.is_dir() {
                    let mut names: Vec<String> = std::fs::read_dir(&old_p)
                        .with_context(|| format!("reading {}", old_p.display()))?
                        .filter_map(|e| e.ok())
                        .filter_map(|e| e.file_name().into_string().ok())
                        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                        .collect();
                    names.sort();
                    names.iter().map(|n| (old_p.join(n), new_p.join(n))).collect()
                } else {
                    vec![(old_p.clone(), new_p.clone())]
                };
            if pairs.is_empty() {
                bail!("no BENCH_*.json documents under {}", old_p.display());
            }
            let load = |p: &std::path::Path| -> Result<json::Json> {
                let text = std::fs::read_to_string(p)
                    .with_context(|| format!("reading {}", p.display()))?;
                json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))
            };
            let no_assert = std::env::var("BENCH_DIFF_NO_ASSERT").as_deref() == Ok("1");
            let force = std::env::var("BENCH_DIFF_FORCE").as_deref() == Ok("1");
            let mut gated = 0usize;
            for (op, np) in &pairs {
                if !np.exists() {
                    println!("bench-diff: {} has no counterpart (skipped)", op.display());
                    continue;
                }
                let d = mutransfer::report::perf::bench_diff(&load(op)?, &load(np)?, threshold);
                print!("{}", d.render());
                if d.machine_match || force {
                    gated += d.gate_failures().len();
                }
            }
            if gated > 0 && !no_assert {
                bail!(
                    "{gated} row(s) regressed more than {threshold}% \
                     (BENCH_DIFF_NO_ASSERT=1 to report without failing)"
                );
            }
        }
        _ => bail!("{USAGE}"),
    }
    Ok(())
}

/// Lenient registry lookup for `profile`: exact name, then `<name>_d2`
/// (the registry's default-depth suffix), then a unique prefix match.
fn resolve_variant(
    man: &mutransfer::runtime::manifest::Manifest,
    want: &str,
) -> Result<String> {
    if man.get(want).is_ok() {
        return Ok(want.to_string());
    }
    let with_depth = format!("{want}_d2");
    if man.get(&with_depth).is_ok() {
        return Ok(with_depth);
    }
    let names = man.names();
    let hits: Vec<&&str> = names.iter().filter(|n| n.starts_with(want)).collect();
    match hits.as_slice() {
        [one] => Ok(one.to_string()),
        [] => bail!(
            "variant {want} not in the registry (no exact, _d2, or prefix match); \
             see `mutransfer list-artifacts`"
        ),
        many => bail!(
            "variant {want} is ambiguous: {}",
            many.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(", ")
        ),
    }
}

/// Parse the transfer-shaped flag set into a serve [`JobSpec`] — one
/// parser (and one `JobSpec::setup()` mapping behind it) shared by the
/// offline `transfer` subcommand and the daemon-bound `submit`, so a
/// submitted job and an offline run are the same job by construction.
fn parse_job_spec(args: &Args, kind: &str) -> Result<JobSpec> {
    // flagless defaults come from JobSpec::default() — the same source
    // JobSpec::from_json uses for a body-less POST /jobs, so the CLI and
    // the API can never drift apart on what the default job is
    let d = JobSpec::default();
    let steps = args.usize_or("steps", d.steps);
    // eta/rung0 are consumed even for random/grid so passing them with a
    // different tuner stays a no-op rather than an unknown-flag error
    let eta = args.usize_or("eta", JobSpec::default_eta());
    let rung0 = args.usize_or("rung0", JobSpec::default_rung0(steps));
    let tuner = match args.str_or("tuner", "random").as_str() {
        "random" => TunerKind::Random,
        "grid" => TunerKind::Grid,
        "sha" => TunerKind::Sha { eta, rung0 },
        other => bail!("--tuner must be random|grid|sha, got {other}"),
    };
    let param = {
        let alias = args.str_or("scheme", d.param.name());
        let name = args.str_or("param", &alias);
        Scheme::parse(&name)
            .with_context(|| format!("--param must be sp|mup|umup, got {name}"))?
    };
    // validated(): the same checks POST /jobs applies, so the offline CLI
    // can never accept a spec the API would reject (or vice versa)
    JobSpec {
        name: args.str_or("name", "cli"),
        kind: JobKind::parse(kind)?,
        proxy: args.str_or("proxy", &d.proxy),
        target: args.str_or("target", &d.target),
        base_width: args.usize_or("base-width", d.base_width),
        samples: args.usize_or("samples", d.samples),
        steps,
        target_steps: args.usize_or("target-steps", d.target_steps),
        seed: args.u64_or("seed", d.seed),
        workers: args.usize_or("workers", d.workers),
        tuner,
        ckpt_every: args.usize_or("checkpoint-every", d.ckpt_every),
        param,
        base_depth: args.usize_or("base-depth", d.base_depth),
        base_batch: args.usize_or("base-batch", d.base_batch),
    }
    .validated()
}

/// Sink for `train --coords`: prints one stderr line per sampled
/// parameter group on top of the quiet default (warnings only).  The
/// inner [`StderrSink`] counts the event for `/metrics`; forwarding
/// wrappers must not count again (see `serve::events::count_event`).
struct CoordStderr(serve::StderrSink);

impl serve::EventSink for CoordStderr {
    fn emit(&self, ev: &serve::Event) {
        if let serve::Event::CoordStats { step, groups, .. } = ev {
            for (name, w_rms, upd_rms) in groups {
                eprintln!("coords @{step} {name}: w_rms={w_rms:.3e} upd_rms={upd_rms:.3e}");
            }
        }
        self.0.emit(ev);
    }
}

fn parse_scheme(
    scheme: &str,
    opt: Optimizer,
    v: &mutransfer::runtime::Variant,
    base_width: usize,
) -> Result<(Parametrization, BaseShape)> {
    let sch = Scheme::parse(scheme)
        .with_context(|| format!("--param must be sp|mup|umup, got {scheme}"))?;
    let par = Parametrization::new(sch, opt);
    let base = if sch == Scheme::Sp || base_width == 0 {
        BaseShape::SameAsTarget
    } else {
        match v.arch {
            mutransfer::runtime::Arch::Transformer => BaseShape::Tfm {
                d_model: base_width,
                n_head: v.config.get("n_head").unwrap_or(4),
                d_head: base_width / v.config.get("n_head").unwrap_or(4).max(1),
                d_ffn: 4 * base_width,
            },
            _ => BaseShape::Width(base_width),
        }
    };
    Ok((par, base))
}
