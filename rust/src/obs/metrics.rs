//! Lock-sparse metrics registry (DESIGN.md §12).
//!
//! Every metric is a `static` with a *static* `mutransfer_`-prefixed
//! snake_case name (the `metric-names` lint enforces both the prefix and
//! that record sites in serve/ and runtime/native/ hot paths never build
//! strings).  Recording is one or two relaxed atomic ops — no locks, no
//! allocation — so instrumented hot paths stay within the ≤ 2% overhead
//! budget gated by `benches/obs_overhead.rs`.
//!
//! Two render targets share the same atomics:
//!
//! * [`render_prometheus`] — Prometheus text exposition (`# HELP`/
//!   `# TYPE`, `_total` counters, `_bucket{le=…}`/`_sum`/`_count`
//!   histograms) served at `GET /metrics`;
//! * [`render_json`] — a JSON twin with p50/p99 extracted from the
//!   log₂-bucketed histograms, served at `GET /debug/metrics`.
//!
//! Coherence: a histogram's `_count` is derived from the same per-bucket
//! snapshot as its `_bucket` lines, so cumulative bucket counts are
//! monotone and `_count` equals the `+Inf` bucket even while other
//! threads record concurrently.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::{jnum, jstr, Json};

/// Monotonic counter.  Name must be `mutransfer_*_total` snake_case.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str, help: &'static str) -> Counter {
        Counter { name, help, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (signed: RAII guards may transiently race inc/dec
/// order, and a clamped-at-zero gauge would hide that bug class).
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    v: AtomicI64,
}

impl Gauge {
    pub const fn new(name: &'static str, help: &'static str) -> Gauge {
        Gauge { name, help, v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    /// RAII inc-now/dec-on-drop — occupancy tracking that stays correct
    /// across early returns and unwinds (SSE subscribers, executor
    /// slots, pool membership).
    pub fn guard(&'static self) -> GaugeGuard {
        self.inc();
        GaugeGuard(self)
    }
}

pub struct GaugeGuard(&'static Gauge);

impl Drop for GaugeGuard {
    fn drop(&mut self) {
        self.0.dec();
    }
}

/// Log₂ latency buckets in microseconds: `le = 2^i µs` for `i < BUCKETS`,
/// then `+Inf`.  24 buckets span 1 µs … ~8.4 s, plenty for both a GEMM
/// and a full keep-alive request.
pub const BUCKETS: usize = 24;

const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

/// Lock-free histogram over nanosecond durations.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    counts: [AtomicU64; BUCKETS + 1],
    sum_ns: AtomicU64,
}

/// Bucket index for a duration: smallest `i` with `µs ≤ 2^i`, clamped to
/// the `+Inf` bucket.
fn bucket_idx(ns: u64) -> usize {
    let us = ns.div_ceil(1000);
    if us <= 1 {
        return 0;
    }
    let i = (64 - (us - 1).leading_zeros()) as usize;
    i.min(BUCKETS)
}

impl Histogram {
    pub const fn new(name: &'static str, help: &'static str) -> Histogram {
        Histogram {
            name,
            help,
            counts: [ATOMIC_ZERO; BUCKETS + 1],
            sum_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe_ns(&self, ns: u64) {
        self.counts[bucket_idx(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record the elapsed time since `t0` — the idiomatic record site:
    /// `let t0 = Instant::now(); …; H.observe_since(t0);`
    #[inline]
    pub fn observe_since(&self, t0: Instant) {
        self.observe_ns(t0.elapsed().as_nanos() as u64);
    }

    /// One coherent read of every bucket (non-cumulative) plus the sum.
    fn snapshot(&self) -> ([u64; BUCKETS + 1], u64) {
        let mut counts = [0u64; BUCKETS + 1];
        for (i, c) in self.counts.iter().enumerate() {
            counts[i] = c.load(Ordering::Relaxed);
        }
        (counts, self.sum_ns.load(Ordering::Relaxed))
    }

    pub fn count(&self) -> u64 {
        self.snapshot().0.iter().sum()
    }

    /// Quantile in µs (upper bucket bound), 0 when empty.  `q` in [0, 1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let (counts, _) = self.snapshot();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if i >= BUCKETS { u64::MAX } else { 1u64 << i };
            }
        }
        u64::MAX
    }
}

/// One HTTP route: request count + latency histogram, keyed by a static
/// label so record sites never format strings.
pub struct Route {
    pub label: &'static str,
    hits: AtomicU64,
    lat: Histogram,
}

impl Route {
    const fn new(label: &'static str) -> Route {
        Route {
            label,
            hits: AtomicU64::new(0),
            lat: Histogram::new(
                "mutransfer_http_request_latency_seconds",
                "wall time from parsed request to response written, per route",
            ),
        }
    }

    #[inline]
    pub fn record(&self, t0: Instant) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.lat.observe_since(t0);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}

// Route indices — `api::route_idx` classifies a parsed request into one
// of these; anything unknown lands on ROUTE_OTHER.
pub const ROUTE_HEALTHZ: usize = 0;
pub const ROUTE_METRICS: usize = 1;
pub const ROUTE_DEBUG_METRICS: usize = 2;
pub const ROUTE_JOBS_CREATE: usize = 3;
pub const ROUTE_JOBS_LIST: usize = 4;
pub const ROUTE_JOB_GET: usize = 5;
pub const ROUTE_JOB_RESULTS: usize = 6;
pub const ROUTE_JOB_JOURNAL: usize = 7;
pub const ROUTE_JOB_EVENTS: usize = 8;
pub const ROUTE_JOB_METRICS: usize = 9;
pub const ROUTE_JOB_DELETE: usize = 10;
pub const ROUTE_HP: usize = 11;
pub const ROUTE_DEBUG_PROFILE: usize = 12;
pub const ROUTE_OTHER: usize = 13;
pub const NROUTES: usize = 14;

pub static ROUTES: [Route; NROUTES] = [
    Route::new("healthz"),
    Route::new("metrics"),
    Route::new("debug_metrics"),
    Route::new("jobs_create"),
    Route::new("jobs_list"),
    Route::new("job_get"),
    Route::new("job_results"),
    Route::new("job_journal"),
    Route::new("job_events"),
    Route::new("job_metrics"),
    Route::new("job_delete"),
    Route::new("hp"),
    Route::new("debug_profile"),
    Route::new("other"),
];

/// Out-of-range indices fall back to the `other` route instead of
/// panicking — record sites in serve/ must not be able to panic.
#[inline]
pub fn route(idx: usize) -> &'static Route {
    ROUTES.get(idx).unwrap_or(&ROUTES[ROUTE_OTHER])
}

// ----------------------------------------------------------- the registry

pub static HTTP_SHEDS: Counter = Counter::new(
    "mutransfer_http_sheds_total",
    "connections shed with 503 because --max-conns was reached",
);
pub static CACHE_HITS: Counter = Counter::new(
    "mutransfer_result_cache_hits_total",
    "results served from the terminal-results byte cache",
);
pub static CACHE_MISSES: Counter = Counter::new(
    "mutransfer_result_cache_misses_total",
    "results reads that went to disk",
);
pub static CACHE_EVICTIONS: Counter = Counter::new(
    "mutransfer_result_cache_evictions_total",
    "cache entries evicted to stay under the byte budget",
);
pub static WARNINGS: Counter = Counter::new(
    "mutransfer_warnings_total",
    "Event::Warning emitted anywhere (quiet sinks still count)",
);
pub static TRAIN_STEPS: Counter = Counter::new(
    "mutransfer_train_steps_total",
    "optimizer steps executed across all trials",
);
pub static JOBS_SUBMITTED: Counter = Counter::new(
    "mutransfer_jobs_submitted_total",
    "jobs accepted into the registry queue",
);
pub static COORD_SAMPLES: Counter = Counter::new(
    "mutransfer_coord_samples_total",
    "per-step coordinate-scale telemetry samples recorded",
);
pub static BUS_EVENTS: Counter = Counter::new(
    "mutransfer_bus_events_total",
    "events published onto per-job event buses",
);
pub static TRACE_DROPPED: Counter = Counter::new(
    "mutransfer_trace_dropped_total",
    "trace spans dropped because the bounded span buffer was full",
);

pub static HTTP_OPEN_CONNS: Gauge = Gauge::new(
    "mutransfer_http_open_conns",
    "accepted keep-alive connections currently owned by the pool",
);
pub static SSE_SUBSCRIBERS: Gauge = Gauge::new(
    "mutransfer_sse_subscribers",
    "live SSE event-stream subscribers",
);
pub static EXEC_SLOTS_BUSY: Gauge = Gauge::new(
    "mutransfer_exec_slots_busy",
    "executor slots currently running a job",
);
pub static EXEC_SLOTS_TOTAL: Gauge = Gauge::new(
    "mutransfer_exec_slots_total",
    "executor slots configured (--exec-slots)",
);
pub static BUDGET_OUTSTANDING: Gauge = Gauge::new(
    "mutransfer_budget_outstanding",
    "fair-share worker permits currently held",
);
pub static BUDGET_WAITING: Gauge = Gauge::new(
    "mutransfer_budget_waiting",
    "threads blocked waiting for a fair-share permit",
);
pub static CACHE_BYTES: Gauge = Gauge::new(
    "mutransfer_result_cache_bytes",
    "bytes resident in the terminal-results cache",
);
pub static TRACE_BUF_HWM: Gauge = Gauge::new(
    "mutransfer_trace_buffer_hwm",
    "high-water mark of the bounded trace span buffer (cap: trace::MAX_EVENTS)",
);

pub static STEP_LATENCY: Histogram = Histogram::new(
    "mutransfer_train_step_latency_seconds",
    "wall time of one optimizer step (forward+backward+update)",
);
pub static JOURNAL_FSYNC: Histogram = Histogram::new(
    "mutransfer_journal_fsync_latency_seconds",
    "wall time of one journal append (write + fdatasync)",
);
pub static CKPT_PUBLISH: Histogram = Histogram::new(
    "mutransfer_ckpt_publish_latency_seconds",
    "wall time of one checkpoint serialize + atomic publish",
);

static COUNTERS: [&Counter; 10] = [
    &HTTP_SHEDS,
    &CACHE_HITS,
    &CACHE_MISSES,
    &CACHE_EVICTIONS,
    &WARNINGS,
    &TRAIN_STEPS,
    &JOBS_SUBMITTED,
    &COORD_SAMPLES,
    &BUS_EVENTS,
    &TRACE_DROPPED,
];

static GAUGES: [&Gauge; 8] = [
    &HTTP_OPEN_CONNS,
    &SSE_SUBSCRIBERS,
    &EXEC_SLOTS_BUSY,
    &EXEC_SLOTS_TOTAL,
    &BUDGET_OUTSTANDING,
    &BUDGET_WAITING,
    &CACHE_BYTES,
    &TRACE_BUF_HWM,
];

static HISTOGRAMS: [&Histogram; 3] = [&STEP_LATENCY, &JOURNAL_FSYNC, &CKPT_PUBLISH];

// ------------------------------------------------------------- rendering

/// Escape a label *value* for the text exposition: `\` → `\\`, `"` →
/// `\"`, newline → `\n` (Prometheus exposition format §label values).
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn le_seconds(i: usize) -> f64 {
    (1u64 << i) as f64 * 1e-6
}

fn write_histogram(out: &mut String, h: &Histogram, label: Option<(&str, &str)>) {
    let (counts, sum_ns) = h.snapshot();
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        let le = if i >= BUCKETS {
            "+Inf".to_string()
        } else {
            format!("{}", le_seconds(i))
        };
        match label {
            Some((k, v)) => out.push_str(&format!(
                "{}_bucket{{{k}=\"{}\",le=\"{le}\"}} {cum}\n",
                h.name,
                escape_label(v)
            )),
            None => out.push_str(&format!("{}_bucket{{le=\"{le}\"}} {cum}\n", h.name)),
        }
    }
    let sum = sum_ns as f64 / 1e9;
    match label {
        Some((k, v)) => {
            let v = escape_label(v);
            out.push_str(&format!("{}_sum{{{k}=\"{v}\"}} {sum}\n", h.name));
            out.push_str(&format!("{}_count{{{k}=\"{v}\"}} {cum}\n", h.name));
        }
        None => {
            out.push_str(&format!("{}_sum {sum}\n", h.name));
            out.push_str(&format!("{}_count {cum}\n", h.name));
        }
    }
}

fn write_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// The full registry as Prometheus text exposition (`GET /metrics`).
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(8 * 1024);
    for c in COUNTERS {
        write_header(&mut out, c.name, c.help, "counter");
        out.push_str(&format!("{} {}\n", c.name, c.get()));
    }
    for g in GAUGES {
        write_header(&mut out, g.name, g.help, "gauge");
        out.push_str(&format!("{} {}\n", g.name, g.get()));
    }
    write_header(
        &mut out,
        "mutransfer_http_requests_total",
        "HTTP requests handled, per route",
        "counter",
    );
    for r in &ROUTES {
        out.push_str(&format!(
            "mutransfer_http_requests_total{{route=\"{}\"}} {}\n",
            escape_label(r.label),
            r.hits()
        ));
    }
    let lat = &ROUTES[0].lat;
    write_header(&mut out, lat.name, lat.help, "histogram");
    for r in &ROUTES {
        write_histogram(&mut out, &r.lat, Some(("route", r.label)));
    }
    for h in HISTOGRAMS {
        write_header(&mut out, h.name, h.help, "histogram");
        write_histogram(&mut out, h, None);
    }
    out
}

fn histogram_json(h: &Histogram) -> Json {
    let (counts, sum_ns) = h.snapshot();
    let count: u64 = counts.iter().sum();
    Json::from_pairs(vec![
        ("count", jnum(count as f64)),
        ("sum_seconds", jnum(sum_ns as f64 / 1e9)),
        ("p50_us", jnum(h.quantile_us(0.50) as f64)),
        ("p99_us", jnum(h.quantile_us(0.99) as f64)),
    ])
}

/// The JSON twin (`GET /debug/metrics`): same atomics, p50/p99 extracted.
pub fn render_json() -> Json {
    let counters = Json::from_pairs(
        COUNTERS
            .iter()
            .map(|c| (c.name, jnum(c.get() as f64)))
            .collect(),
    );
    let gauges = Json::from_pairs(
        GAUGES
            .iter()
            .map(|g| (g.name, jnum(g.get() as f64)))
            .collect(),
    );
    let routes = Json::Arr(
        ROUTES
            .iter()
            .map(|r| {
                let mut j = Json::from_pairs(vec![
                    ("route", jstr(r.label)),
                    ("requests", jnum(r.hits() as f64)),
                ]);
                j.set("latency", histogram_json(&r.lat));
                j
            })
            .collect(),
    );
    let histograms = Json::from_pairs(
        HISTOGRAMS
            .iter()
            .map(|h| (h.name, histogram_json(h)))
            .collect(),
    );
    let mut j = Json::from_pairs(vec![("counters", counters), ("gauges", gauges)]);
    j.set("routes", routes);
    j.set("histograms", histograms);
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_idx(0), 0);
        assert_eq!(bucket_idx(999), 0); // <1µs
        assert_eq!(bucket_idx(1_000), 0); // exactly 1µs -> le=1µs
        assert_eq!(bucket_idx(1_001), 1); // just over -> le=2µs
        assert_eq!(bucket_idx(2_000), 1);
        assert_eq!(bucket_idx(2_001), 2);
        assert_eq!(bucket_idx(u64::MAX / 2), BUCKETS); // +Inf
    }

    #[test]
    fn counter_and_gauge_basics() {
        static C: Counter = Counter::new("mutransfer_test_ctr_total", "t");
        static G: Gauge = Gauge::new("mutransfer_test_gauge", "t");
        let before = C.get();
        C.inc();
        C.add(4);
        assert_eq!(C.get(), before + 5);
        G.set(3);
        G.inc();
        G.dec();
        G.dec();
        assert_eq!(G.get(), 2);
    }

    /// Exposition conformance on a privately-owned histogram: HELP before
    /// TYPE, cumulative buckets monotone, `_count` == `+Inf` bucket,
    /// `_sum` coherent with what was recorded.
    #[test]
    fn prometheus_exposition_conformance() {
        static H: Histogram = Histogram::new("mutransfer_test_conf_seconds", "conformance");
        // 1µs, 3µs, 5ms, 100s (overflow) — spread across buckets
        for ns in [1_000u64, 3_000, 5_000_000, 100_000_000_000] {
            H.observe_ns(ns);
        }
        let mut out = String::new();
        write_header(&mut out, H.name, H.help, "histogram");
        write_histogram(&mut out, &H, None);
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[0].starts_with("# HELP mutransfer_test_conf_seconds "));
        assert!(lines[1].starts_with("# TYPE mutransfer_test_conf_seconds histogram"));
        let mut prev = 0u64;
        let mut inf = None;
        for l in &lines[2..] {
            if l.contains("_bucket{") {
                let v: u64 = l.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= prev, "buckets must be cumulative-monotone: {out}");
                prev = v;
                if l.contains("le=\"+Inf\"") {
                    inf = Some(v);
                }
            }
        }
        assert_eq!(inf, Some(4), "every observation lands in +Inf's cumulative count");
        let count_line = lines.iter().find(|l| l.starts_with("mutransfer_test_conf_seconds_count")).unwrap();
        assert_eq!(count_line.rsplit(' ').next().unwrap(), "4");
        let sum_line = lines.iter().find(|l| l.starts_with("mutransfer_test_conf_seconds_sum")).unwrap();
        let sum: f64 = sum_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((sum - 100.005004).abs() < 1e-9, "{sum}");
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    /// N threads hammer one histogram; totals are exact (no lost updates).
    #[test]
    fn concurrent_recording_is_exact() {
        static H: Histogram = Histogram::new("mutransfer_test_hammer_seconds", "hammer");
        const THREADS: u64 = 8;
        const PER: u64 = 20_000;
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..PER {
                        // deterministic spread over buckets incl. overflow
                        H.observe_ns((i % 64) * 700 + t);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(H.count(), THREADS * PER);
        let expect_sum: u64 = (0..THREADS)
            .map(|t| (0..PER).map(|i| (i % 64) * 700 + t).sum::<u64>())
            .sum();
        assert_eq!(H.snapshot().1, expect_sum);
        // quantiles come back as bucket bounds, ordered
        assert!(H.quantile_us(0.5) <= H.quantile_us(0.99));
    }

    #[test]
    fn quantiles_empty_and_filled() {
        static H: Histogram = Histogram::new("mutransfer_test_quant_seconds", "q");
        assert_eq!(H.quantile_us(0.99), 0);
        for _ in 0..99 {
            H.observe_ns(1_000); // 1µs
        }
        H.observe_ns(40_000_000); // 40ms
        assert_eq!(H.quantile_us(0.5), 1);
        // p99 over 100 samples targets rank 99 -> still the 1µs bucket;
        // p995 catches the outlier's bucket (le = 2^16 µs covers 40ms... )
        let p995 = H.quantile_us(0.995);
        assert!(p995 >= 32_768, "{p995}");
    }

    /// The registry itself guarantees the ≥ 12 distinct series the
    /// acceptance criterion asks for, before any traffic at all.
    #[test]
    fn registry_exposes_at_least_12_series() {
        let text = render_prometheus();
        let families: std::collections::BTreeSet<&str> = text
            .lines()
            .filter(|l| l.starts_with("# TYPE "))
            .filter_map(|l| l.split(' ').nth(2))
            .collect();
        assert!(families.len() >= 12, "only {} families: {families:?}", families.len());
        // every family carries the project prefix
        for f in &families {
            assert!(f.starts_with("mutransfer_"), "{f}");
        }
        // the JSON twin parses back through our own parser
        let j = crate::util::json::parse(&render_json().to_string()).unwrap();
        assert!(j.get("counters").is_some() && j.get("histograms").is_some());
    }

    #[test]
    fn route_lookup_never_panics() {
        assert_eq!(route(ROUTE_HP).label, "hp");
        assert_eq!(route(usize::MAX).label, "other");
        let t0 = Instant::now();
        route(ROUTE_OTHER).record(t0);
        assert!(route(ROUTE_OTHER).hits() >= 1);
    }
}
