//! Always-available perf-attribution aggregator (DESIGN.md §13).
//!
//! [`trace`](crate::obs::trace) spans are the raw signal; this module
//! folds them — *streaming, as each span closes* — into a bounded
//! aggregate instead of a bounded raw buffer, so attribution can stay
//! enabled for an entire daemon lifetime (`GET /debug/profile`) or a
//! profiled CLI run (`mutransfer profile`, `train --profile-out`)
//! without ever dropping data.
//!
//! Two views share one pass:
//!
//! * **per span kind, per thread** — count, total (inclusive) time and
//!   *self* time (total − direct children, computed streaming by the
//!   span guards).  Self times of all kinds partition the span-covered
//!   wall time exactly, which is what makes the phase-share table sum
//!   to ~100% by construction;
//! * **per GEMM shape** — count, total time, and FLOPs from
//!   `model::flops::flops_for_shape` (the single accounting source),
//!   giving achieved GFLOP/s per (m, k, n).
//!
//! Cost model: span guards fold into a *thread-local* map and flush to
//! the global mutex only when the thread's root span closes (once per
//! train step / HTTP request), so enabling the profiler adds no
//! per-GEMM lock traffic.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::model::flops::flops_for_shape;

static ENABLED: AtomicBool = AtomicBool::new(false);
static TID_SEQ: AtomicU64 = AtomicU64::new(1);

/// Per-(kind, thread) accumulator.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KindStat {
    pub count: u64,
    pub total_ns: u64,
    pub self_ns: u64,
}

/// Per-GEMM-shape accumulator; `flops` comes from `flops_for_shape` so
/// utilization math can never drift from `model/flops.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShapeStat {
    pub count: u64,
    pub total_ns: u64,
    pub flops: f64,
}

#[derive(Default)]
struct LocalAgg {
    kinds: BTreeMap<&'static str, KindStat>,
    shapes: BTreeMap<(u32, u32, u32), ShapeStat>,
}

/// One profiled thread's slice of the global aggregate.
#[derive(Debug, Clone, Default)]
pub struct ThreadStats {
    pub label: Option<String>,
    pub kinds: BTreeMap<&'static str, KindStat>,
}

#[derive(Default)]
struct State {
    threads: BTreeMap<u64, ThreadStats>,
    shapes: BTreeMap<(u32, u32, u32), ShapeStat>,
    labels: BTreeMap<u64, String>,
}

static STATE: Mutex<Option<State>> = Mutex::new(None);

thread_local! {
    static LOCAL: RefCell<LocalAgg> = RefCell::new(LocalAgg::default());
    static PTID: RefCell<(u64, Option<String>)> = const { RefCell::new((0, None)) };
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start aggregating (keeps any existing aggregate; use [`reset`] for a
/// clean window).
pub fn enable() {
    {
        let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_none() {
            *g = Some(State::default());
        }
    }
    ENABLED.store(true, Ordering::Relaxed);
    crate::obs::trace::sync_active();
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    crate::obs::trace::sync_active();
}

/// Clear the aggregate (global and not-yet-flushed local residue is
/// dropped on next flush by the epoch below being irrelevant: locals
/// flush at root-span close, so call `reset` only between runs).
pub fn reset() {
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let labels = g.as_ref().map(|s| s.labels.clone()).unwrap_or_default();
    *g = Some(State { labels, ..State::default() });
}

/// Name the calling thread in profile output (executor slots, pool
/// workers).  Sticky across [`reset`].
pub fn label_current_thread(label: &str) {
    PTID.with(|p| p.borrow_mut().1 = Some(label.to_string()));
    let tid = local_tid();
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let st = g.get_or_insert_with(State::default);
    st.labels.insert(tid, label.to_string());
    if let Some(t) = st.threads.get_mut(&tid) {
        t.label = Some(label.to_string());
    }
}

fn local_tid() -> u64 {
    PTID.with(|p| {
        let mut b = p.borrow_mut();
        if b.0 == 0 {
            b.0 = TID_SEQ.fetch_add(1, Ordering::Relaxed);
        }
        b.0
    })
}

/// Fold one completed span (called by `trace::SpanGuard::drop`).
/// `depth == 1` means the thread's root span just closed — flush the
/// thread-local aggregate into the global state.
pub(crate) fn record(
    name: &'static str,
    args: [u32; 3],
    dur_ns: u64,
    self_ns: u64,
    depth: u32,
) {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let k = l.kinds.entry(name).or_default();
        k.count += 1;
        k.total_ns += dur_ns;
        k.self_ns += self_ns;
        if args != [0; 3] {
            let s = l.shapes.entry((args[0], args[1], args[2])).or_default();
            s.count += 1;
            s.total_ns += dur_ns;
            s.flops += flops_for_shape(args[0] as usize, args[1] as usize, args[2] as usize);
        }
    });
    if depth == 1 {
        flush_local();
    }
}

fn flush_local() {
    let agg = LOCAL.with(|l| std::mem::take(&mut *l.borrow_mut()));
    if agg.kinds.is_empty() && agg.shapes.is_empty() {
        return;
    }
    let tid = local_tid();
    let label = PTID.with(|p| p.borrow().1.clone());
    let mut g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let st = g.get_or_insert_with(State::default);
    let t = st.threads.entry(tid).or_default();
    if t.label.is_none() {
        t.label = label.or_else(|| st.labels.get(&tid).cloned());
    }
    for (name, ks) in agg.kinds {
        let dst = t.kinds.entry(name).or_default();
        dst.count += ks.count;
        dst.total_ns += ks.total_ns;
        dst.self_ns += ks.self_ns;
    }
    for (shape, ss) in agg.shapes {
        let dst = st.shapes.entry(shape).or_default();
        dst.count += ss.count;
        dst.total_ns += ss.total_ns;
        dst.flops += ss.flops;
    }
}

/// Point-in-time copy of the aggregate.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// (tid, stats) sorted by tid.
    pub threads: Vec<(u64, ThreadStats)>,
    /// ((m, k, n), stats) sorted by shape.
    pub shapes: Vec<((u32, u32, u32), ShapeStat)>,
}

impl Snapshot {
    /// Kind stats summed across threads.
    pub fn kinds_merged(&self) -> BTreeMap<&'static str, KindStat> {
        let mut out: BTreeMap<&'static str, KindStat> = BTreeMap::new();
        for (_, t) in &self.threads {
            for (name, ks) in &t.kinds {
                let dst = out.entry(name).or_default();
                dst.count += ks.count;
                dst.total_ns += ks.total_ns;
                dst.self_ns += ks.self_ns;
            }
        }
        out
    }

    /// Span-attributed GEMM FLOPs in the window.
    pub fn gemm_flops(&self) -> f64 {
        self.shapes.iter().map(|(_, s)| s.flops).sum()
    }
}

pub fn snapshot() -> Snapshot {
    let g = STATE.lock().unwrap_or_else(|e| e.into_inner());
    let Some(st) = g.as_ref() else { return Snapshot::default() };
    Snapshot {
        threads: st
            .threads
            .iter()
            .map(|(tid, t)| {
                let mut t = t.clone();
                if t.label.is_none() {
                    t.label = st.labels.get(tid).cloned();
                }
                (*tid, t)
            })
            .collect(),
        shapes: st.shapes.iter().map(|(k, v)| (*k, *v)).collect(),
    }
}

// --------------------------------------------------------------- roofline

/// Machine-measured scalar f32 FMA peak, in FLOP/s, for one core — the
/// roofline that turns achieved GFLOP/s into a utilization *fraction*.
/// Eight independent accumulator chains hide the FMA latency, all data
/// stays in registers, and the best of `trials` timed windows is taken
/// (interference only ever slows a window down).  "Scalar" is nominal:
/// whatever the compiler does to this plain loop is exactly what it does
/// to the blocked kernels' inner loops, so the ratio is honest.
pub fn measured_peak_flops() -> f64 {
    const CHAINS: usize = 8;
    const ITERS: usize = 200_000;
    let mut best = 0f64;
    for trial in 0..3 {
        let mut acc = [1.0f32 + trial as f32 * 0.25; CHAINS];
        let m = 1.000_000_1f32;
        let a = 1e-9f32;
        let t0 = Instant::now();
        for _ in 0..ITERS {
            for c in acc.iter_mut() {
                *c = c.mul_add(m, a);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(acc);
        // 2 FLOPs per mul_add per chain
        let flops = (2 * CHAINS * ITERS) as f64 / secs.max(1e-12);
        if flops.total_cmp(&best).is_gt() {
            best = flops;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace;

    /// Sequential lifecycle test (the enable flags are process-global).
    #[test]
    fn aggregates_self_time_and_shapes() {
        reset();
        enable();
        label_current_thread("test-thread");
        {
            let _root = trace::span("prof_test_root");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _g = trace::span_mnk("prof_test_gemm", 4, 8, 2);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            {
                let _g = trace::span_mnk("prof_test_gemm", 4, 8, 2);
            }
        }
        disable();
        let snap = snapshot();
        let kinds = snap.kinds_merged();
        let root = kinds["prof_test_root"];
        let gemm = kinds["prof_test_gemm"];
        assert_eq!(root.count, 1);
        assert_eq!(gemm.count, 2);
        // parent self excludes children; totals nest
        assert!(root.total_ns >= gemm.total_ns);
        assert!(root.self_ns <= root.total_ns - gemm.total_ns + 1_000_000);
        // self times partition the root total (exact up to clock reads)
        let self_sum: u64 = kinds.values().map(|k| k.self_ns).sum();
        let drift = root.total_ns.abs_diff(self_sum);
        assert!(
            drift < root.total_ns / 50 + 50_000,
            "self-time partition drift {drift}ns of {}ns",
            root.total_ns
        );
        // shapes carry flops from the shared helper
        let (&shape, stat) = snap
            .shapes
            .iter()
            .map(|(s, v)| (s, v))
            .find(|(s, _)| **s == (4, 8, 2))
            .expect("gemm shape aggregated");
        assert_eq!(shape, (4, 8, 2));
        assert_eq!(stat.count, 2);
        assert_eq!(stat.flops, 2.0 * flops_for_shape(4, 8, 2));
        // thread label survives into the snapshot
        assert!(snap
            .threads
            .iter()
            .any(|(_, t)| t.label.as_deref() == Some("test-thread")));
        reset();
        assert!(snapshot().threads.is_empty());
    }

    #[test]
    fn peak_measurement_is_positive_and_stable() {
        let p = measured_peak_flops();
        assert!(p > 1e6, "peak {p} implausibly low");
        assert!(p < 1e13, "peak {p} implausibly high for one scalar core");
    }
}
