//! Live μ-coordinate telemetry (DESIGN.md §12).
//!
//! The paper's correctness instrument — the coordinate check — says
//! per-coordinate scales stay O(1) in width under μP while SP blows up
//! (Tensor Programs V §4; Lingle arXiv 2404.05728 shows the failure is
//! usually *silent*).  `coordcheck/` runs that offline on dedicated
//! `__coord` probe variants; this module makes a width-normalized slice
//! of the same signal available **while a trial trains**:
//!
//! * `w_rms` — RMS of each parameter tensor (for unit-variance inputs
//!   this tracks the activation scale that tensor produces, the u-μP
//!   unit-scaling argument from arXiv 2407.17465);
//! * `upd_rms` — RMS(Δparam) · √fan_in, the same normalization
//!   `coordcheck::growth_exponents` fits: flat-or-shrinking across
//!   widths under μP, growing like √fan_in under SP-with-global-LR.
//!
//! Sampling is read-only (`session.param(idx)` copies) every
//! [`SAMPLE_EVERY`] steps, so the training trajectory stays bitwise
//! identical with telemetry on or off; the ≤ 2% overhead budget is
//! gated by `benches/obs_overhead.rs`.  Samples are emitted as
//! [`crate::serve::events::Event::CoordStats`] on the job's event bus,
//! ring-buffered per job by the daemon's registry, and served at
//! `GET /jobs/:id/metrics`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::runtime::manifest::ParamInfo;
use crate::stats::rms;
use crate::util::json::{jnum, jstr, Json};

/// Off by default: offline `train`/`transfer` runs sample only when the
/// caller opts in; the serve daemon enables it at startup so every job
/// has live telemetry.
static ENABLED: AtomicBool = AtomicBool::new(false);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Sample cadence in optimizer steps.  Amortizes the two `param(idx)`
/// snapshots a sample needs; step 0 is always sampled so short trials
/// still report.
pub const SAMPLE_EVERY: usize = 8;

/// Per-job ring capacity in the daemon registry: with [`SAMPLE_EVERY`]=8
/// this retains the trailing ~2k steps of a live job.
pub const RING_CAP: usize = 256;

/// Should this step be sampled?  (Telemetry off ⇒ never.)
#[inline]
pub fn sample_step(step: usize) -> bool {
    enabled() && step % SAMPLE_EVERY == 0
}

/// One parameter group's coordinate-scale stats at one step.
#[derive(Debug, Clone)]
pub struct GroupStat {
    pub name: String,
    /// RMS of the tensor itself (activation-scale proxy).
    pub w_rms: f64,
    /// RMS(Δparam) · √fan_in — the coordcheck normalization.
    pub upd_rms: f64,
}

/// Compute per-tensor stats from before/after parameter snapshots.
/// Length mismatches (a backend declining some tensor) drop just that
/// tensor rather than failing the step.
pub fn group_stats(params: &[ParamInfo], before: &[Vec<f32>], after: &[Vec<f32>]) -> Vec<GroupStat> {
    let mut out = Vec::with_capacity(params.len());
    for (i, info) in params.iter().enumerate() {
        let (Some(b), Some(a)) = (before.get(i), after.get(i)) else { continue };
        if b.len() != a.len() || a.is_empty() {
            continue;
        }
        let delta: Vec<f32> = a.iter().zip(b.iter()).map(|(x, y)| x - y).collect();
        out.push(GroupStat {
            name: info.name.clone(),
            w_rms: rms(a),
            upd_rms: rms(&delta) * (info.fan_in.max(1) as f64).sqrt(),
        });
    }
    out
}

/// The scalar scale-growth signal for one sample: the largest normalized
/// update scale across groups.  Fit against width via
/// `stats::growth_exponent` this is ≈ +0.5 for SP (global LR) and ≤ 0
/// for μP — the acceptance test in `rust/tests/obs.rs` pins both.  A
/// NaN group (diverged trial) wins the max via `stats::nan_last` —
/// divergence must never be masked by a finite sibling.
pub fn scale_signal(groups: &[GroupStat]) -> f64 {
    groups
        .iter()
        .map(|g| g.upd_rms)
        .max_by(crate::stats::nan_last)
        .unwrap_or(0.0)
}

/// Wire format of one sample (shared by `Event::CoordStats` and
/// `GET /jobs/:id/metrics`):
/// `{"step":N,"groups":[{"name":…,"w_rms":…,"upd_rms":…},…]}`.
pub fn sample_json(step: usize, groups: &[GroupStat]) -> Json {
    Json::from_pairs(vec![
        ("step", jnum(step as f64)),
        (
            "groups",
            Json::Arr(
                groups
                    .iter()
                    .map(|g| {
                        Json::from_pairs(vec![
                            ("name", jstr(&g.name)),
                            ("w_rms", jnum(g.w_rms)),
                            ("upd_rms", jnum(g.upd_rms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Fixed-capacity ring of samples (oldest evicted first); the registry
/// keeps one per live job.
#[derive(Debug, Default)]
pub struct CoordRing {
    buf: VecDeque<Json>,
}

impl CoordRing {
    pub fn push(&mut self, sample: Json) {
        if self.buf.len() >= RING_CAP {
            self.buf.pop_front();
        }
        self.buf.push_back(sample);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.buf.iter().cloned().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mup::Role;

    fn info(name: &str, fan_in: usize, numel: usize) -> ParamInfo {
        ParamInfo {
            name: name.into(),
            shape: vec![numel],
            role: Role::Hidden,
            fan_in,
            fan_out: 1,
            init: "normal".into(),
        }
    }

    #[test]
    fn group_stats_math_and_mismatch_tolerance() {
        let params = vec![info("w", 4, 2), info("b", 1, 2), info("gone", 4, 2)];
        let before = vec![vec![1.0f32, 1.0], vec![0.0, 0.0]];
        let after = vec![vec![1.5f32, 0.5], vec![3.0, 4.0]];
        let g = group_stats(&params, &before, &after);
        assert_eq!(g.len(), 2, "missing third snapshot drops just that tensor");
        // w: delta = [0.5, -0.5] -> rms 0.5, * sqrt(4) = 1.0
        assert!((g[0].upd_rms - 1.0).abs() < 1e-12, "{}", g[0].upd_rms);
        // after [1.5, 0.5] -> rms sqrt((2.25+0.25)/2) = sqrt(1.25)
        assert!((g[0].w_rms - 1.25f64.sqrt()).abs() < 1e-12);
        // b: delta rms = sqrt((9+16)/2), fan_in 1
        assert!((g[1].upd_rms - 12.5f64.sqrt()).abs() < 1e-12);
        assert!((scale_signal(&g) - 12.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut r = CoordRing::default();
        for i in 0..(RING_CAP + 10) {
            r.push(sample_json(i, &[]));
        }
        assert_eq!(r.len(), RING_CAP);
        let arr = r.to_json();
        let first = arr.as_arr().unwrap()[0].get("step").unwrap().as_f64().unwrap();
        assert_eq!(first as usize, 10, "oldest 10 evicted");
    }

    #[test]
    fn sample_json_shape() {
        let g = vec![GroupStat { name: "block0.wq".into(), w_rms: 0.5, upd_rms: 0.25 }];
        let j = sample_json(40, &g);
        let s = j.to_string();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(back.get("step").unwrap().as_f64().unwrap(), 40.0);
        let groups = back.get("groups").unwrap().as_arr().unwrap();
        assert_eq!(groups[0].get("name").unwrap().as_str().unwrap(), "block0.wq");
        assert_eq!(groups[0].get("upd_rms").unwrap().as_f64().unwrap(), 0.25);
    }
}
