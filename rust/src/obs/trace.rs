//! Hierarchical trace spans with a Chrome trace-event dump (DESIGN.md
//! §12) and a streaming feed into the [`profile`](crate::obs::profile)
//! aggregator (§13).
//!
//! Tracing is a debugging mode, off by default.  The disabled fast path
//! of [`span`] is one relaxed atomic load and a `None` — no clock read,
//! no lock, no allocation — so span guards can sit inside hot kernels
//! (train step, GEMM, attention, journal fsync, HTTP parse-respond)
//! without moving the ≤ 2% telemetry overhead budget.
//!
//! When enabled (`mutransfer train --trace-out FILE`, `serve
//! --trace-dir DIR`), each completed span pushes one record (static
//! name, thread id, depth, start, duration, optional m·k·n args) onto a
//! bounded global buffer; [`write_chrome`] dumps them as Chrome
//! trace-event JSON (`"ph":"X"` complete events) loadable in
//! `chrome://tracing` or Perfetto.  Nesting is carried by per-thread
//! depth counters plus the natural containment of `ts`/`dur` on one
//! `tid`.
//!
//! The same guards also drive the profiler: when
//! [`profile::enabled`](crate::obs::profile::enabled) a completed span
//! folds (total time, *self* time = total − direct children, FLOPs for
//! GEMM shapes) into the per-thread aggregate without touching the
//! bounded raw buffer, so attribution can stay on for a whole daemon
//! lifetime.  Self time is computed streaming via a per-thread stack of
//! child-duration accumulators — no post-processing pass over raw spans.

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::obs::{metrics, profile};
use crate::util::fsio;
use crate::util::json::{jnum, jstr, Json};

static ENABLED: AtomicBool = AtomicBool::new(false);
/// `ENABLED || profile::enabled()` — the one load on the disabled fast
/// path.  Kept coherent by [`sync_active`].
static ACTIVE: AtomicBool = AtomicBool::new(false);
static TID_SEQ: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);

/// Bounded so a runaway traced loop degrades to dropped spans, not OOM.
/// Overflow is *not* silent: every dropped span increments
/// `mutransfer_trace_dropped_total` and the buffer's high-water mark is
/// exported as `mutransfer_trace_buffer_hwm` (DESIGN.md §12).
pub const MAX_EVENTS: usize = 1 << 18;

/// One completed span.  `args` is `[m, k, n]` for GEMM spans recorded
/// via [`span_mnk`] (FLOPs = 2·m·k·n), `[0, 0, 0]` otherwise.
#[derive(Debug, Clone)]
pub struct SpanRec {
    pub name: &'static str,
    pub tid: u64,
    pub depth: u32,
    pub start: Instant,
    pub dur_ns: u64,
    pub args: [u32; 3],
}

static STORE: Mutex<Vec<SpanRec>> = Mutex::new(Vec::new());

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
    static DEPTH: Cell<u32> = const { Cell::new(0) };
    // Per-open-span accumulator of direct-child durations; the top entry
    // belongs to the innermost open span on this thread.
    static CHILD_NS: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

fn tid() -> u64 {
    TID.with(|t| {
        if t.get() == 0 {
            t.set(TID_SEQ.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

/// Recompute the combined fast-path flag; called by trace and profile
/// enable/disable.
pub(crate) fn sync_active() {
    ACTIVE.store(
        ENABLED.load(Ordering::Relaxed) || profile::enabled(),
        Ordering::Relaxed,
    );
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start collecting spans (clears any previous buffer).
pub fn enable() {
    let mut g = STORE.lock().unwrap_or_else(|e| e.into_inner());
    g.clear();
    DROPPED.store(0, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
    sync_active();
}

/// Stop collecting; already-recorded spans stay buffered for [`take`] /
/// [`write_chrome`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    sync_active();
}

/// Drain the span buffer.  Returns `(spans, dropped_count)`.
pub fn take() -> (Vec<SpanRec>, u64) {
    let mut g = STORE.lock().unwrap_or_else(|e| e.into_inner());
    let spans = std::mem::take(&mut *g);
    (spans, DROPPED.swap(0, Ordering::Relaxed))
}

/// RAII span guard: records on drop when tracing or profiling is
/// enabled.  The name must be a static literal — the `metric-names`
/// lint keeps record sites in serve/ and runtime/native/ free of string
/// allocation.
pub struct SpanGuard {
    name: &'static str,
    args: [u32; 3],
    start: Option<Instant>,
}

#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    span_mnk(name, 0, 0, 0)
}

/// A span carrying GEMM shape args; the profiler attributes
/// `2·m·k·n` FLOPs to it (`model::flops::flops_for_shape`, the one
/// accounting source).  `(m, k, n)` are the *effective* output-rows /
/// contraction / output-cols extents, whatever the kernel's transpose
/// layout.
#[inline]
pub fn span_mnk(name: &'static str, m: usize, k: usize, n: usize) -> SpanGuard {
    if !ACTIVE.load(Ordering::Relaxed) {
        return SpanGuard { name, args: [0; 3], start: None };
    }
    DEPTH.with(|d| d.set(d.get() + 1));
    CHILD_NS.with(|c| c.borrow_mut().push(0));
    SpanGuard {
        name,
        args: [m as u32, k as u32, n as u32],
        start: Some(Instant::now()),
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(t0) = self.start else { return };
        let dur_ns = t0.elapsed().as_nanos() as u64;
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v.saturating_sub(1));
            v
        });
        // Streaming self-time: pop this span's child accumulator and
        // charge our total duration to the parent's (if any).
        let child_ns = CHILD_NS.with(|c| {
            let mut st = c.borrow_mut();
            let mine = st.pop().unwrap_or(0);
            if let Some(parent) = st.last_mut() {
                *parent += dur_ns;
            }
            mine
        });
        let self_ns = dur_ns.saturating_sub(child_ns);
        if profile::enabled() {
            profile::record(self.name, self.args, dur_ns, self_ns, depth);
        }
        if !ENABLED.load(Ordering::Relaxed) {
            return;
        }
        // disable() between span() and drop: the record is still taken —
        // a half-open trace window keeps its in-flight spans.
        let mut g = STORE.lock().unwrap_or_else(|e| e.into_inner());
        if g.len() >= MAX_EVENTS {
            DROPPED.fetch_add(1, Ordering::Relaxed);
            metrics::TRACE_DROPPED.inc();
            return;
        }
        g.push(SpanRec {
            name: self.name,
            tid: tid(),
            depth,
            start: t0,
            dur_ns,
            args: self.args,
        });
        let hwm = metrics::TRACE_BUF_HWM.get();
        if (g.len() as i64) > hwm {
            metrics::TRACE_BUF_HWM.set(g.len() as i64);
        }
    }
}

/// Drain the buffer and publish it at `path` as Chrome trace-event JSON.
/// Returns the number of spans written.
pub fn write_chrome(path: &Path) -> Result<usize> {
    let (spans, dropped) = take();
    let epoch = spans.iter().map(|s| s.start).min();
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let ts = epoch
                .map(|e| s.start.saturating_duration_since(e).as_nanos() as f64 / 1e3)
                .unwrap_or(0.0);
            let mut j = Json::from_pairs(vec![
                ("name", jstr(s.name)),
                ("cat", jstr("mutransfer")),
                ("ph", jstr("X")),
                ("pid", jnum(1.0)),
                ("tid", jnum(s.tid as f64)),
                ("ts", jnum(ts)),
                ("dur", jnum(s.dur_ns as f64 / 1e3)),
            ]);
            let mut args = Json::from_pairs(vec![("depth", jnum(s.depth as f64))]);
            if s.args != [0; 3] {
                args.set("m", jnum(s.args[0] as f64));
                args.set("k", jnum(s.args[1] as f64));
                args.set("n", jnum(s.args[2] as f64));
            }
            j.set("args", args);
            j
        })
        .collect();
    let mut doc = Json::from_pairs(vec![("traceEvents", Json::Arr(events))]);
    doc.set("displayTimeUnit", jstr("ms"));
    if dropped > 0 {
        doc.set("mutransfer_dropped_spans", jnum(dropped as f64));
    }
    fsio::write_atomic(path, doc.to_string().as_bytes())?;
    Ok(spans.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One sequential test for the whole lifecycle: the enable flag is
    /// process-global, so splitting these into parallel #[test]s would
    /// race each other.
    #[test]
    fn lifecycle_nesting_and_chrome_dump() {
        // disabled: spans are free and record nothing with our names
        {
            let _s = span("obs_test_never");
        }
        enable();
        {
            let _outer = span("obs_test_outer");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span_mnk("obs_test_inner", 3, 4, 5);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        disable();
        let dir = std::env::temp_dir().join("mutransfer_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let n = write_chrome(&path).unwrap();
        assert!(n >= 2, "expected at least the two test spans, got {n}");
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let find = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(|v| v.as_str()) == Some(name))
                .unwrap_or_else(|| panic!("span {name} missing"))
        };
        assert!(
            events
                .iter()
                .all(|e| e.get("name").and_then(|v| v.as_str()) != Some("obs_test_never")),
            "disabled span must not record"
        );
        let outer = find("obs_test_outer");
        let inner = find("obs_test_inner");
        assert_eq!(outer.get("ph").unwrap().as_str().unwrap(), "X");
        let od = outer.get("dur").unwrap().as_f64().unwrap();
        let id = inner.get("dur").unwrap().as_f64().unwrap();
        assert!(od >= id, "outer ({od}µs) must contain inner ({id}µs)");
        let odep = outer.get("args").unwrap().get("depth").unwrap().as_f64().unwrap();
        let idep = inner.get("args").unwrap().get("depth").unwrap().as_f64().unwrap();
        assert!(idep > odep, "inner depth {idep} must exceed outer {odep}");
        // shape args survive the dump; plain spans carry none
        let ia = inner.get("args").unwrap();
        assert_eq!(ia.get("m").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(ia.get("n").unwrap().as_f64().unwrap(), 5.0);
        assert!(outer.get("args").unwrap().get("m").is_none());
    }
}
