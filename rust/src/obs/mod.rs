//! `mutobs` — low-overhead observability for train + serve (DESIGN.md
//! §12).
//!
//! Three independent facilities share one design rule: *disabled or idle
//! telemetry costs (at most) a relaxed atomic load per site*, gated by
//! `benches/obs_overhead.rs` at ≤ 2% train-step overhead.
//!
//! * [`metrics`] — always-on lock-sparse counters/gauges/histograms with
//!   static `mutransfer_`-prefixed names (the `metric-names` lint),
//!   rendered as Prometheus text at `GET /metrics` and JSON at
//!   `GET /debug/metrics`;
//! * [`trace`] — opt-in hierarchical spans dumped as Chrome trace-event
//!   JSON (`train --trace-out`, `serve --trace-dir`);
//! * [`coords`] — opt-in live μ-coordinate telemetry: width-normalized
//!   per-tensor scale stats sampled during training, emitted as
//!   `Event::CoordStats`, served at `GET /jobs/:id/metrics`;
//! * [`profile`] — streaming perf attribution folded from the trace
//!   spans (self/child time per kind, per-GEMM-shape GFLOP/s), served
//!   at `GET /debug/profile` and by `mutransfer profile` (§13).

pub mod coords;
pub mod metrics;
pub mod profile;
pub mod trace;
