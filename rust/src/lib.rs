//! # mutransfer — zero-shot hyperparameter transfer via μP
//!
//! A Rust + JAX + Pallas reproduction of *"Tensor Programs V: Tuning Large
//! Neural Networks via Zero-Shot Hyperparameter Transfer"* (μTransfer).
//!
//! The stack has three layers (see DESIGN.md):
//!
//! 1. **Pallas kernels** (`python/compile/kernels/`) — matmul, fused 1/d
//!    attention, layernorm, fused per-tensor-LR optimizer steps.
//! 2. **JAX model graphs** (`python/compile/model.py`) — Transformer/MLP
//!    train-eval-coord steps, AOT-lowered once to HLO text artifacts.
//! 3. **This crate** — the coordinator: μP rule engine ([`mup`]), PJRT
//!    runtime ([`runtime`]), data substrates ([`data`]), training driver
//!    ([`train`]), HP search ([`tuner`]), sweep scheduler ([`sweep`]),
//!    μTransfer workflow ([`transfer`]), coordinate checking
//!    ([`coordcheck`]), and the experiment harness ([`exp`]) that
//!    regenerates every table and figure of the paper.
//!
//! Python never runs at run time: `make artifacts` is the only build-time
//! Python entry point, after which the `mutransfer` binary is
//! self-contained.

pub mod config;
pub mod coordcheck;
pub mod data;
pub mod exp;
pub mod init;
pub mod model;
pub mod mup;
pub mod report;
pub mod runtime;
pub mod stats;
pub mod sweep;
pub mod train;
pub mod transfer;
pub mod tuner;
pub mod util;

/// Default artifacts directory, overridable with `MUTRANSFER_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MUTRANSFER_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd so examples/tests work from any subdirectory.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

/// Default results directory for experiment outputs.
pub fn results_dir() -> std::path::PathBuf {
    let d = artifacts_dir()
        .parent()
        .map(|p| p.join("results"))
        .unwrap_or_else(|| "results".into());
    let _ = std::fs::create_dir_all(&d);
    d
}
