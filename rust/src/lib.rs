//! # mutransfer — zero-shot hyperparameter transfer via μP
//!
//! A Rust reproduction of *"Tensor Programs V: Tuning Large Neural
//! Networks via Zero-Shot Hyperparameter Transfer"* (μTransfer).
//!
//! The stack has three layers (see DESIGN.md):
//!
//! 1. **μP rule engine** ([`mup`], [`model`], [`init`]) — the paper's
//!    Tables 3/8/9 as an executable library: per-tensor init std, LR
//!    scales, and graph multipliers relative to a base shape.
//! 2. **Execution backends** ([`runtime`]) — a pluggable [`runtime::Backend`]
//!    behind one [`runtime::TrainSession`] API.  The default **native**
//!    backend runs the Transformer/MLP/ResMLP train-eval-coord steps in
//!    pure Rust (forward, hand-derived backward, fused per-tensor-LR
//!    Adam/SGD) with a built-in variant registry — hermetic on any box.
//!    The optional `pjrt` cargo feature executes AOT-lowered HLO
//!    artifacts (from `python/compile/aot.py`, JAX + Pallas kernels)
//!    through XLA instead (requires the Cargo.toml edits described
//!    there: uncomment the `xla` dep, set `pjrt = ["dep:xla"]`).
//! 3. **The harness** — data substrates ([`data`]), training driver
//!    ([`train`]), HP search ([`tuner`], including successive halving in
//!    [`tuner::sha`]), sweep scheduler ([`sweep`]), μTransfer workflow
//!    ([`transfer`]), coordinate checking ([`coordcheck`]), and the
//!    experiment harness ([`exp`]) that regenerates every table and
//!    figure of the paper.  Durable trial state lives in [`ckpt`]: a
//!    versioned, CRC-checked binary snapshot format plus
//!    `BackendSession::state`/`restore` capabilities, so interrupted
//!    runs/sweeps resume mid-trial bitwise-identically and adaptive
//!    tuners can pause/promote trials.  [`serve`] turns the harness into
//!    a service: a typed event bus every layer emits progress into, and a
//!    `mutransfer serve` daemon with a durable job registry, REST/SSE API
//!    and `GET /hp` — tune once on a proxy, serve the HPs to any scale.
//!    [`obs`] threads low-overhead observability through all of it:
//!    a lock-sparse metrics registry (`GET /metrics`), opt-in Chrome
//!    trace spans, and live μ-coordinate telemetry (`Event::CoordStats`,
//!    `GET /jobs/:id/metrics`).
//!
//! Python never runs at run time, and by default never at build time
//! either: `cargo test -q` exercises the whole verification story (golden
//! trajectories, μP property tests, sweep resume) natively.

pub mod analysis;
pub mod ckpt;
pub mod config;
pub mod coordcheck;
pub mod data;
pub mod exp;
pub mod init;
pub mod model;
pub mod mup;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod sweep;
pub mod train;
pub mod transfer;
pub mod tuner;
pub mod util;

/// Default artifacts directory, overridable with `MUTRANSFER_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MUTRANSFER_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd so examples/tests work from any subdirectory.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

/// Default results directory for experiment outputs.
pub fn results_dir() -> std::path::PathBuf {
    let d = artifacts_dir()
        .parent()
        .map(|p| p.join("results"))
        .unwrap_or_else(|| "results".into());
    let _ = std::fs::create_dir_all(&d);
    d
}
