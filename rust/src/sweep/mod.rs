//! Sweep scheduler: run a batch of training trials with journaling.
//!
//! A sweep = a list of [`crate::train::RunSpec`]-producing jobs executed
//! through a shared [`crate::runtime::Runtime`].  Results stream to a
//! JSON-lines journal so an interrupted sweep resumes where it left off —
//! the sweep is the "cluster scheduler" of the paper's benefit #4, scaled
//! to one box.
//!
//! Parallelism: [`Sweep::run`] fans pending jobs out across
//! [`Sweep::workers`] threads via `util::pool::run_indexed` whenever the
//! backend offers `Send` sessions (`Backend::session_send`; the native
//! backend does, the PJRT client declines and the sweep transparently
//! falls back to the sequential loop).  A mutex-synchronized journal
//! writer appends every completed trial exactly once; journal line
//! *order* varies with worker scheduling, but the journal is a keyed set,
//! so resume stays bit-exact regardless of worker count — and results
//! always return in job order (rust/tests/sweep_resume.rs pins all of
//! this).  The journal format is also what makes multi-process scale-out
//! trivial.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::data::source_for;
use crate::runtime::Runtime;
use crate::train::{prepare, run, PreparedRun, RunSpec};
use crate::tuner::{Assignment, Trial};
use crate::util::json::{self, jnum, Json};
use crate::util::pool;

/// One schedulable unit: an HP assignment to evaluate on a variant.
#[derive(Debug, Clone)]
pub struct Job {
    /// stable key for journaling / resume
    pub key: String,
    pub spec: RunSpec,
    pub assignment: Assignment,
    pub data_seed: u64,
}

/// Sweep outcome for one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub key: String,
    pub trial: Trial,
    pub train_curve: Vec<f64>,
    pub val_curve: Vec<(usize, f64)>,
    pub wall_secs: f64,
}

impl JobResult {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("key", json::jstr(&self.key)),
            ("trial", self.trial.to_json()),
            (
                "train_curve",
                json::jnums(&self.train_curve.iter().map(|&x| x).collect::<Vec<_>>()),
            ),
            (
                "val_curve",
                Json::Arr(
                    self.val_curve
                        .iter()
                        .map(|&(s, l)| Json::Arr(vec![jnum(s as f64), jnum(l)]))
                        .collect(),
                ),
            ),
            ("wall_secs", jnum(self.wall_secs)),
        ])
    }

    fn from_json(j: &Json) -> Option<JobResult> {
        let trial = j.get("trial")?;
        let mut assignment = Assignment::default();
        if let Json::Obj(m) = trial.get("assignment")? {
            for (k, v) in m {
                // null (a non-finite value) decodes to NaN like every other
                // numeric field — dropping the record would re-run the job
                assignment
                    .values
                    .insert(k.clone(), v.as_f64().unwrap_or(f64::NAN));
            }
        }
        Some(JobResult {
            key: j.get("key")?.as_str()?.to_string(),
            trial: Trial {
                assignment,
                // Non-finite f64s serialize as JSON null; every numeric
                // field must decode null back to NaN (not drop the record)
                // or a diverged job would silently re-run on resume.
                val_loss: trial.get("val_loss")?.as_f64().unwrap_or(f64::NAN),
                train_loss: trial.get("train_loss")?.as_f64().unwrap_or(f64::NAN),
                diverged: trial.get("diverged")?.as_bool()?,
                flops: trial.get("flops")?.as_f64().unwrap_or(f64::NAN),
            },
            train_curve: j
                .get("train_curve")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64().unwrap_or(f64::NAN))
                .collect(),
            val_curve: j
                .get("val_curve")?
                .as_arr()?
                .iter()
                .filter_map(|p| {
                    // Hand-edited / corrupted pairs must not panic the
                    // resume path: anything that isn't a 2-element array
                    // skips just this point (the record still loads).  A
                    // null step decodes to 0 and a null loss to NaN, so a
                    // point survives either half going non-finite.
                    let a = p.as_arr()?;
                    if a.len() < 2 {
                        return None;
                    }
                    Some((
                        a[0].as_f64().unwrap_or(0.0) as usize,
                        a[1].as_f64().unwrap_or(f64::NAN),
                    ))
                })
                .collect(),
            wall_secs: j.get("wall_secs")?.as_f64().unwrap_or(f64::NAN),
        })
    }
}

/// Journaled sweep runner.
pub struct Sweep<'rt> {
    rt: &'rt Runtime,
    journal_path: Option<PathBuf>,
    done: std::collections::BTreeMap<String, JobResult>,
    pub verbose: bool,
    workers: usize,
}

impl<'rt> Sweep<'rt> {
    /// Defaults to one worker (or `MUTRANSFER_WORKERS` from the env — the
    /// CI matrix sets it so every journal/resume test also exercises the
    /// parallel scheduler).  Use [`Sweep::with_workers`] to set it
    /// explicitly.
    pub fn new(rt: &'rt Runtime) -> Sweep<'rt> {
        Sweep {
            rt,
            journal_path: None,
            done: Default::default(),
            verbose: false,
            workers: pool::env_workers().unwrap_or(1),
        }
    }

    /// Fan jobs out across `n` worker threads (clamped to ≥1; further
    /// clamped at run time to the backend's `parallelism()` capability,
    /// so requesting 8 workers on the PJRT backend quietly runs
    /// sequentially rather than failing).
    pub fn with_workers(mut self, n: usize) -> Sweep<'rt> {
        self.workers = n.max(1);
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Attach a journal file; previously-completed jobs are loaded and
    /// skipped on re-run.
    pub fn with_journal(mut self, path: &Path) -> Result<Sweep<'rt>> {
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                if let Ok(j) = json::parse(line) {
                    if let Some(r) = JobResult::from_json(&j) {
                        self.done.insert(r.key.clone(), r);
                    }
                }
            }
        }
        self.journal_path = Some(path.to_path_buf());
        Ok(self)
    }

    pub fn completed(&self) -> usize {
        self.done.len()
    }

    /// Run all jobs (skipping journaled ones), returning results in job
    /// order.
    ///
    /// With `workers > 1` and a backend that offers `Send` sessions
    /// ([`crate::runtime::Backend::session_send`]), pending jobs fan out
    /// across worker threads; each completed trial is appended to the
    /// journal exactly once, as it finishes.  Execution is deterministic
    /// per job, so the results (and a later resume) are bit-identical to
    /// a sequential run regardless of worker count — only journal line
    /// order varies.
    pub fn run(&mut self, jobs: &[Job]) -> Result<Vec<JobResult>> {
        let workers = self
            .workers
            .min(self.rt.backend().parallelism())
            .clamp(1, jobs.len().max(1));
        if workers > 1 {
            if let Some(out) = self.run_parallel(jobs, workers)? {
                return Ok(out);
            }
            // backend declined Send sessions (PJRT): sequential fallback
        }
        self.run_sequential(jobs)
    }

    fn run_sequential(&mut self, jobs: &[Job]) -> Result<Vec<JobResult>> {
        let total = jobs.len();
        let mut out = Vec::with_capacity(total);
        for (i, job) in jobs.iter().enumerate() {
            if let Some(r) = self.done.get(&job.key) {
                out.push(r.clone());
                continue;
            }
            let t0 = std::time::Instant::now();
            let variant = self.rt.manifest().get(&job.spec.variant)?;
            let data = source_for(variant, job.data_seed);
            let rr = run(self.rt, &job.spec, data.as_ref())
                .with_context(|| format!("job {}", job.key))?;
            let result = JobResult {
                key: job.key.clone(),
                trial: Trial {
                    assignment: job.assignment.clone(),
                    val_loss: rr.best_val_loss(),
                    train_loss: rr.final_train_loss(),
                    diverged: rr.diverged,
                    flops: rr.flops,
                },
                train_curve: rr.train_losses.clone(),
                val_curve: rr.val_losses.clone(),
                wall_secs: t0.elapsed().as_secs_f64(),
            };
            if self.verbose {
                eprintln!(
                    "[{}/{}] {} -> train {:.4} val {:.4}{} ({:.1}s)",
                    i + 1,
                    total,
                    job.key,
                    result.trial.train_loss,
                    result.trial.val_loss,
                    if result.trial.diverged { " DIVERGED" } else { "" },
                    result.wall_secs,
                );
            }
            self.append_journal(&result)?;
            self.done.insert(job.key.clone(), result.clone());
            out.push(result);
        }
        Ok(out)
    }

    /// The multi-worker path.  Returns `Ok(None)` when the backend
    /// declines `Send` sessions, in which case nothing has executed and
    /// the caller falls back to the sequential loop.
    ///
    /// Pending jobs are prepared (sessions built) on this thread in
    /// chunks of `workers × 8` — enough runway that uneven trial
    /// durations still load-balance, without holding every session of a
    /// huge sweep resident at once — then executed via
    /// `pool::run_indexed`.  Workers append finished trials to the shared
    /// journal under a mutex, so every record lands exactly once and
    /// whole-line-atomically even though completion order is arbitrary.
    fn run_parallel(&mut self, jobs: &[Job], workers: usize) -> Result<Option<Vec<JobResult>>> {
        struct Prepared {
            key: String,
            assignment: Assignment,
            data_seed: u64,
            run: PreparedRun,
        }

        // open the journal once up front; worker threads share it
        let file = match &self.journal_path {
            Some(p) => Some(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(p)?,
            ),
            None => None,
        };
        let journal = Arc::new(Mutex::new(file));
        let finished = Arc::new(AtomicUsize::new(
            jobs.iter().filter(|j| self.done.contains_key(&j.key)).count(),
        ));
        let verbose = self.verbose;
        let total = jobs.len();

        let mut queue: Vec<&Job> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for job in jobs {
            // duplicate keys execute once; later occurrences resolve from
            // the done map, same as on the sequential path
            if !self.done.contains_key(&job.key) && seen.insert(job.key.clone()) {
                queue.push(job);
            }
        }

        let mut first_err: Option<anyhow::Error> = None;
        for chunk in queue.chunks(workers.saturating_mul(8).max(1)) {
            let mut prepared = Vec::with_capacity(chunk.len());
            for job in chunk {
                match prepare(self.rt, &job.spec)? {
                    Some(run) => prepared.push(Prepared {
                        key: job.key.clone(),
                        assignment: job.assignment.clone(),
                        data_seed: job.data_seed,
                        run,
                    }),
                    // static backend capability: if one job can't get a
                    // Send session, none can — nothing in this chunk ran
                    None => return Ok(None),
                }
            }
            let journal = journal.clone();
            let finished = finished.clone();
            let outcomes: Vec<Result<JobResult>> =
                pool::run_indexed(prepared, workers, move |_, p: Prepared| -> Result<JobResult> {
                    let t0 = std::time::Instant::now();
                    let data = source_for(p.run.variant(), p.data_seed);
                    let rr = p
                        .run
                        .execute(data.as_ref())
                        .with_context(|| format!("job {}", p.key))?;
                    let result = JobResult {
                        key: p.key,
                        trial: Trial {
                            assignment: p.assignment,
                            val_loss: rr.best_val_loss(),
                            train_loss: rr.final_train_loss(),
                            diverged: rr.diverged,
                            flops: rr.flops,
                        },
                        train_curve: rr.train_losses,
                        val_curve: rr.val_losses,
                        wall_secs: t0.elapsed().as_secs_f64(),
                    };
                    {
                        // exactly-once, whole-line append; recover a
                        // poisoned lock — the file is always between lines
                        let mut guard = journal.lock().unwrap_or_else(|e| e.into_inner());
                        if let Some(f) = guard.as_mut() {
                            writeln!(f, "{}", result.to_json().to_string())
                                .with_context(|| format!("journaling job {}", result.key))?;
                        }
                    }
                    if verbose {
                        let k = finished.fetch_add(1, Ordering::SeqCst) + 1;
                        eprintln!(
                            "[{k}/{total}] {} -> train {:.4} val {:.4}{} ({:.1}s)",
                            result.key,
                            result.trial.train_loss,
                            result.trial.val_loss,
                            if result.trial.diverged { " DIVERGED" } else { "" },
                            result.wall_secs,
                        );
                    }
                    Ok(result)
                });
            for outcome in outcomes {
                match outcome {
                    // journaled by the worker already; record for resume +
                    // result assembly
                    Ok(r) => {
                        self.done.insert(r.key.clone(), r);
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
            if first_err.is_some() {
                break; // sibling successes are journaled; abort like the
                       // sequential path would
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(Some(
            jobs.iter()
                .map(|j| {
                    self.done
                        .get(&j.key)
                        .cloned()
                        .expect("parallel sweep: every job completed or errored")
                })
                .collect(),
        ))
    }

    fn append_journal(&self, r: &JobResult) -> Result<()> {
        if let Some(p) = &self.journal_path {
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(p)?;
            writeln!(f, "{}", r.to_json().to_string())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobresult_json_roundtrip() {
        let r = JobResult {
            key: "k1".into(),
            trial: Trial {
                assignment: Assignment::single("lr", 0.01),
                val_loss: 2.5,
                train_loss: 2.4,
                diverged: false,
                flops: 1e9,
            },
            train_curve: vec![3.0, 2.8, 2.4],
            val_curve: vec![(10, 2.6), (20, 2.5)],
            wall_secs: 1.25,
        };
        let j = r.to_json();
        let back = JobResult::from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.key, "k1");
        assert_eq!(back.trial.assignment.values["lr"], 0.01);
        assert_eq!(back.train_curve, vec![3.0, 2.8, 2.4]);
        assert_eq!(back.val_curve, vec![(10, 2.6), (20, 2.5)]);
        assert!(!back.trial.diverged);
    }

    #[test]
    fn diverged_nan_roundtrip() {
        let r = JobResult {
            key: "k2".into(),
            trial: Trial {
                assignment: Assignment::default(),
                val_loss: f64::NAN,
                train_loss: f64::NAN,
                diverged: true,
                flops: 0.0,
            },
            train_curve: vec![f64::NAN],
            val_curve: vec![],
            wall_secs: 0.1,
        };
        let back = JobResult::from_json(&json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert!(back.trial.diverged);
        assert!(back.trial.val_loss.is_nan()); // null -> NaN
    }

    #[test]
    fn corrupt_val_curve_pairs_skip_the_point_not_the_record() {
        // Regression: a[0]/a[1] indexing panicked resume on a hand-edited
        // journal line with a short pair; now the bad pair is skipped, a
        // null step decodes to 0, and a null loss decodes to NaN.
        let line = r#"{"key":"k","trial":{"assignment":{"lr":0.1},"val_loss":1.0,"train_loss":1.0,"diverged":false,"flops":1.0},"train_curve":[1.0],"val_curve":[[10,2.5],[20],[],7,[null,2.25],[30,null]],"wall_secs":0.1}"#;
        let r = JobResult::from_json(&json::parse(line).unwrap()).unwrap();
        assert_eq!(r.key, "k");
        assert_eq!(r.val_curve.len(), 3);
        assert_eq!(r.val_curve[0], (10, 2.5));
        assert_eq!(r.val_curve[1], (0, 2.25)); // null step -> 0, point kept
        assert_eq!(r.val_curve[2].0, 30);
        assert!(r.val_curve[2].1.is_nan()); // null loss -> NaN, point kept
    }

    #[test]
    fn nan_flops_and_wall_secs_do_not_drop_the_record() {
        // Regression: flops/wall_secs used `?` on null while the losses
        // used unwrap_or(NAN), so a record with NaN flops deserialized to
        // None and the journal silently dropped it on resume.
        let mut assignment = Assignment::single("lr", 0.1);
        assignment.values.insert("sigma".into(), f64::NAN);
        let r = JobResult {
            key: "k3".into(),
            trial: Trial {
                assignment,
                val_loss: f64::NAN,
                train_loss: f64::NAN,
                diverged: true,
                flops: f64::NAN,
            },
            train_curve: vec![10.0, f64::NAN],
            val_curve: vec![],
            wall_secs: f64::NAN,
        };
        let back = JobResult::from_json(&json::parse(&r.to_json().to_string()).unwrap())
            .expect("NaN flops must still round-trip");
        assert_eq!(back.key, "k3");
        assert!(back.trial.flops.is_nan());
        assert!(back.wall_secs.is_nan());
        assert_eq!(back.trial.assignment.values["lr"], 0.1);
        assert!(back.trial.assignment.values["sigma"].is_nan());
        assert_eq!(back.train_curve[0], 10.0);
        assert!(back.train_curve[1].is_nan());
    }
}
