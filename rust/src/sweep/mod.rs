//! Sweep scheduler: run a batch of training trials with journaling.
//!
//! A sweep = a list of [`crate::train::RunSpec`]-producing jobs executed
//! through a shared [`crate::runtime::Runtime`].  Results stream to a
//! JSON-lines journal so an interrupted sweep resumes where it left off —
//! the sweep is the "cluster scheduler" of the paper's benefit #4, scaled
//! to one box.
//!
//! Note on parallelism: the scheduler itself is sequential today.  The
//! native backend's concrete types are all `Send` (unlike the PJRT
//! client), which is the prerequisite for thread-fan-out via
//! `util::pool` — but the current `Box<dyn Backend>`/`Box<dyn
//! BackendSession>` handles erase that marker, so multi-worker sweeps
//! additionally need a `Send`-bounded session handle (tracked in
//! ROADMAP.md).  The journal format is what makes multi-process
//! scale-out trivial either way, and resume is bit-exact
//! (rust/tests/sweep_resume.rs).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::source_for;
use crate::runtime::Runtime;
use crate::train::{run, RunSpec};
use crate::tuner::{Assignment, Trial};
use crate::util::json::{self, jnum, Json};

/// One schedulable unit: an HP assignment to evaluate on a variant.
#[derive(Debug, Clone)]
pub struct Job {
    /// stable key for journaling / resume
    pub key: String,
    pub spec: RunSpec,
    pub assignment: Assignment,
    pub data_seed: u64,
}

/// Sweep outcome for one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub key: String,
    pub trial: Trial,
    pub train_curve: Vec<f64>,
    pub val_curve: Vec<(usize, f64)>,
    pub wall_secs: f64,
}

impl JobResult {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("key", json::jstr(&self.key)),
            ("trial", self.trial.to_json()),
            (
                "train_curve",
                json::jnums(&self.train_curve.iter().map(|&x| x).collect::<Vec<_>>()),
            ),
            (
                "val_curve",
                Json::Arr(
                    self.val_curve
                        .iter()
                        .map(|&(s, l)| Json::Arr(vec![jnum(s as f64), jnum(l)]))
                        .collect(),
                ),
            ),
            ("wall_secs", jnum(self.wall_secs)),
        ])
    }

    fn from_json(j: &Json) -> Option<JobResult> {
        let trial = j.get("trial")?;
        let mut assignment = Assignment::default();
        if let Json::Obj(m) = trial.get("assignment")? {
            for (k, v) in m {
                // null (a non-finite value) decodes to NaN like every other
                // numeric field — dropping the record would re-run the job
                assignment
                    .values
                    .insert(k.clone(), v.as_f64().unwrap_or(f64::NAN));
            }
        }
        Some(JobResult {
            key: j.get("key")?.as_str()?.to_string(),
            trial: Trial {
                assignment,
                // Non-finite f64s serialize as JSON null; every numeric
                // field must decode null back to NaN (not drop the record)
                // or a diverged job would silently re-run on resume.
                val_loss: trial.get("val_loss")?.as_f64().unwrap_or(f64::NAN),
                train_loss: trial.get("train_loss")?.as_f64().unwrap_or(f64::NAN),
                diverged: trial.get("diverged")?.as_bool()?,
                flops: trial.get("flops")?.as_f64().unwrap_or(f64::NAN),
            },
            train_curve: j
                .get("train_curve")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64().unwrap_or(f64::NAN))
                .collect(),
            val_curve: j
                .get("val_curve")?
                .as_arr()?
                .iter()
                .filter_map(|p| {
                    let a = p.as_arr()?;
                    Some((a[0].as_f64()? as usize, a[1].as_f64().unwrap_or(f64::NAN)))
                })
                .collect(),
            wall_secs: j.get("wall_secs")?.as_f64().unwrap_or(f64::NAN),
        })
    }
}

/// Journaled sweep runner.
pub struct Sweep<'rt> {
    rt: &'rt Runtime,
    journal_path: Option<PathBuf>,
    done: std::collections::BTreeMap<String, JobResult>,
    pub verbose: bool,
}

impl<'rt> Sweep<'rt> {
    pub fn new(rt: &'rt Runtime) -> Sweep<'rt> {
        Sweep {
            rt,
            journal_path: None,
            done: Default::default(),
            verbose: false,
        }
    }

    /// Attach a journal file; previously-completed jobs are loaded and
    /// skipped on re-run.
    pub fn with_journal(mut self, path: &Path) -> Result<Sweep<'rt>> {
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                if let Ok(j) = json::parse(line) {
                    if let Some(r) = JobResult::from_json(&j) {
                        self.done.insert(r.key.clone(), r);
                    }
                }
            }
        }
        self.journal_path = Some(path.to_path_buf());
        Ok(self)
    }

    pub fn completed(&self) -> usize {
        self.done.len()
    }

    /// Run all jobs (skipping journaled ones), returning results in job
    /// order.
    pub fn run(&mut self, jobs: &[Job]) -> Result<Vec<JobResult>> {
        let total = jobs.len();
        let mut out = Vec::with_capacity(total);
        for (i, job) in jobs.iter().enumerate() {
            if let Some(r) = self.done.get(&job.key) {
                out.push(r.clone());
                continue;
            }
            let t0 = std::time::Instant::now();
            let variant = self.rt.manifest().get(&job.spec.variant)?;
            let data = source_for(variant, job.data_seed);
            let rr = run(self.rt, &job.spec, data.as_ref())
                .with_context(|| format!("job {}", job.key))?;
            let result = JobResult {
                key: job.key.clone(),
                trial: Trial {
                    assignment: job.assignment.clone(),
                    val_loss: rr.best_val_loss(),
                    train_loss: rr.final_train_loss(),
                    diverged: rr.diverged,
                    flops: rr.flops,
                },
                train_curve: rr.train_losses.clone(),
                val_curve: rr.val_losses.clone(),
                wall_secs: t0.elapsed().as_secs_f64(),
            };
            if self.verbose {
                eprintln!(
                    "[{}/{}] {} -> train {:.4} val {:.4}{} ({:.1}s)",
                    i + 1,
                    total,
                    job.key,
                    result.trial.train_loss,
                    result.trial.val_loss,
                    if result.trial.diverged { " DIVERGED" } else { "" },
                    result.wall_secs,
                );
            }
            self.append_journal(&result)?;
            self.done.insert(job.key.clone(), result.clone());
            out.push(result);
        }
        Ok(out)
    }

    fn append_journal(&self, r: &JobResult) -> Result<()> {
        if let Some(p) = &self.journal_path {
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(p)?;
            writeln!(f, "{}", r.to_json().to_string())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobresult_json_roundtrip() {
        let r = JobResult {
            key: "k1".into(),
            trial: Trial {
                assignment: Assignment::single("lr", 0.01),
                val_loss: 2.5,
                train_loss: 2.4,
                diverged: false,
                flops: 1e9,
            },
            train_curve: vec![3.0, 2.8, 2.4],
            val_curve: vec![(10, 2.6), (20, 2.5)],
            wall_secs: 1.25,
        };
        let j = r.to_json();
        let back = JobResult::from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.key, "k1");
        assert_eq!(back.trial.assignment.values["lr"], 0.01);
        assert_eq!(back.train_curve, vec![3.0, 2.8, 2.4]);
        assert_eq!(back.val_curve, vec![(10, 2.6), (20, 2.5)]);
        assert!(!back.trial.diverged);
    }

    #[test]
    fn diverged_nan_roundtrip() {
        let r = JobResult {
            key: "k2".into(),
            trial: Trial {
                assignment: Assignment::default(),
                val_loss: f64::NAN,
                train_loss: f64::NAN,
                diverged: true,
                flops: 0.0,
            },
            train_curve: vec![f64::NAN],
            val_curve: vec![],
            wall_secs: 0.1,
        };
        let back = JobResult::from_json(&json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert!(back.trial.diverged);
        assert!(back.trial.val_loss.is_nan()); // null -> NaN
    }

    #[test]
    fn nan_flops_and_wall_secs_do_not_drop_the_record() {
        // Regression: flops/wall_secs used `?` on null while the losses
        // used unwrap_or(NAN), so a record with NaN flops deserialized to
        // None and the journal silently dropped it on resume.
        let mut assignment = Assignment::single("lr", 0.1);
        assignment.values.insert("sigma".into(), f64::NAN);
        let r = JobResult {
            key: "k3".into(),
            trial: Trial {
                assignment,
                val_loss: f64::NAN,
                train_loss: f64::NAN,
                diverged: true,
                flops: f64::NAN,
            },
            train_curve: vec![10.0, f64::NAN],
            val_curve: vec![],
            wall_secs: f64::NAN,
        };
        let back = JobResult::from_json(&json::parse(&r.to_json().to_string()).unwrap())
            .expect("NaN flops must still round-trip");
        assert_eq!(back.key, "k3");
        assert!(back.trial.flops.is_nan());
        assert!(back.wall_secs.is_nan());
        assert_eq!(back.trial.assignment.values["lr"], 0.1);
        assert!(back.trial.assignment.values["sigma"].is_nan());
        assert_eq!(back.train_curve[0], 10.0);
        assert!(back.train_curve[1].is_nan());
    }
}
