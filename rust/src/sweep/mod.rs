//! Sweep scheduler: run a batch of training trials with journaling.
//!
//! A sweep = a list of [`crate::train::RunSpec`]-producing jobs executed
//! through a shared [`crate::runtime::Runtime`].  Results stream to a
//! JSON-lines journal so an interrupted sweep resumes where it left off —
//! the sweep is the "cluster scheduler" of the paper's benefit #4, scaled
//! to one box.
//!
//! Parallelism: [`Sweep::run`] fans pending jobs out across
//! [`Sweep::workers`] threads via `util::pool::run_indexed` whenever the
//! backend offers `Send` sessions (`Backend::session_send`; the native
//! backend does, the PJRT client declines and the sweep transparently
//! falls back to the sequential loop).  A mutex-synchronized journal
//! writer appends every completed trial exactly once; journal line
//! *order* varies with worker scheduling, but the journal is a keyed set,
//! so resume stays bit-exact regardless of worker count — and results
//! always return in job order (rust/tests/sweep_resume.rs pins all of
//! this).  The journal format is also what makes multi-process scale-out
//! trivial.
//!
//! Durable trial state: with [`Sweep::with_checkpoints`], every running
//! trial snapshots its model/optimizer state (via `train::CkptConfig` →
//! the [`crate::ckpt`] subsystem), the journal records each trial's
//! checkpoint path before it starts, and an interrupted sweep resumes
//! in-flight trials *mid-trial* instead of from step 0.  Each append is a
//! single write + fdatasync, and the loader tolerates a torn final line
//! by truncating back to the last complete record — so a kill at any
//! instant loses at most the unfinished tail of one trial.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::data::source_for;
use crate::init::rng::fold64;
use crate::runtime::Runtime;
use crate::serve::events::{Event, EventSink, StderrSink};
use crate::train::{prepare, run_ckpt_with, CkptConfig, PreparedRun, RunSpec};
use crate::tuner::{Assignment, Trial};
use crate::util::json::{self, jnum, Json};
use crate::util::pool;

/// One schedulable unit: an HP assignment to evaluate on a variant.
#[derive(Debug, Clone)]
pub struct Job {
    /// stable key for journaling / resume
    pub key: String,
    pub spec: RunSpec,
    pub assignment: Assignment,
    pub data_seed: u64,
    /// stable checkpoint identity, shared across re-submissions of the
    /// same underlying trial: SHA re-keys each rung (`…@r<budget>`) but
    /// chains snapshots through this id so a promoted trial resumes from
    /// its previous rung instead of step 0.  `None` = use `key`.
    pub ckpt_id: Option<String>,
}

impl Job {
    /// The identity a trial's checkpoint file is keyed by.
    pub fn ckpt_key(&self) -> &str {
        self.ckpt_id.as_deref().unwrap_or(&self.key)
    }
}

/// Collision-safe file name for a trial checkpoint: a sanitized prefix of
/// the id (human-greppable) plus a 64-bit hash of the full id.
fn ckpt_file_name(id: &str) -> String {
    let h = fold64(0x9E37_79B9_7F4A_7C15, id.as_bytes());
    let mut safe: String = id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect();
    safe.truncate(80);
    format!("{safe}-{h:016x}.ckpt")
}

/// Append one journal record as a single write followed by fdatasync: a
/// crash can tear at most the final line, which `with_journal` recovers
/// from by truncating back to the last complete record.
fn append_line(path: &Path, line: &str) -> Result<()> {
    let _sp = crate::obs::trace::span("journal_fsync");
    let t0 = std::time::Instant::now();
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    let mut bytes = line.as_bytes().to_vec();
    bytes.push(b'\n');
    f.write_all(&bytes)?;
    f.sync_data()?;
    crate::obs::metrics::JOURNAL_FSYNC.observe_since(t0);
    Ok(())
}

/// Sweep outcome for one job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub key: String,
    pub trial: Trial,
    pub train_curve: Vec<f64>,
    pub val_curve: Vec<(usize, f64)>,
    pub wall_secs: f64,
}

impl JobResult {
    fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("key", json::jstr(&self.key)),
            ("trial", self.trial.to_json()),
            (
                "train_curve",
                json::jnums(&self.train_curve.iter().map(|&x| x).collect::<Vec<_>>()),
            ),
            (
                "val_curve",
                Json::Arr(
                    self.val_curve
                        .iter()
                        .map(|&(s, l)| Json::Arr(vec![jnum(s as f64), jnum(l)]))
                        .collect(),
                ),
            ),
            ("wall_secs", jnum(self.wall_secs)),
        ])
    }

    fn from_json(j: &Json) -> Option<JobResult> {
        let trial = j.get("trial")?;
        let mut assignment = Assignment::default();
        if let Json::Obj(m) = trial.get("assignment")? {
            for (k, v) in m {
                // null (a non-finite value) decodes to NaN like every other
                // numeric field — dropping the record would re-run the job
                assignment
                    .values
                    .insert(k.clone(), v.as_f64().unwrap_or(f64::NAN));
            }
        }
        Some(JobResult {
            key: j.get("key")?.as_str()?.to_string(),
            trial: Trial {
                assignment,
                // Non-finite f64s serialize as JSON null; every numeric
                // field must decode null back to NaN (not drop the record)
                // or a diverged job would silently re-run on resume.
                val_loss: trial.get("val_loss")?.as_f64().unwrap_or(f64::NAN),
                train_loss: trial.get("train_loss")?.as_f64().unwrap_or(f64::NAN),
                diverged: trial.get("diverged")?.as_bool()?,
                flops: trial.get("flops")?.as_f64().unwrap_or(f64::NAN),
            },
            train_curve: j
                .get("train_curve")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64().unwrap_or(f64::NAN))
                .collect(),
            val_curve: j
                .get("val_curve")?
                .as_arr()?
                .iter()
                .filter_map(|p| {
                    // Hand-edited / corrupted pairs must not panic the
                    // resume path: anything that isn't a 2-element array
                    // skips just this point (the record still loads).  A
                    // null step decodes to 0 and a null loss to NaN, so a
                    // point survives either half going non-finite.
                    let a = p.as_arr()?;
                    if a.len() < 2 {
                        return None;
                    }
                    Some((
                        a[0].as_f64().unwrap_or(0.0) as usize,
                        a[1].as_f64().unwrap_or(f64::NAN),
                    ))
                })
                .collect(),
            wall_secs: j.get("wall_secs")?.as_f64().unwrap_or(f64::NAN),
        })
    }
}

/// Journaled sweep runner.
pub struct Sweep<'rt> {
    rt: &'rt Runtime,
    journal_path: Option<PathBuf>,
    done: std::collections::BTreeMap<String, JobResult>,
    pub verbose: bool,
    workers: usize,
    ckpt_dir: Option<PathBuf>,
    ckpt_every: usize,
    /// ckpt-id → snapshot path, loaded from the journal's `ckpt` records
    /// on resume (deterministically re-derived when absent)
    ckpt_records: std::collections::BTreeMap<String, PathBuf>,
    /// where progress events go; `None` = a stderr sink whose progress
    /// lines follow [`Sweep::verbose`] (the pre-bus CLI output)
    sink: Option<Arc<dyn EventSink>>,
    /// fair-share lease on the daemon's shared worker budget: each trial
    /// holds a permit while it executes, so concurrent jobs split the
    /// machine instead of multiplying thread counts.  `None` = offline
    /// sweep, no throttling.
    budget: Option<Arc<pool::BudgetLease>>,
}

impl<'rt> Sweep<'rt> {
    /// Defaults to one worker (or `MUTRANSFER_WORKERS` from the env — the
    /// CI matrix sets it so every journal/resume test also exercises the
    /// parallel scheduler).  Use [`Sweep::with_workers`] to set it
    /// explicitly.
    pub fn new(rt: &'rt Runtime) -> Sweep<'rt> {
        Sweep {
            rt,
            journal_path: None,
            done: Default::default(),
            verbose: false,
            workers: pool::env_workers().unwrap_or(1),
            ckpt_dir: None,
            ckpt_every: 0,
            ckpt_records: Default::default(),
            sink: None,
            budget: None,
        }
    }

    /// Route every progress/warning event this sweep (and the trials it
    /// drives) produces into `sink` — the serve daemon passes each job's
    /// [`crate::serve::events::EventBus`] here.  Without a sink the
    /// default stderr sink reproduces the pre-bus CLI output exactly.
    pub fn with_sink(mut self, sink: Arc<dyn EventSink>) -> Sweep<'rt> {
        self.sink = Some(sink);
        self
    }

    /// Throttle trial execution through a fair-share lease on a shared
    /// worker budget ([`pool::FairBudget`]).  Each trial blocks for a
    /// permit before executing and releases it when done, so N concurrent
    /// sweeps converge on budget/N effective workers each.  Scheduling
    /// only — results stay bit-identical to an unthrottled run.
    pub fn with_budget(mut self, lease: Arc<pool::BudgetLease>) -> Sweep<'rt> {
        self.budget = Some(lease);
        self
    }

    /// The effective event sink (explicit sink, else a stderr sink whose
    /// progress lines follow [`Sweep::verbose`]).  SHA uses this to emit
    /// its rung-promotion events onto the same bus.
    pub fn sink(&self) -> Arc<dyn EventSink> {
        self.sink
            .clone()
            .unwrap_or_else(|| Arc::new(StderrSink::new(self.verbose)))
    }

    /// Fan jobs out across `n` worker threads (clamped to ≥1; further
    /// clamped at run time to the backend's `parallelism()` capability,
    /// so requesting 8 workers on the PJRT backend quietly runs
    /// sequentially rather than failing).
    pub fn with_workers(mut self, n: usize) -> Sweep<'rt> {
        self.workers = n.max(1);
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Attach a journal file; previously-completed jobs are loaded and
    /// skipped on re-run, and journaled checkpoint paths are picked up so
    /// interrupted trials resume mid-flight.
    ///
    /// Crash consistency: a kill between `write` and `fsync` can leave a
    /// torn final line.  Instead of failing (or silently dropping every
    /// later append into the garbage), the loader truncates the file back
    /// to the end of the last complete JSON record and resumes from there
    /// — only the torn record's trial re-runs.
    pub fn with_journal(mut self, path: &Path) -> Result<Sweep<'rt>> {
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let mut pos = 0usize; // byte offset just past the current line
            let mut good_end = 0usize; // … past the last usable record
            let mut missing_newline = false;
            for line in text.split_inclusive('\n') {
                pos += line.len();
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    good_end = pos;
                    continue;
                }
                match json::parse(trimmed) {
                    Ok(j) => {
                        if let Some(c) = j.get("ckpt") {
                            if let (Some(id), Some(p)) = (
                                c.get("id").and_then(|v| v.as_str()),
                                c.get("path").and_then(|v| v.as_str()),
                            ) {
                                self.ckpt_records
                                    .insert(id.to_string(), PathBuf::from(p));
                            }
                        } else if let Some(r) = JobResult::from_json(&j) {
                            self.done.insert(r.key.clone(), r);
                        }
                        good_end = pos;
                        missing_newline = !line.ends_with('\n');
                    }
                    Err(_) => {
                        // unusable record: skipped.  If nothing usable
                        // follows, good_end stays put and the torn tail is
                        // truncated away below.
                    }
                }
            }
            // Only a file in which we actually recognized journal records
            // (results or ckpt paths) may ever be modified — pointing
            // --resume-from at some other non-empty file must be an error,
            // not an append target and never a truncation victim.
            let recognized = !self.done.is_empty() || !self.ckpt_records.is_empty();
            if good_end < text.len() {
                if !recognized {
                    bail!(
                        "{} does not look like a sweep journal (no records recognized); refusing to use it",
                        path.display()
                    );
                }
                // A crash mid-append tears at most ONE trailing line, and a
                // torn write is a strict prefix — so the crash signature is
                // exactly "one unparseable final line with no newline".
                // Only that gets truncated; complete-but-unparseable lines
                // (hand-edited corruption) are skipped without modifying
                // the file.
                let tail = &text[good_end..];
                let torn_single = !tail.ends_with('\n') && !tail.trim_end().contains('\n');
                if torn_single {
                    // torn final record: physically drop it so future
                    // appends can't merge into the garbage
                    let f = std::fs::OpenOptions::new().write(true).open(path)?;
                    f.set_len(good_end as u64)?;
                    f.sync_all()?;
                }
            } else if missing_newline {
                if !recognized {
                    bail!(
                        "{} does not look like a sweep journal (no records recognized); refusing to use it",
                        path.display()
                    );
                }
                // final record parsed but its newline is missing: complete
                // the line so the next append starts fresh
                let mut f = std::fs::OpenOptions::new().append(true).open(path)?;
                f.write_all(b"\n")?;
                f.sync_data()?;
            }
        }
        self.journal_path = Some(path.to_path_buf());
        Ok(self)
    }

    /// Enable durable trial state under `dir` (created if needed): every
    /// running trial snapshots to its own file every `every` steps (0 =
    /// only at trial end), an interrupted sweep resumes such trials
    /// mid-flight instead of from step 0, and SHA rungs chain through the
    /// same files.  The journal records each trial's checkpoint path the
    /// first time the trial starts.  Backends without state capture (PJRT)
    /// silently run without checkpoints.
    pub fn with_checkpoints(mut self, dir: &Path, every: usize) -> Result<Sweep<'rt>> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        self.ckpt_dir = Some(dir.to_path_buf());
        self.ckpt_every = every;
        Ok(self)
    }

    /// Whether durable trial state is configured ([`Sweep::with_checkpoints`]).
    pub fn checkpoints_enabled(&self) -> bool {
        self.ckpt_dir.is_some()
    }

    /// Where a trial's snapshot lives; `None` when checkpointing is off.
    pub fn checkpoint_path(&self, ckpt_key: &str) -> Option<PathBuf> {
        let dir = self.ckpt_dir.as_ref()?;
        Some(
            self.ckpt_records
                .get(ckpt_key)
                .cloned()
                .unwrap_or_else(|| dir.join(ckpt_file_name(ckpt_key))),
        )
    }

    /// Delete a trial's snapshot (SHA prunes eliminated trials; harmless
    /// if the file never existed).
    pub fn remove_checkpoint(&self, ckpt_key: &str) {
        if let Some(p) = self.checkpoint_path(ckpt_key) {
            let _ = std::fs::remove_file(p);
        }
    }

    fn ckpt_cfg(&self, job: &Job) -> Option<CkptConfig> {
        self.checkpoint_path(job.ckpt_key()).map(|path| CkptConfig {
            every: self.ckpt_every,
            path,
        })
    }

    /// Journal a trial's checkpoint path before it starts executing, so a
    /// crash mid-trial leaves the path discoverable.  Idempotent per id.
    fn journal_ckpt_record(&mut self, job: &Job) -> Result<()> {
        if self.ckpt_dir.is_none() || self.journal_path.is_none() {
            return Ok(());
        }
        let id = job.ckpt_key().to_string();
        if self.ckpt_records.contains_key(&id) {
            return Ok(());
        }
        let path = self
            .checkpoint_path(&id)
            .expect("ckpt_dir is set");
        let rec = Json::from_pairs(vec![(
            "ckpt",
            Json::from_pairs(vec![
                ("id", json::jstr(&id)),
                ("path", json::jstr(&path.to_string_lossy())),
            ]),
        )]);
        let jp = self.journal_path.clone().expect("journal_path is set");
        append_line(&jp, &rec.to_string())?;
        self.ckpt_records.insert(id, path);
        Ok(())
    }

    pub fn completed(&self) -> usize {
        self.done.len()
    }

    /// Run all jobs (skipping journaled ones), returning results in job
    /// order.
    ///
    /// With `workers > 1` and a backend that offers `Send` sessions
    /// ([`crate::runtime::Backend::session_send`]), pending jobs fan out
    /// across worker threads; each completed trial is appended to the
    /// journal exactly once, as it finishes.  Execution is deterministic
    /// per job, so the results (and a later resume) are bit-identical to
    /// a sequential run regardless of worker count — only journal line
    /// order varies.
    pub fn run(&mut self, jobs: &[Job]) -> Result<Vec<JobResult>> {
        let sink = self.sink();
        let workers = self
            .workers
            .min(self.rt.backend().parallelism())
            .clamp(1, jobs.len().max(1));
        let out = if workers > 1 {
            match self.run_parallel(jobs, workers, &sink)? {
                Some(out) => out,
                // backend declined Send sessions (PJRT): sequential fallback
                None => self.run_sequential(jobs, &sink)?,
            }
        } else {
            self.run_sequential(jobs, &sink)?
        };
        sink.emit(&Event::SweepDone { total: jobs.len() });
        Ok(out)
    }

    fn run_sequential(
        &mut self,
        jobs: &[Job],
        sink: &Arc<dyn EventSink>,
    ) -> Result<Vec<JobResult>> {
        let total = jobs.len();
        let mut out = Vec::with_capacity(total);
        for (i, job) in jobs.iter().enumerate() {
            if let Some(r) = self.done.get(&job.key) {
                out.push(r.clone());
                continue;
            }
            // fair-share: hold a budget permit for the trial's duration
            let _permit = self.budget.as_ref().map(|b| b.acquire());
            let t0 = std::time::Instant::now();
            self.journal_ckpt_record(job)?;
            let ckpt = self.ckpt_cfg(job);
            let variant = self.rt.manifest().get(&job.spec.variant)?;
            let data = source_for(variant, job.data_seed);
            sink.emit(&Event::TrialStarted { key: job.key.clone() });
            let rr = run_ckpt_with(
                self.rt,
                &job.spec,
                data.as_ref(),
                ckpt.as_ref(),
                sink.as_ref(),
                &job.key,
            )
            .with_context(|| format!("job {}", job.key))?;
            let result = JobResult {
                key: job.key.clone(),
                trial: Trial {
                    assignment: job.assignment.clone(),
                    val_loss: rr.best_val_loss(),
                    train_loss: rr.final_train_loss(),
                    diverged: rr.diverged,
                    flops: rr.flops,
                },
                train_curve: rr.train_losses.clone(),
                val_curve: rr.val_losses.clone(),
                wall_secs: t0.elapsed().as_secs_f64(),
            };
            sink.emit(&Event::TrialFinished {
                key: job.key.clone(),
                ordinal: i + 1,
                total,
                train_loss: result.trial.train_loss,
                val_loss: result.trial.val_loss,
                diverged: result.trial.diverged,
                wall_secs: result.wall_secs,
            });
            self.append_journal(&result)?;
            self.done.insert(job.key.clone(), result.clone());
            out.push(result);
        }
        Ok(out)
    }

    /// The multi-worker path.  Returns `Ok(None)` when the backend
    /// declines `Send` sessions, in which case nothing has executed and
    /// the caller falls back to the sequential loop.
    ///
    /// Pending jobs are prepared (sessions built) on this thread in
    /// chunks of `workers × 8` — enough runway that uneven trial
    /// durations still load-balance, without holding every session of a
    /// huge sweep resident at once — then executed via
    /// `pool::run_indexed`.  Workers append finished trials to the shared
    /// journal under a mutex, so every record lands exactly once and
    /// whole-line-atomically even though completion order is arbitrary.
    fn run_parallel(
        &mut self,
        jobs: &[Job],
        workers: usize,
        sink: &Arc<dyn EventSink>,
    ) -> Result<Option<Vec<JobResult>>> {
        struct Prepared {
            key: String,
            assignment: Assignment,
            data_seed: u64,
            run: PreparedRun,
        }

        // open the journal once up front; worker threads share it
        let file = match &self.journal_path {
            Some(p) => Some(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(p)?,
            ),
            None => None,
        };
        let journal = Arc::new(Mutex::new(file));
        let finished = Arc::new(AtomicUsize::new(
            jobs.iter().filter(|j| self.done.contains_key(&j.key)).count(),
        ));
        let total = jobs.len();

        let mut queue: Vec<&Job> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for job in jobs {
            // duplicate keys execute once; later occurrences resolve from
            // the done map, same as on the sequential path
            if !self.done.contains_key(&job.key) && seen.insert(job.key.clone()) {
                queue.push(job);
            }
        }

        let mut first_err: Option<anyhow::Error> = None;
        for chunk in queue.chunks(workers.saturating_mul(8).max(1)) {
            let mut prepared = Vec::with_capacity(chunk.len());
            for job in chunk {
                match prepare(self.rt, &job.spec)? {
                    Some(run) => {
                        // journal the checkpoint path before anything
                        // executes, so a crash mid-trial leaves it findable
                        self.journal_ckpt_record(job)?;
                        let run = match self.ckpt_cfg(job) {
                            Some(cfg) => run.with_checkpoint(cfg),
                            None => run,
                        };
                        let run = run.with_emitter(sink.clone(), &job.key);
                        prepared.push(Prepared {
                            key: job.key.clone(),
                            assignment: job.assignment.clone(),
                            data_seed: job.data_seed,
                            run,
                        })
                    }
                    // static backend capability: if one job can't get a
                    // Send session, none can — nothing in this chunk ran
                    None => return Ok(None),
                }
            }
            let journal = journal.clone();
            let finished = finished.clone();
            let sink = sink.clone();
            let budget = self.budget.clone();
            let outcomes: Vec<Result<JobResult>> =
                pool::run_indexed(prepared, workers, move |_, p: Prepared| -> Result<JobResult> {
                    // fair-share: each worker's trial holds one permit
                    let _permit = budget.as_ref().map(|b| b.acquire());
                    let t0 = std::time::Instant::now();
                    let data = source_for(p.run.variant(), p.data_seed);
                    sink.emit(&Event::TrialStarted { key: p.key.clone() });
                    let rr = p
                        .run
                        .execute(data.as_ref())
                        .with_context(|| format!("job {}", p.key))?;
                    let result = JobResult {
                        key: p.key,
                        trial: Trial {
                            assignment: p.assignment,
                            val_loss: rr.best_val_loss(),
                            train_loss: rr.final_train_loss(),
                            diverged: rr.diverged,
                            flops: rr.flops,
                        },
                        train_curve: rr.train_losses,
                        val_curve: rr.val_losses,
                        wall_secs: t0.elapsed().as_secs_f64(),
                    };
                    {
                        // exactly-once, whole-line append; recover a
                        // poisoned lock — the file is always between lines.
                        // One write_all + fdatasync per record: a crash can
                        // tear at most the final line, which with_journal
                        // truncates away on resume.
                        let mut guard = journal.lock().unwrap_or_else(|e| e.into_inner());
                        if let Some(f) = guard.as_mut() {
                            let _sp = crate::obs::trace::span("journal_fsync");
                            let jt0 = std::time::Instant::now();
                            let mut bytes = result.to_json().to_string().into_bytes();
                            bytes.push(b'\n');
                            f.write_all(&bytes)
                                .with_context(|| format!("journaling job {}", result.key))?;
                            f.sync_data()
                                .with_context(|| format!("syncing journal for {}", result.key))?;
                            crate::obs::metrics::JOURNAL_FSYNC.observe_since(jt0);
                        }
                    }
                    let k = finished.fetch_add(1, Ordering::SeqCst) + 1;
                    sink.emit(&Event::TrialFinished {
                        key: result.key.clone(),
                        ordinal: k,
                        total,
                        train_loss: result.trial.train_loss,
                        val_loss: result.trial.val_loss,
                        diverged: result.trial.diverged,
                        wall_secs: result.wall_secs,
                    });
                    Ok(result)
                });
            for outcome in outcomes {
                match outcome {
                    // journaled by the worker already; record for resume +
                    // result assembly
                    Ok(r) => {
                        self.done.insert(r.key.clone(), r);
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
            if first_err.is_some() {
                break; // sibling successes are journaled; abort like the
                       // sequential path would
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(Some(
            jobs.iter()
                .map(|j| {
                    self.done
                        .get(&j.key)
                        .cloned()
                        .expect("parallel sweep: every job completed or errored")
                })
                .collect(),
        ))
    }

    fn append_journal(&self, r: &JobResult) -> Result<()> {
        if let Some(p) = &self.journal_path {
            append_line(p, &r.to_json().to_string())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ckpt_file_names_are_stable_safe_and_collision_resistant() {
        let a = ckpt_file_name("transfer/proxy/3");
        assert_eq!(a, ckpt_file_name("transfer/proxy/3"), "must be deterministic");
        assert!(a.ends_with(".ckpt"));
        assert!(!a.contains('/'), "path separators must be sanitized: {a}");
        // same sanitized prefix, different ids -> different hashes
        let b = ckpt_file_name("transfer:proxy:3");
        assert_ne!(a, b);
        // long ids stay bounded
        let long = ckpt_file_name(&"x".repeat(500));
        assert!(long.len() < 120, "{}", long.len());
    }

    #[test]
    fn jobresult_json_roundtrip() {
        let r = JobResult {
            key: "k1".into(),
            trial: Trial {
                assignment: Assignment::single("lr", 0.01),
                val_loss: 2.5,
                train_loss: 2.4,
                diverged: false,
                flops: 1e9,
            },
            train_curve: vec![3.0, 2.8, 2.4],
            val_curve: vec![(10, 2.6), (20, 2.5)],
            wall_secs: 1.25,
        };
        let j = r.to_json();
        let back = JobResult::from_json(&json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.key, "k1");
        assert_eq!(back.trial.assignment.values["lr"], 0.01);
        assert_eq!(back.train_curve, vec![3.0, 2.8, 2.4]);
        assert_eq!(back.val_curve, vec![(10, 2.6), (20, 2.5)]);
        assert!(!back.trial.diverged);
    }

    #[test]
    fn diverged_nan_roundtrip() {
        let r = JobResult {
            key: "k2".into(),
            trial: Trial {
                assignment: Assignment::default(),
                val_loss: f64::NAN,
                train_loss: f64::NAN,
                diverged: true,
                flops: 0.0,
            },
            train_curve: vec![f64::NAN],
            val_curve: vec![],
            wall_secs: 0.1,
        };
        let back = JobResult::from_json(&json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert!(back.trial.diverged);
        assert!(back.trial.val_loss.is_nan()); // null -> NaN
    }

    #[test]
    fn corrupt_val_curve_pairs_skip_the_point_not_the_record() {
        // Regression: a[0]/a[1] indexing panicked resume on a hand-edited
        // journal line with a short pair; now the bad pair is skipped, a
        // null step decodes to 0, and a null loss decodes to NaN.
        let line = r#"{"key":"k","trial":{"assignment":{"lr":0.1},"val_loss":1.0,"train_loss":1.0,"diverged":false,"flops":1.0},"train_curve":[1.0],"val_curve":[[10,2.5],[20],[],7,[null,2.25],[30,null]],"wall_secs":0.1}"#;
        let r = JobResult::from_json(&json::parse(line).unwrap()).unwrap();
        assert_eq!(r.key, "k");
        assert_eq!(r.val_curve.len(), 3);
        assert_eq!(r.val_curve[0], (10, 2.5));
        assert_eq!(r.val_curve[1], (0, 2.25)); // null step -> 0, point kept
        assert_eq!(r.val_curve[2].0, 30);
        assert!(r.val_curve[2].1.is_nan()); // null loss -> NaN, point kept
    }

    #[test]
    fn nan_flops_and_wall_secs_do_not_drop_the_record() {
        // Regression: flops/wall_secs used `?` on null while the losses
        // used unwrap_or(NAN), so a record with NaN flops deserialized to
        // None and the journal silently dropped it on resume.
        let mut assignment = Assignment::single("lr", 0.1);
        assignment.values.insert("sigma".into(), f64::NAN);
        let r = JobResult {
            key: "k3".into(),
            trial: Trial {
                assignment,
                val_loss: f64::NAN,
                train_loss: f64::NAN,
                diverged: true,
                flops: f64::NAN,
            },
            train_curve: vec![10.0, f64::NAN],
            val_curve: vec![],
            wall_secs: f64::NAN,
        };
        let back = JobResult::from_json(&json::parse(&r.to_json().to_string()).unwrap())
            .expect("NaN flops must still round-trip");
        assert_eq!(back.key, "k3");
        assert!(back.trial.flops.is_nan());
        assert!(back.wall_secs.is_nan());
        assert_eq!(back.trial.assignment.values["lr"], 0.1);
        assert!(back.trial.assignment.values["sigma"].is_nan());
        assert_eq!(back.train_curve[0], 10.0);
        assert!(back.train_curve[1].is_nan());
    }
}
