//! Durable trial state: checkpoint/restore for training runs
//! (DESIGN.md §7).
//!
//! A [`Snapshot`] is the complete, host-side image of one training trial
//! at a step boundary: the variant name, every parameter and optimizer
//! moment tensor ([`crate::runtime::ModelState`] order, named and shaped
//! by the variant's param specs), the step counter, the loss curves
//! recorded so far, and — for stateful data sources — an
//! [`crate::init::rng::RngState`].  Restoring a snapshot into a fresh
//! session and continuing the drive loop produces a **bitwise identical**
//! trajectory to the uninterrupted run (pinned by
//! `rust/tests/ckpt_resume.rs`): tensors round-trip as raw little-endian
//! f32 bits, losses as raw f64 bits, and the repo's data substrates are
//! pure functions of (seed, split, step), so the persisted step counter
//! *is* the data cursor.
//!
//! The byte format lives in [`format`]: magic + version + shape manifest
//! + per-section CRC32, written tmp-file-then-rename so a crash never
//! leaves a torn checkpoint under the final name.  Backends without state
//! capture (PJRT) decline via `BackendSession::state`, and every caller
//! falls back to running from step 0.

pub mod format;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::init::rng::RngState;
use crate::runtime::backend::ModelState;
use crate::runtime::manifest::Variant;
use self::format::Section;

/// How far a run had progressed when the snapshot was taken.
#[derive(Debug, Clone, PartialEq)]
pub struct RunProgress {
    /// optimizer steps completed (also the data-stream cursor)
    pub steps_done: usize,
    /// true for the end-of-run snapshot (the run finished or diverged);
    /// false for a periodic mid-run snapshot
    pub complete: bool,
    pub diverged: bool,
    /// FLOPs spent so far (restored so resumed totals match uninterrupted)
    pub flops: f64,
    pub train_losses: Vec<f64>,
    /// (step, val_loss) pairs recorded so far
    pub val_losses: Vec<(usize, f64)>,
}

/// One trial frozen at a step boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// manifest variant this state belongs to (restore refuses others)
    pub variant: String,
    /// fingerprint of the run configuration that produced this state
    /// (`RunSpec::trajectory_fingerprint`: parametrization, HPs, base
    /// shape, seed, schedule — everything except the step budget).  Resume
    /// refuses a snapshot whose fingerprint does not match, so changed HPs
    /// can never be silently glued onto old state.
    pub spec_fp: u64,
    pub n_params: usize,
    pub progress: RunProgress,
    /// named state tensors: the parameters (manifest names) followed by
    /// the optimizer-state blocks (`opt0.<name>`, `opt1.<name>`, …) —
    /// the same order as [`crate::runtime::BackendSession::param`]
    pub tensors: Vec<(String, Vec<f32>)>,
    /// shapes parallel to `tensors` (the file's shape manifest)
    pub shapes: Vec<Vec<usize>>,
    /// data-RNG stream state, for sources that are not (seed, step)-pure
    pub data_rng: Option<RngState>,
}

const SEC_VARIANT: &str = "variant";
const SEC_META: &str = "meta";
const SEC_FLOPS: &str = "flops";
const SEC_TRAIN: &str = "train_losses";
const SEC_VAL_STEPS: &str = "val_steps";
const SEC_VAL_LOSSES: &str = "val_losses";
const SEC_RNG: &str = "data_rng";
const TENSOR_PREFIX: &str = "t:";

impl Snapshot {
    /// Assemble a snapshot from a backend state capture, naming and
    /// shaping every tensor from the variant's param specs.  Takes the
    /// state by value and moves the tensors — snapshotting is on the
    /// train hot path, so the capture's clone is the only full copy.
    pub fn from_state(
        variant: &Variant,
        state: ModelState,
        progress: RunProgress,
        spec_fp: u64,
        data_rng: Option<RngState>,
    ) -> Result<Snapshot> {
        let p = variant.n_params();
        if p == 0 || state.n_params != p {
            bail!(
                "state has {} params, variant {} has {p}",
                state.n_params,
                variant.name
            );
        }
        if state.tensors.len() % p != 0 || state.tensors.len() < p {
            bail!(
                "state has {} tensors, not a whole number of {p}-tensor blocks",
                state.tensors.len()
            );
        }
        let mut tensors = Vec::with_capacity(state.tensors.len());
        let mut shapes = Vec::with_capacity(state.tensors.len());
        for (i, t) in state.tensors.into_iter().enumerate() {
            let info = &variant.params[i % p];
            if t.len() != info.numel() {
                bail!(
                    "state tensor {i} ({}) has {} elements, spec says {}",
                    info.name,
                    t.len(),
                    info.numel()
                );
            }
            let name = if i < p {
                info.name.clone()
            } else {
                format!("opt{}.{}", i / p - 1, info.name)
            };
            tensors.push((name, t));
            shapes.push(info.shape.clone());
        }
        Ok(Snapshot {
            variant: variant.name.clone(),
            spec_fp,
            n_params: p,
            progress,
            tensors,
            shapes,
            data_rng,
        })
    }

    /// The backend-facing view: tensors in `param(idx)` order.
    pub fn model_state(&self) -> ModelState {
        ModelState {
            tensors: self.tensors.iter().map(|(_, d)| d.clone()).collect(),
            n_params: self.n_params,
        }
    }

    /// Consuming variant of [`Snapshot::model_state`]: moves the tensors
    /// instead of cloning them — the resume path restores once and drops
    /// the snapshot, so the copy would only double peak memory.
    pub fn into_model_state(self) -> ModelState {
        ModelState {
            tensors: self.tensors.into_iter().map(|(_, d)| d).collect(),
            n_params: self.n_params,
        }
    }

    /// Refuse to restore into the wrong variant or a mismatched layout.
    pub fn validate_for(&self, variant: &Variant) -> Result<()> {
        if self.variant != variant.name {
            bail!(
                "checkpoint is for variant {}, session runs {}",
                self.variant,
                variant.name
            );
        }
        let p = variant.n_params();
        if self.n_params != p || p == 0 || self.tensors.len() % p != 0 {
            bail!(
                "checkpoint layout mismatch: {} params / {} tensors vs variant's {p}",
                self.n_params,
                self.tensors.len()
            );
        }
        for (i, (name, data)) in self.tensors.iter().enumerate() {
            let info = &variant.params[i % p];
            if data.len() != info.numel() {
                bail!(
                    "checkpoint tensor {name} has {} elements, spec {} wants {}",
                    data.len(),
                    info.name,
                    info.numel()
                );
            }
        }
        Ok(())
    }

    /// Serialize + atomically publish (tmp-file-then-rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let _sp = crate::obs::trace::span("ckpt_publish");
        let t0 = std::time::Instant::now();
        let pr = &self.progress;
        let mut secs = vec![
            Section::raw(SEC_VARIANT, self.variant.as_bytes().to_vec()),
            Section::u64s(
                SEC_META,
                &[
                    pr.steps_done as u64,
                    self.n_params as u64,
                    pr.complete as u64,
                    pr.diverged as u64,
                    self.tensors.len() as u64,
                    self.spec_fp,
                ],
            ),
            Section::f64s(SEC_FLOPS, &[pr.flops]),
            Section::f64s(SEC_TRAIN, &pr.train_losses),
            Section::u64s(
                SEC_VAL_STEPS,
                &pr.val_losses.iter().map(|&(s, _)| s as u64).collect::<Vec<_>>(),
            ),
            Section::f64s(
                SEC_VAL_LOSSES,
                &pr.val_losses.iter().map(|&(_, l)| l).collect::<Vec<_>>(),
            ),
        ];
        if let Some(rng) = &self.data_rng {
            secs.push(Section::u64s(SEC_RNG, &rng.to_words()));
        }
        for ((name, data), shape) in self.tensors.iter().zip(&self.shapes) {
            let dims: Vec<u64> = shape.iter().map(|&d| d as u64).collect();
            secs.push(Section::f32s(
                &format!("{TENSOR_PREFIX}{name}"),
                &dims,
                data,
            ));
        }
        let out = format::write_file(path, &secs)
            .with_context(|| format!("writing checkpoint {}", path.display()));
        crate::obs::metrics::CKPT_PUBLISH.observe_since(t0);
        out
    }

    /// Read + fully validate a checkpoint file (magic, version, CRCs,
    /// section schema).
    pub fn load(path: &Path) -> Result<Snapshot> {
        let secs = format::read_file(path)
            .with_context(|| format!("loading checkpoint {}", path.display()))?;
        let find = |name: &str| -> Result<&Section> {
            secs.iter()
                .find(|s| s.name == name)
                .with_context(|| format!("checkpoint is missing the {name} section"))
        };
        let variant = find(SEC_VARIANT)?.as_text()?;
        let meta = find(SEC_META)?.as_u64s()?;
        if meta.len() != 6 {
            bail!("meta section has {} words, expected 6", meta.len());
        }
        let flops = *find(SEC_FLOPS)?
            .as_f64s()?
            .first()
            .context("flops section is empty")?;
        let train_losses = find(SEC_TRAIN)?.as_f64s()?;
        let val_steps = find(SEC_VAL_STEPS)?.as_u64s()?;
        let val_vals = find(SEC_VAL_LOSSES)?.as_f64s()?;
        if val_steps.len() != val_vals.len() {
            bail!(
                "val curve mismatch: {} steps vs {} losses",
                val_steps.len(),
                val_vals.len()
            );
        }
        let data_rng = match secs.iter().find(|s| s.name == SEC_RNG) {
            Some(s) => {
                Some(RngState::from_words(&s.as_u64s()?).map_err(|e| anyhow::anyhow!(e))?)
            }
            None => None,
        };
        let mut tensors = Vec::new();
        let mut shapes = Vec::new();
        for s in &secs {
            if let Some(name) = s.name.strip_prefix(TENSOR_PREFIX) {
                tensors.push((name.to_string(), s.as_f32s()?));
                shapes.push(s.shape.iter().map(|&d| d as usize).collect());
            }
        }
        if tensors.len() as u64 != meta[4] {
            bail!(
                "checkpoint lists {} tensors, meta says {}",
                tensors.len(),
                meta[4]
            );
        }
        Ok(Snapshot {
            variant,
            spec_fp: meta[5],
            n_params: meta[1] as usize,
            progress: RunProgress {
                steps_done: meta[0] as usize,
                complete: meta[2] != 0,
                diverged: meta[3] != 0,
                flops,
                train_losses,
                val_losses: val_steps
                    .iter()
                    .zip(&val_vals)
                    .map(|(&s, &l)| (s as usize, l))
                    .collect(),
            },
            tensors,
            shapes,
            data_rng,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn sample_snapshot() -> (Snapshot, Variant) {
        let rt = Runtime::native();
        let variant = rt.manifest().get("mlp_w64").unwrap().clone();
        let tensors: Vec<Vec<f32>> = variant
            .params
            .iter()
            .chain(variant.params.iter()) // params + one momentum block
            .enumerate()
            .map(|(i, p)| (0..p.numel()).map(|j| (i * 1000 + j) as f32 * 0.5).collect())
            .collect();
        let state = ModelState {
            n_params: variant.n_params(),
            tensors,
        };
        let progress = RunProgress {
            steps_done: 7,
            complete: false,
            diverged: false,
            flops: 1.25e9,
            train_losses: vec![2.3, 2.2, f64::NAN],
            val_losses: vec![(4, 2.25), (7, f64::NAN)],
        };
        let snap = Snapshot::from_state(&variant, state, progress, 0xFEED, None).unwrap();
        (snap, variant)
    }

    #[test]
    fn snapshot_roundtrip_is_bitwise() {
        let (snap, variant) = sample_snapshot();
        let dir = std::env::temp_dir().join("mutransfer_ckpt_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.ckpt");
        snap.save(&path).unwrap();
        let back = Snapshot::load(&path).unwrap();
        assert_eq!(back.variant, snap.variant);
        assert_eq!(back.spec_fp, 0xFEED);
        assert_eq!(back.n_params, snap.n_params);
        assert_eq!(back.progress.steps_done, 7);
        assert!(!back.progress.complete);
        assert_eq!(back.progress.flops, 1.25e9);
        assert_eq!(back.progress.train_losses.len(), 3);
        assert_eq!(back.progress.train_losses[1].to_bits(), 2.2f64.to_bits());
        assert!(back.progress.train_losses[2].is_nan());
        assert_eq!(back.progress.val_losses[0], (4, 2.25));
        assert!(back.progress.val_losses[1].1.is_nan());
        for ((na, da), (nb, db)) in snap.tensors.iter().zip(&back.tensors) {
            assert_eq!(na, nb);
            assert_eq!(da.len(), db.len());
            for (x, y) in da.iter().zip(db) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        back.validate_for(&variant).unwrap();
    }

    #[test]
    fn validate_refuses_other_variants() {
        let (snap, _) = sample_snapshot();
        let rt = Runtime::native();
        let other = rt.manifest().get("resmlp_w32").unwrap().clone();
        assert!(snap.validate_for(&other).is_err());
    }

    #[test]
    fn opt_blocks_are_named_by_block_index() {
        let (snap, variant) = sample_snapshot();
        let p = variant.n_params();
        assert_eq!(snap.tensors[0].0, variant.params[0].name);
        assert!(snap.tensors[p].0.starts_with("opt0."));
    }
}
