//! The on-disk checkpoint container: a versioned, deterministic binary
//! section format (DESIGN.md §7).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic[8] = "MUTCKPT\0"
//! version  u32
//! n_sections u32
//! section × n_sections:
//!   name_len u16, name utf-8 bytes
//!   dtype    u8   (1 = f32, 2 = f64, 3 = u64, 4 = raw bytes)
//!   ndim     u8,  dims u64 × ndim          (the shape manifest)
//!   payload_len u64, payload bytes         (little-endian scalars)
//!   crc32    u32  over the section record (name_len..payload inclusive)
//! ```
//!
//! Writers serialize the whole file into one buffer and publish it
//! atomically via `util::fsio::write_atomic` (hidden tmp sibling, fsync,
//! rename over `path`) — a crash can never leave a half-written
//! checkpoint visible under the final name.  Readers
//! validate magic, version, per-section shape/payload consistency, and
//! every CRC before returning a single byte of data; the same state always
//! serializes to the same bytes (no timestamps, no map iteration order —
//! sections are an explicit list).

use std::path::Path;

use anyhow::{bail, Context, Result};
#[cfg(test)]
use std::path::PathBuf;

pub const MAGIC: [u8; 8] = *b"MUTCKPT\0";
pub const VERSION: u32 = 1;

/// IEEE CRC-32 (the zlib polynomial), table built at compile time — the
/// vendored crate set has no checksum crate.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
    U64,
    Raw,
}

impl Dtype {
    fn code(self) -> u8 {
        match self {
            Dtype::F32 => 1,
            Dtype::F64 => 2,
            Dtype::U64 => 3,
            Dtype::Raw => 4,
        }
    }

    fn parse(c: u8) -> Result<Dtype> {
        Ok(match c {
            1 => Dtype::F32,
            2 => Dtype::F64,
            3 => Dtype::U64,
            4 => Dtype::Raw,
            other => bail!("unknown section dtype code {other}"),
        })
    }

    fn elem_size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 | Dtype::U64 => 8,
            Dtype::Raw => 1,
        }
    }
}

/// One named, shaped, checksummed blob.
#[derive(Debug, Clone, PartialEq)]
pub struct Section {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<u64>,
    /// little-endian scalar bytes; length == product(shape) · elem_size
    pub payload: Vec<u8>,
}

impl Section {
    pub fn f32s(name: &str, shape: &[u64], data: &[f32]) -> Section {
        let mut payload = Vec::with_capacity(data.len() * 4);
        for v in data {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Section {
            name: name.to_string(),
            dtype: Dtype::F32,
            shape: shape.to_vec(),
            payload,
        }
    }

    pub fn f64s(name: &str, data: &[f64]) -> Section {
        let mut payload = Vec::with_capacity(data.len() * 8);
        for v in data {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Section {
            name: name.to_string(),
            dtype: Dtype::F64,
            shape: vec![data.len() as u64],
            payload,
        }
    }

    pub fn u64s(name: &str, data: &[u64]) -> Section {
        let mut payload = Vec::with_capacity(data.len() * 8);
        for v in data {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Section {
            name: name.to_string(),
            dtype: Dtype::U64,
            shape: vec![data.len() as u64],
            payload,
        }
    }

    pub fn raw(name: &str, bytes: Vec<u8>) -> Section {
        Section {
            name: name.to_string(),
            dtype: Dtype::Raw,
            shape: vec![bytes.len() as u64],
            payload: bytes,
        }
    }

    pub fn numel(&self) -> u64 {
        self.shape.iter().product()
    }

    pub fn as_f32s(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("section {} is {:?}, expected F32", self.name, self.dtype);
        }
        Ok(self
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_f64s(&self) -> Result<Vec<f64>> {
        if self.dtype != Dtype::F64 {
            bail!("section {} is {:?}, expected F64", self.name, self.dtype);
        }
        Ok(self
            .payload
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    pub fn as_u64s(&self) -> Result<Vec<u64>> {
        if self.dtype != Dtype::U64 {
            bail!("section {} is {:?}, expected U64", self.name, self.dtype);
        }
        Ok(self
            .payload
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    pub fn as_text(&self) -> Result<String> {
        if self.dtype != Dtype::Raw {
            bail!("section {} is {:?}, expected Raw", self.name, self.dtype);
        }
        String::from_utf8(self.payload.clone())
            .with_context(|| format!("section {} is not utf-8", self.name))
    }

    /// Serialize the section record (everything except the trailing CRC).
    fn encode(&self) -> Result<Vec<u8>> {
        if self.name.len() > u16::MAX as usize {
            bail!("section name too long ({} bytes)", self.name.len());
        }
        if self.shape.len() > u8::MAX as usize {
            bail!("section {} has {} dims (max 255)", self.name, self.shape.len());
        }
        let want = self
            .numel()
            .checked_mul(self.dtype.elem_size() as u64)
            .context("section size overflow")?;
        if self.payload.len() as u64 != want {
            bail!(
                "section {}: payload is {} bytes, shape {:?} implies {want}",
                self.name,
                self.payload.len(),
                self.shape
            );
        }
        let mut out = Vec::with_capacity(self.name.len() + self.payload.len() + 32);
        out.extend_from_slice(&(self.name.len() as u16).to_le_bytes());
        out.extend_from_slice(self.name.as_bytes());
        out.push(self.dtype.code());
        out.push(self.shape.len() as u8);
        for d in &self.shape {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&(self.payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&self.payload);
        Ok(out)
    }
}

/// Serialize and atomically publish a checkpoint (tmp-then-rename via
/// `util::fsio`).  Identical sections always produce identical bytes.
pub fn write_file(path: &Path, sections: &[Section]) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    for s in sections {
        let rec = s.encode()?;
        buf.extend_from_slice(&rec);
        buf.extend_from_slice(&crc32(&rec).to_le_bytes());
    }
    crate::util::fsio::write_atomic(path, &buf)
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // `pos + n` could wrap on a crafted length (e.g. a Raw section
        // declaring u64::MAX bytes passes the shape/payload consistency
        // check); compare against the remaining bytes instead so corrupt
        // files stay a recoverable error, never a panic.
        if n > self.buf.len() - self.pos {
            bail!(
                "truncated checkpoint: wanted {n} bytes at offset {}, file has {}",
                self.pos,
                self.buf.len()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

/// Read and fully validate a checkpoint file.  Every failure mode is a
/// distinct error: bad magic, unsupported version, truncation, a
/// shape/payload mismatch, or a CRC mismatch naming the section.
pub fn read_file(path: &Path) -> Result<Vec<Section>> {
    let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut cur = Cursor { buf: &buf, pos: 0 };
    let magic = cur.take(8)?;
    if magic != MAGIC {
        bail!("bad magic: {} is not a mutransfer checkpoint", path.display());
    }
    let version = cur.u32()?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version} (this build reads {VERSION})");
    }
    let n = cur.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1024));
    for i in 0..n {
        let rec_start = cur.pos;
        let name_len = cur.u16()? as usize;
        let name = String::from_utf8(cur.take(name_len)?.to_vec())
            .with_context(|| format!("section {i}: name is not utf-8"))?;
        let dtype = Dtype::parse(cur.u8()?)?;
        let ndim = cur.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(cur.u64()?);
        }
        let payload_len = cur.u64()? as usize;
        let numel: u64 = shape.iter().try_fold(1u64, |a, &d| a.checked_mul(d)).context(
            "section shape overflow",
        )?;
        let want = numel
            .checked_mul(dtype.elem_size() as u64)
            .context("section size overflow")?;
        if payload_len as u64 != want {
            bail!(
                "section {name}: payload length {payload_len} does not match shape {shape:?}"
            );
        }
        let payload = cur.take(payload_len)?.to_vec();
        let rec_end = cur.pos;
        let stored = cur.u32()?;
        let actual = crc32(&buf[rec_start..rec_end]);
        if stored != actual {
            bail!("crc mismatch in section {name}: stored {stored:#010x}, computed {actual:#010x}");
        }
        out.push(Section {
            name,
            dtype,
            shape,
            payload,
        });
    }
    if cur.pos != buf.len() {
        bail!(
            "trailing bytes after last section ({} of {} consumed)",
            cur.pos,
            buf.len()
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_reference_vectors() {
        // the canonical zlib check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("mutransfer_ckpt_format_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let path = tmpfile("roundtrip.ckpt");
        let secs = vec![
            Section::raw("meta", b"hello".to_vec()),
            Section::u64s("ints", &[0, 1, u64::MAX]),
            Section::f64s("curve", &[1.5, f64::NAN, -0.0]),
            Section::f32s("w", &[2, 3], &[1.0, -2.5, 0.0, f32::NAN, 3.25, -0.0]),
        ];
        write_file(&path, &secs).unwrap();
        let back = read_file(&path).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back[0].as_text().unwrap(), "hello");
        assert_eq!(back[1].as_u64s().unwrap(), vec![0, 1, u64::MAX]);
        let curve = back[2].as_f64s().unwrap();
        assert_eq!(curve[0].to_bits(), 1.5f64.to_bits());
        assert_eq!(curve[1].to_bits(), f64::NAN.to_bits()); // bit-exact, incl. NaN
        assert_eq!(curve[2].to_bits(), (-0.0f64).to_bits());
        assert_eq!(back[3].shape, vec![2, 3]);
        let w = back[3].as_f32s().unwrap();
        assert_eq!(w.len(), 6);
        assert_eq!(w[3].to_bits(), f32::NAN.to_bits());
        assert_eq!(w[5].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn deterministic_bytes() {
        let a = tmpfile("det_a.ckpt");
        let b = tmpfile("det_b.ckpt");
        let secs = vec![
            Section::raw("variant", b"tfm".to_vec()),
            Section::f32s("w", &[4], &[0.1, 0.2, 0.3, 0.4]),
        ];
        write_file(&a, &secs).unwrap();
        write_file(&b, &secs).unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    }

    #[test]
    fn no_tmp_file_left_behind() {
        let path = tmpfile("clean.ckpt");
        write_file(&path, &[Section::raw("x", vec![1, 2, 3])]).unwrap();
        assert!(path.exists());
        // util::fsio's hidden-sibling tmp name
        assert!(!path.with_file_name(".clean.ckpt.tmp").exists());
    }

    #[test]
    fn corruption_is_rejected() {
        let path = tmpfile("corrupt.ckpt");
        write_file(
            &path,
            &[Section::f32s("w", &[3], &[1.0, 2.0, 3.0])],
        )
        .unwrap();
        let good = std::fs::read(&path).unwrap();

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let e = read_file(&path).unwrap_err().to_string();
        assert!(e.contains("magic"), "{e}");

        // wrong version
        let mut bad = good.clone();
        bad[8] = 99;
        std::fs::write(&path, &bad).unwrap();
        let e = read_file(&path).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");

        // truncated
        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        let e = read_file(&path).unwrap_err().to_string();
        assert!(e.to_lowercase().contains("truncated"), "{e}");

        // flipped payload byte -> crc mismatch
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 6] ^= 0x01; // inside the last section's payload
        std::fs::write(&path, &bad).unwrap();
        let e = read_file(&path).unwrap_err().to_string();
        assert!(e.contains("crc"), "{e}");

        // intact file still loads
        std::fs::write(&path, &good).unwrap();
        assert!(read_file(&path).is_ok());
    }

    /// A crafted section declaring a u64::MAX-byte payload must come back
    /// as a truncation error, not an overflow panic (regression for the
    /// `pos + n` wrap in `Cursor::take`).
    #[test]
    fn absurd_declared_length_is_an_error_not_a_panic() {
        let path = tmpfile("absurd.ckpt");
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // one section
        buf.extend_from_slice(&1u16.to_le_bytes()); // name_len
        buf.push(b'x');
        buf.push(4); // Raw
        buf.push(1); // ndim
        buf.extend_from_slice(&u64::MAX.to_le_bytes()); // dim
        buf.extend_from_slice(&u64::MAX.to_le_bytes()); // payload_len
        std::fs::write(&path, &buf).unwrap();
        let e = read_file(&path).unwrap_err().to_string();
        assert!(e.to_lowercase().contains("truncated"), "{e}");
    }
}
