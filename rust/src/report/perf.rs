//! Perf-attribution reports and the machine-readable bench trajectory
//! (DESIGN.md §13).
//!
//! Two halves, one file, because they share the same contract — *numbers
//! leave the process as schema-versioned JSON first, human text second*:
//!
//! * [`profile_report`] renders an [`obs::profile`](crate::obs::profile)
//!   snapshot into (a) a JSON document and (b) aligned text tables:
//!   per-phase self-time shares (summing to ~100% by construction),
//!   per-GEMM-shape achieved GFLOP/s against the machine-measured
//!   roofline, per-thread attribution, and the span-FLOPs vs
//!   `model::flops::step_gemm_flops` cross-check;
//! * [`BenchDoc`] is the shared writer every `benches/*.rs` routes its
//!   headline rows through — `BENCH_<name>.json` under `BENCH_OUT_DIR`
//!   (default `results/bench/`) with commit/date/machine stamps — and
//!   [`bench_diff`] compares two such documents, flagging >threshold
//!   regressions so CI can gate on the trajectory.
//!
//! Gate policy: only `higher_is_better=false` rows (latencies,
//! overheads) fail the gate; throughput-style rows are report-only
//! because their noise floor on shared runners drowns a 10% band.
//! A machine-fingerprint mismatch downgrades the whole diff to
//! report-only (the caller honors `BENCH_DIFF_FORCE=1` to re-arm it).

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::model::flops;
use crate::obs::profile::Snapshot;
use crate::runtime::Variant;
use crate::util::fsio::write_atomic;
use crate::util::json::{jnum, jstr, Json};
use crate::util::table::Table;

/// Schema version stamped into every profile and bench document.
pub const SCHEMA_VERSION: f64 = 1.0;

/// The named attribution phases, in display order.  Every other span
/// kind's self time folds into `other`, so the shares always cover 100%
/// of span-attributed wall time.
pub const PHASES: &[&str] = &[
    "gemm",
    "attn_fwd",
    "attn_bwd",
    "optimizer",
    "eval",
    "ckpt_publish",
    "journal_fsync",
];

/// Context the snapshot itself cannot know: what ran, for how many
/// steps, and the machine roofline to normalize GFLOP/s against.
pub struct ProfileCtx<'a> {
    /// Variant profiled, when the window covered exactly one (the
    /// `profile` subcommand); `None` for daemon-wide aggregates.
    pub variant: Option<&'a Variant>,
    /// Profiled optimizer steps in the window, when known.
    pub steps: Option<usize>,
    /// `profile::measured_peak_flops()`, or 0.0 to skip utilization.
    pub peak_flops: f64,
}

pub struct ProfileReport {
    pub json: Json,
    pub text: String,
}

fn ms(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1e6)
}

/// Fold a profile snapshot into the §13 report (JSON + text tables).
pub fn profile_report(snap: &Snapshot, ctx: &ProfileCtx) -> ProfileReport {
    let kinds = snap.kinds_merged();
    let total_self_ns: u64 = kinds.values().map(|k| k.self_ns).sum();
    let share = |self_ns: u64| -> f64 {
        if total_self_ns == 0 {
            0.0
        } else {
            100.0 * self_ns as f64 / total_self_ns as f64
        }
    };

    // ---- phase shares (named phases + "other" = 100%) ------------------
    let mut phase_rows: Vec<(String, u64, u64, u64)> = Vec::new();
    let mut named_self = 0u64;
    for &p in PHASES {
        let k = kinds.get(p).copied().unwrap_or_default();
        named_self += k.self_ns;
        phase_rows.push((p.to_string(), k.count, k.total_ns, k.self_ns));
    }
    let other_self = total_self_ns.saturating_sub(named_self);
    let other_count: u64 = kinds
        .iter()
        .filter(|(name, _)| !PHASES.contains(name))
        .map(|(_, k)| k.count)
        .sum();
    phase_rows.push(("other".to_string(), other_count, other_self, other_self));

    let mut jphases = Vec::new();
    let title = match (ctx.variant, ctx.steps) {
        (Some(v), Some(s)) => format!("perf attribution: {} ({s} steps)", v.name),
        (Some(v), None) => format!("perf attribution: {}", v.name),
        _ => "perf attribution".to_string(),
    };
    let mut tphases = Table::new(&title, &["phase", "spans", "self ms", "share %"]);
    for (name, count, total_ns, self_ns) in &phase_rows {
        jphases.push(Json::from_pairs(vec![
            ("name", jstr(name)),
            ("count", jnum(*count as f64)),
            ("total_ns", jnum(*total_ns as f64)),
            ("self_ns", jnum(*self_ns as f64)),
            ("share_pct", jnum(share(*self_ns))),
        ]));
        tphases.row(vec![
            name.clone(),
            count.to_string(),
            ms(*self_ns),
            format!("{:.1}", share(*self_ns)),
        ]);
    }

    // ---- raw kinds (full taxonomy, for drill-down) ---------------------
    let mut jkinds = Vec::new();
    let mut kind_rows: Vec<_> = kinds.iter().collect();
    kind_rows.sort_by(|a, b| b.1.self_ns.cmp(&a.1.self_ns).then(a.0.cmp(b.0)));
    for (name, k) in &kind_rows {
        jkinds.push(Json::from_pairs(vec![
            ("name", jstr(name)),
            ("count", jnum(k.count as f64)),
            ("total_ns", jnum(k.total_ns as f64)),
            ("self_ns", jnum(k.self_ns as f64)),
        ]));
    }

    // ---- per-GEMM-shape GFLOP/s vs the roofline ------------------------
    let mut shape_rows: Vec<_> = snap.shapes.iter().collect();
    shape_rows.sort_by(|a, b| b.1.total_ns.cmp(&a.1.total_ns).then(a.0.cmp(&b.0)));
    let mut jshapes = Vec::new();
    let mut tshapes = Table::new(
        "gemm shapes (m, k, n effective)",
        &["m", "k", "n", "calls", "time ms", "GFLOP/s", "% peak"],
    );
    for ((m, k, n), s) in &shape_rows {
        let secs = s.total_ns as f64 / 1e9;
        let gflops = if secs > 0.0 { s.flops / secs / 1e9 } else { 0.0 };
        let util = if ctx.peak_flops > 0.0 {
            100.0 * gflops * 1e9 / ctx.peak_flops
        } else {
            0.0
        };
        jshapes.push(Json::from_pairs(vec![
            ("m", jnum(*m as f64)),
            ("k", jnum(*k as f64)),
            ("n", jnum(*n as f64)),
            ("count", jnum(s.count as f64)),
            ("total_ns", jnum(s.total_ns as f64)),
            ("flops", jnum(s.flops)),
            ("gflops", jnum(gflops)),
            ("util_pct", jnum(util)),
        ]));
        tshapes.row(vec![
            m.to_string(),
            k.to_string(),
            n.to_string(),
            s.count.to_string(),
            ms(s.total_ns),
            format!("{gflops:.2}"),
            format!("{util:.1}"),
        ]);
    }

    // ---- per-thread / executor-slot attribution ------------------------
    let mut jthreads = Vec::new();
    let mut tthreads = Table::new("threads", &["tid", "label", "spans", "self ms"]);
    for (tid, t) in &snap.threads {
        let spans: u64 = t.kinds.values().map(|k| k.count).sum();
        let self_ns: u64 = t.kinds.values().map(|k| k.self_ns).sum();
        let label = t.label.clone().unwrap_or_default();
        jthreads.push(Json::from_pairs(vec![
            ("tid", jnum(*tid as f64)),
            ("label", jstr(&label)),
            ("spans", jnum(spans as f64)),
            ("self_ns", jnum(self_ns as f64)),
        ]));
        tthreads.row(vec![tid.to_string(), label, spans.to_string(), ms(self_ns)]);
    }

    // ---- FLOPs cross-check against model/flops.rs ----------------------
    let span_flops = snap.gemm_flops();
    let expected = match (ctx.variant, ctx.steps) {
        (Some(v), Some(steps)) => Some(flops::step_gemm_flops(v) * steps as f64),
        _ => None,
    };
    let agreement = expected.map(|e| if e > 0.0 { 100.0 * span_flops / e } else { 0.0 });
    let gemm_time_ns = kinds.get("gemm").map(|k| k.total_ns).unwrap_or(0);
    let achieved = if gemm_time_ns > 0 {
        span_flops / (gemm_time_ns as f64 / 1e9)
    } else {
        0.0
    };
    let mut gemm = Json::from_pairs(vec![
        ("span_flops", jnum(span_flops)),
        ("achieved_gflops", jnum(achieved / 1e9)),
        ("peak_gflops", jnum(ctx.peak_flops / 1e9)),
    ]);
    if let Some(e) = expected {
        gemm.set("expected_flops", jnum(e));
    }
    if let Some(a) = agreement {
        gemm.set("agreement_pct", jnum(a));
    }

    let mut json = Json::from_pairs(vec![
        ("schema_version", jnum(SCHEMA_VERSION)),
        ("total_self_ns", jnum(total_self_ns as f64)),
        ("phases", Json::Arr(jphases)),
        ("kinds", Json::Arr(jkinds)),
        ("shapes", Json::Arr(jshapes)),
        ("threads", Json::Arr(jthreads)),
        ("gemm", gemm),
    ]);
    if let Some(v) = ctx.variant {
        json.set("variant", jstr(&v.name));
    }
    if let Some(s) = ctx.steps {
        json.set("steps", jnum(s as f64));
    }

    let mut text = tphases.render();
    if !shape_rows.is_empty() {
        text.push('\n');
        text.push_str(&tshapes.render());
    }
    if snap.threads.len() > 1 {
        text.push('\n');
        text.push_str(&tthreads.render());
    }
    text.push('\n');
    if ctx.peak_flops > 0.0 {
        text.push_str(&format!(
            "roofline  : {:.2} GFLOP/s scalar-FMA peak (measured), gemm achieved {:.2} GFLOP/s\n",
            ctx.peak_flops / 1e9,
            achieved / 1e9,
        ));
    }
    text.push_str(&format!("gemm flops: {span_flops:.3e} span-attributed"));
    if let (Some(e), Some(a)) = (expected, agreement) {
        text.push_str(&format!(" vs {e:.3e} model/flops.rs inventory ({a:.1}% agreement)"));
    }
    text.push('\n');

    ProfileReport { json, text }
}

// ------------------------------------------------------------- bench docs

/// Env-derived commit / date stamps (CI injects `GITHUB_SHA`; local runs
/// can set `MUTRANSFER_COMMIT` / `MUTRANSFER_DATE`, else "unknown" — the
/// doc stays byte-deterministic for a given env).
fn env_stamp(keys: &[&str]) -> String {
    for k in keys {
        if let Ok(v) = std::env::var(k) {
            if !v.is_empty() {
                return v;
            }
        }
    }
    "unknown".to_string()
}

/// The host identity a bench number is only comparable within.
pub fn machine_fingerprint() -> Json {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    Json::from_pairs(vec![
        ("arch", jstr(std::env::consts::ARCH)),
        ("os", jstr(std::env::consts::OS)),
        ("cores", jnum(cores as f64)),
    ])
}

/// Where `BENCH_<name>.json` documents land: `BENCH_OUT_DIR` or
/// `results/bench/`.
pub fn bench_out_dir() -> PathBuf {
    match std::env::var("BENCH_OUT_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => crate::results_dir().join("bench"),
    }
}

/// One named measurement in a bench document.
pub struct BenchRow {
    pub name: String,
    pub value: f64,
    pub unit: String,
    pub higher_is_better: bool,
}

/// The shared machine-readable writer every `benches/*.rs` routes its
/// headline rows through (schema in DESIGN.md §13).
pub struct BenchDoc {
    bench: String,
    rows: Vec<BenchRow>,
}

impl BenchDoc {
    pub fn new(bench: &str) -> BenchDoc {
        BenchDoc { bench: bench.to_string(), rows: Vec::new() }
    }

    /// Append a named row.  `higher_is_better=false` rows (latencies,
    /// overhead percentages) are the ones `bench_diff` gates on.
    pub fn row(&mut self, name: &str, value: f64, unit: &str, higher_is_better: bool) -> &mut Self {
        self.rows.push(BenchRow {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
            higher_is_better,
        });
        self
    }

    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("name", jstr(&r.name)),
                    ("value", jnum(r.value)),
                    ("unit", jstr(&r.unit)),
                    ("higher_is_better", Json::Bool(r.higher_is_better)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("schema_version", jnum(SCHEMA_VERSION)),
            ("bench", jstr(&self.bench)),
            ("commit", jstr(&env_stamp(&["MUTRANSFER_COMMIT", "GITHUB_SHA"]))),
            ("date", jstr(&env_stamp(&["MUTRANSFER_DATE", "SOURCE_DATE_EPOCH"]))),
            ("machine", machine_fingerprint()),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Atomic-publish `BENCH_<name>.json` into [`bench_out_dir`],
    /// returning the path written.
    pub fn finish(&self) -> Result<PathBuf> {
        let dir = bench_out_dir();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating bench dir {}", dir.display()))?;
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        write_atomic(&path, self.to_json().to_string().as_bytes())?;
        Ok(path)
    }
}

// ------------------------------------------------------------- bench diff

/// One row's old-vs-new comparison.
pub struct DiffRow {
    pub name: String,
    pub unit: String,
    pub old: f64,
    pub new: f64,
    /// Percent change new vs old, signed (positive = value went up).
    pub delta_pct: f64,
    pub higher_is_better: bool,
    /// Moved more than the threshold in this row's *bad* direction.
    pub regressed: bool,
}

pub struct BenchDiff {
    pub bench: String,
    /// Machine fingerprints agree (arch + os + cores); on mismatch the
    /// caller downgrades to report-only unless `BENCH_DIFF_FORCE=1`.
    pub machine_match: bool,
    pub threshold_pct: f64,
    pub rows: Vec<DiffRow>,
    /// Row names present in only one of the two documents.
    pub missing: Vec<String>,
}

impl BenchDiff {
    /// Rows that fail the gate: `higher_is_better=false` rows past the
    /// threshold (throughput rows report but never gate — §13).
    pub fn gate_failures(&self) -> Vec<&DiffRow> {
        self.rows.iter().filter(|r| r.regressed && !r.higher_is_better).collect()
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!("bench-diff: {} (gate >{:.0}% on lower-is-better rows)", self.bench, self.threshold_pct),
            &["row", "old", "new", "delta %", "dir", "verdict"],
        );
        for r in &self.rows {
            t.row(vec![
                format!("{} [{}]", r.name, r.unit),
                format!("{:.4}", r.old),
                format!("{:.4}", r.new),
                format!("{:+.1}", r.delta_pct),
                if r.higher_is_better { "up".into() } else { "down".into() },
                if r.regressed {
                    if r.higher_is_better { "regressed (report-only)".into() } else { "REGRESSED".into() }
                } else {
                    "ok".to_string()
                },
            ]);
        }
        let mut out = t.render();
        for m in &self.missing {
            out.push_str(&format!("  (row {m:?} present in only one document)\n"));
        }
        if !self.machine_match {
            out.push_str("  machine fingerprints differ: diff is report-only (BENCH_DIFF_FORCE=1 to gate anyway)\n");
        }
        out
    }
}

fn rows_by_name(doc: &Json) -> Vec<(String, f64, String, bool)> {
    let mut out = Vec::new();
    let Some(rows) = doc.get("rows").and_then(|r| r.as_arr()) else {
        return out;
    };
    for r in rows {
        let (Some(name), Some(value)) = (
            r.get("name").and_then(|v| v.as_str()),
            r.get("value").and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        let unit = r.get("unit").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let hib = r.get("higher_is_better").and_then(|v| v.as_bool()).unwrap_or(false);
        out.push((name.to_string(), value, unit, hib));
    }
    out
}

/// Compare two [`BenchDoc`] JSON documents row by row.  A row regresses
/// when it moves more than `threshold_pct` in its bad direction (up for
/// latency-like rows, down for throughput-like rows).
pub fn bench_diff(old: &Json, new: &Json, threshold_pct: f64) -> BenchDiff {
    let bench = new
        .get("bench")
        .and_then(|b| b.as_str())
        .or_else(|| old.get("bench").and_then(|b| b.as_str()))
        .unwrap_or("?")
        .to_string();
    let machine_match = match (old.get("machine"), new.get("machine")) {
        (Some(a), Some(b)) => a == b,
        _ => false,
    };
    let old_rows = rows_by_name(old);
    let new_rows = rows_by_name(new);
    let mut rows = Vec::new();
    let mut missing = Vec::new();
    for (name, old_v, unit, hib) in &old_rows {
        let Some((_, new_v, _, _)) = new_rows.iter().find(|(n, ..)| n == name) else {
            missing.push(name.clone());
            continue;
        };
        let delta_pct = if old_v.abs() > 0.0 {
            100.0 * (new_v - old_v) / old_v.abs()
        } else if *new_v == 0.0 {
            0.0
        } else {
            f64::INFINITY
        };
        let regressed = if *hib {
            delta_pct < -threshold_pct
        } else {
            delta_pct > threshold_pct
        };
        rows.push(DiffRow {
            name: name.clone(),
            unit: unit.clone(),
            old: *old_v,
            new: *new_v,
            delta_pct,
            higher_is_better: *hib,
            regressed,
        });
    }
    for (name, ..) in &new_rows {
        if !old_rows.iter().any(|(n, ..)| n == name) {
            missing.push(name.clone());
        }
    }
    BenchDiff { bench, machine_match, threshold_pct, rows, missing }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::profile::{KindStat, ShapeStat, ThreadStats};

    fn synthetic_snapshot() -> Snapshot {
        let mut kinds = std::collections::BTreeMap::new();
        kinds.insert("gemm", KindStat { count: 12, total_ns: 6_000_000, self_ns: 6_000_000 });
        kinds.insert("optimizer", KindStat { count: 2, total_ns: 1_000_000, self_ns: 1_000_000 });
        kinds.insert(
            "train_step",
            KindStat { count: 2, total_ns: 10_000_000, self_ns: 3_000_000 },
        );
        let threads = vec![(
            1u64,
            ThreadStats { label: Some("exec-0".into()), kinds },
        )];
        let shapes = vec![(
            (64u32, 64u32, 64u32),
            ShapeStat {
                count: 12,
                total_ns: 6_000_000,
                flops: 12.0 * crate::model::flops::flops_for_shape(64, 64, 64),
            },
        )];
        Snapshot { threads, shapes }
    }

    #[test]
    fn shares_sum_to_100() {
        let snap = synthetic_snapshot();
        let r = profile_report(&snap, &ProfileCtx { variant: None, steps: None, peak_flops: 1e9 });
        let phases = r.json.req("phases").as_arr().unwrap();
        let sum: f64 = phases
            .iter()
            .map(|p| p.req("share_pct").as_f64().unwrap())
            .sum();
        assert!((sum - 100.0).abs() < 1.0, "shares sum {sum}");
        // "other" absorbs the train_step self time
        let other = phases.iter().find(|p| p.req("name").as_str() == Some("other")).unwrap();
        assert!(other.req("share_pct").as_f64().unwrap() > 0.0);
        // shape carries a positive GFLOP/s and flops from the shared helper
        let sh = &r.json.req("shapes").as_arr().unwrap()[0];
        assert!(sh.req("gflops").as_f64().unwrap() > 0.0);
        assert_eq!(
            sh.req("flops").as_f64().unwrap(),
            12.0 * crate::model::flops::flops_for_shape(64, 64, 64)
        );
        assert!(r.text.contains("gemm"));
    }

    #[test]
    fn profile_json_roundtrips() {
        let snap = synthetic_snapshot();
        let r = profile_report(&snap, &ProfileCtx { variant: None, steps: Some(2), peak_flops: 0.0 });
        let back = crate::util::json::parse(&r.json.to_string()).unwrap();
        assert_eq!(back.req("schema_version").as_f64(), Some(1.0));
        assert_eq!(back.req("steps").as_usize(), Some(2));
        assert_eq!(back, r.json);
    }

    #[test]
    fn bench_doc_schema_and_diff_gate() {
        let mut old = BenchDoc::new("unit_test");
        old.row("step_ms", 10.0, "ms", false).row("throughput", 100.0, "req_s", true);
        let oldj = crate::util::json::parse(&old.to_json().to_string()).unwrap();
        assert_eq!(oldj.req("bench").as_str(), Some("unit_test"));
        assert_eq!(oldj.req("schema_version").as_f64(), Some(1.0));
        assert!(oldj.req("machine").get("arch").is_some());

        // 20% slowdown on a lower-is-better row must gate
        let mut slow = BenchDoc::new("unit_test");
        slow.row("step_ms", 12.0, "ms", false).row("throughput", 100.0, "req_s", true);
        let d = bench_diff(&oldj, &slow.to_json(), 10.0);
        assert!(d.machine_match);
        assert_eq!(d.gate_failures().len(), 1);
        assert_eq!(d.gate_failures()[0].name, "step_ms");
        assert!(d.render().contains("REGRESSED"));

        // 20% throughput drop reports but never gates
        let mut tput = BenchDoc::new("unit_test");
        tput.row("step_ms", 10.0, "ms", false).row("throughput", 80.0, "req_s", true);
        let d = bench_diff(&oldj, &tput.to_json(), 10.0);
        assert!(d.gate_failures().is_empty());
        assert!(d.rows.iter().any(|r| r.regressed && r.higher_is_better));

        // within-band moves pass
        let mut ok = BenchDoc::new("unit_test");
        ok.row("step_ms", 10.5, "ms", false).row("throughput", 97.0, "req_s", true);
        let d = bench_diff(&oldj, &ok.to_json(), 10.0);
        assert!(d.gate_failures().is_empty());
        assert!(d.missing.is_empty());
    }

    #[test]
    fn bench_diff_flags_machine_mismatch_and_missing_rows() {
        let mut a = BenchDoc::new("unit_test");
        a.row("x", 1.0, "ms", false);
        let mut aj = a.to_json();
        aj.set("machine", Json::from_pairs(vec![("arch", jstr("other-arch"))]));
        let mut b = BenchDoc::new("unit_test");
        b.row("y", 2.0, "ms", false);
        let d = bench_diff(&aj, &b.to_json(), 10.0);
        assert!(!d.machine_match);
        assert_eq!(d.missing.len(), 2);
        assert!(d.render().contains("report-only"));
    }

    #[test]
    fn bench_doc_finish_writes_under_out_dir() {
        let dir = std::env::temp_dir().join("mutransfer_bench_doc_test");
        let _ = std::fs::remove_dir_all(&dir);
        // env var is process-global: restore to keep other tests honest
        let prev = std::env::var("BENCH_OUT_DIR").ok();
        std::env::set_var("BENCH_OUT_DIR", &dir);
        let mut doc = BenchDoc::new("finish_test");
        doc.row("v", 3.0, "ms", false);
        let path = doc.finish().unwrap();
        match prev {
            Some(p) => std::env::set_var("BENCH_OUT_DIR", p),
            None => std::env::remove_var("BENCH_OUT_DIR"),
        }
        assert_eq!(path, dir.join("BENCH_finish_test.json"));
        let s = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.req("rows").as_arr().unwrap().len(), 1);
        assert!(!dir.join(".BENCH_finish_test.json.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
