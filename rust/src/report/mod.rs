//! Result persistence: every experiment writes (a) a paper-style text
//! table to stdout, (b) CSV series under `results/`, and (c) a JSON blob
//! with the raw numbers, so EXPERIMENTS.md entries are regenerable.
//!
//! All artifact writes publish tmp-file-then-rename (the same
//! crash-consistency rule `ckpt/format.rs` enforces): the serve daemon
//! reports results too, and a SIGKILLed daemon must never leave a torn
//! CSV/JSON artifact behind for a reader to trip over.

pub mod perf;

use std::path::PathBuf;

use anyhow::Result;

use crate::util::fsio::write_atomic;
use crate::util::json::Json;
use crate::util::table::Table;

pub struct Reporter {
    dir: PathBuf,
    pub quiet: bool,
}

impl Reporter {
    pub fn new(dir: PathBuf) -> Reporter {
        let _ = std::fs::create_dir_all(&dir);
        Reporter { dir, quiet: false }
    }

    pub fn default_results() -> Reporter {
        Reporter::new(crate::results_dir())
    }

    /// Print a table and persist its CSV twin (atomic publish).
    pub fn table(&self, name: &str, t: &Table) -> Result<()> {
        if !self.quiet {
            // mutlint: allow(bus-only-output, "Reporter's stdout table rendering is the exp CLI contract; quiet() is the daemon-side off switch")
            println!("{}", t.render());
        }
        write_atomic(&self.dir.join(format!("{name}.csv")), t.to_csv().as_bytes())?;
        Ok(())
    }

    /// Persist raw JSON (figure series, trial dumps); atomic publish.
    pub fn json(&self, name: &str, j: &Json) -> Result<()> {
        write_atomic(&self.dir.join(format!("{name}.json")), j.to_string().as_bytes())?;
        Ok(())
    }

    pub fn note(&self, msg: &str) {
        if !self.quiet {
            // mutlint: allow(bus-only-output, "Reporter notes are the exp CLI's stdout contract; quiet() is the daemon-side off switch")
            println!("{msg}");
        }
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::jnum;

    #[test]
    fn writes_csv_and_json() {
        let dir = std::env::temp_dir().join("mutransfer_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Reporter::new(dir.clone());
        r.quiet = true;
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into()]);
        r.table("tab", &t).unwrap();
        r.json("blob", &Json::from_pairs(vec![("v", jnum(3.0))])).unwrap();
        assert!(dir.join("tab.csv").exists());
        let s = std::fs::read_to_string(dir.join("blob.json")).unwrap();
        assert!(s.contains("\"v\""));
        // atomic publish leaves no tmp residue behind
        assert!(!dir.join(".tab.csv.tmp").exists());
        assert!(!dir.join(".blob.json.tmp").exists());
    }
}
