//! Result persistence: every experiment writes (a) a paper-style text
//! table to stdout, (b) CSV series under `results/`, and (c) a JSON blob
//! with the raw numbers, so EXPERIMENTS.md entries are regenerable.

use std::path::PathBuf;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::table::Table;

pub struct Reporter {
    dir: PathBuf,
    pub quiet: bool,
}

impl Reporter {
    pub fn new(dir: PathBuf) -> Reporter {
        let _ = std::fs::create_dir_all(&dir);
        Reporter { dir, quiet: false }
    }

    pub fn default_results() -> Reporter {
        Reporter::new(crate::results_dir())
    }

    /// Print a table and persist its CSV twin.
    pub fn table(&self, name: &str, t: &Table) -> Result<()> {
        if !self.quiet {
            println!("{}", t.render());
        }
        std::fs::write(self.dir.join(format!("{name}.csv")), t.to_csv())?;
        Ok(())
    }

    /// Persist raw JSON (figure series, trial dumps).
    pub fn json(&self, name: &str, j: &Json) -> Result<()> {
        std::fs::write(self.dir.join(format!("{name}.json")), j.to_string())?;
        Ok(())
    }

    pub fn note(&self, msg: &str) {
        if !self.quiet {
            println!("{msg}");
        }
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::jnum;

    #[test]
    fn writes_csv_and_json() {
        let dir = std::env::temp_dir().join("mutransfer_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Reporter::new(dir.clone());
        r.quiet = true;
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into()]);
        r.table("tab", &t).unwrap();
        r.json("blob", &Json::from_pairs(vec![("v", jnum(3.0))])).unwrap();
        assert!(dir.join("tab.csv").exists());
        let s = std::fs::read_to_string(dir.join("blob.json")).unwrap();
        assert!(s.contains("\"v\""));
    }
}
