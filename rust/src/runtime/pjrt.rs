//! PJRT/XLA backend (cargo feature `pjrt`, off by default): load AOT
//! artifacts (HLO text) lowered by `python/compile/aot.py`, compile once
//! per variant, and drive training/eval with host-resident state.
//!
//! Enabling this feature requires the `xla` crate (0.1.6): in Cargo.toml
//! uncomment the dependency line AND change the feature to
//! `pjrt = ["dep:xla"]`.  It is intentionally not resolved in default
//! builds so the crate stays hermetic on machines without the XLA
//! toolchain.
//!
//! State handling: PJRT (via the `xla` crate) returns a computation's
//! outputs as a single tuple buffer, so params/opt-state round-trip
//! through host `Literal`s each step (`decompose_tuple` is a move; the
//! dominant cost is one memcpy each way).  On the CPU backend that is a
//! few percent of step time at our sizes, and it buys a Python-free
//! runtime.  Executables are cached per variant and shared by every trial
//! in a sweep.  The PJRT client (and the `Rc`/`RefCell` executable cache)
//! is not `Send`, so this backend *declines* the parallel capabilities:
//! it keeps the trait defaults `parallelism() == 1` and
//! `session_send() == Ok(None)`, and `Sweep::run` falls back to its
//! sequential loop regardless of the requested `--workers`.  For the same
//! reason it declines the checkpoint capabilities (`state() == Ok(None)`,
//! `restore() == Ok(false)`): the live tuple buffer would have to be
//! decomposed mid-stream to snapshot it.  Checkpointing callers
//! (`train::run_ckpt`, the sweep's `--checkpoint-dir` path, SHA) detect
//! the declined capability and transparently run trials from step 0.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::backend::{Backend, BackendSession, DataBatch, Probe};
use super::manifest::{Kind, Manifest, Variant};

pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) the executable for a variant.
    pub fn executable(&self, variant: &Variant) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&variant.name) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            variant
                .hlo_path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("loading HLO text for {}", variant.name))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {}", variant.name))?;
        let exe = Rc::new(exe);
        self.cache
            .borrow_mut()
            .insert(variant.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached (telemetry).
    pub fn cache_size(&self) -> usize {
        self.cache.borrow().len()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn session(
        &self,
        manifest: &Manifest,
        variant: &Variant,
        init: Vec<Vec<f32>>,
    ) -> Result<Box<dyn BackendSession>> {
        let exe = self.executable(variant)?;
        // eval twin, if the registry shipped one (train variants do)
        let eval_name = format!("{}__eval", variant.name.trim_end_matches("__coord"));
        let eval_exe = manifest
            .get(&eval_name)
            .ok()
            .and_then(|v| self.executable(v).ok());
        let mut state = Vec::with_capacity(variant.n_params() * (1 + variant.n_state));
        for (p, data) in variant.params.iter().zip(&init) {
            state.push(to_lit_f32(data, &p.shape)?);
        }
        for _ in 0..variant.n_state {
            for p in &variant.params {
                state.push(to_lit_f32(&vec![0.0; p.numel()], &p.shape)?);
            }
        }
        Ok(Box::new(PjrtSession {
            variant: variant.clone(),
            exe,
            eval_exe,
            state,
        }))
    }
}

struct PjrtSession {
    variant: Variant,
    exe: Rc<xla::PjRtLoadedExecutable>,
    eval_exe: Option<Rc<xla::PjRtLoadedExecutable>>,
    /// params followed by n_state moment blocks, each n_params literals
    state: Vec<xla::Literal>,
}

impl BackendSession for PjrtSession {
    fn step(
        &mut self,
        data: &[DataBatch],
        lr_vec: &[f32],
        gmul: &[f32],
        hp_vec: &[f32; 8],
        want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)> {
        // The AOT-lowered executables take (lr_vec, hp_vec) only; a
        // non-trivial per-tensor gradient multiplier (u-μP fold residue)
        // cannot be applied, and silently dropping it would train a
        // different model than the native backend.
        if gmul.iter().any(|&g| g != 1.0) {
            bail!(
                "the pjrt backend does not support per-tensor gradient \
                 multipliers (gmul_vec); use the native backend for u-μP"
            );
        }
        let p = self.variant.n_params();
        let data_lits: Vec<xla::Literal> =
            data.iter().map(to_literal).collect::<Result<_>>()?;
        let lr_lit = to_lit_f32(lr_vec, &[p])?;
        let hp_lit = to_lit_f32(hp_vec, &[8])?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.variant.n_inputs());
        args.extend(data_lits.iter());
        args.extend(self.state.iter());
        args.push(&lr_lit);
        args.push(&hp_lit);

        let result = self.exe.execute::<&xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut outs = tuple.to_tuple()?;
        if outs.len() != self.variant.n_outputs() {
            bail!(
                "executable returned {} outputs, manifest says {}",
                outs.len(),
                self.variant.n_outputs()
            );
        }
        let probes = if want_probes {
            let names = self.variant.probes.clone();
            let tail = outs.split_off(outs.len() - names.len());
            names
                .into_iter()
                .zip(tail)
                .map(|(name, lit)| {
                    Ok(Probe {
                        name,
                        data: lit.to_vec::<f32>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?
        } else if self.variant.kind == Kind::Coord {
            outs.truncate(outs.len() - self.variant.probes.len());
            Vec::new()
        } else {
            Vec::new()
        };
        let loss = outs[0].get_first_element::<f32>()?;
        self.state = outs.split_off(1);
        Ok((loss, probes))
    }

    fn eval(&self, data: &[DataBatch], hp_vec: &[f32; 8]) -> Result<f32> {
        let exe = self
            .eval_exe
            .as_ref()
            .context("no eval twin artifact for this variant")?;
        let data_lits: Vec<xla::Literal> =
            data.iter().map(to_literal).collect::<Result<_>>()?;
        let hp_lit = to_lit_f32(hp_vec, &[8])?;
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(data_lits.iter());
        args.extend(self.state.iter().take(self.variant.n_params()));
        args.push(&hp_lit);
        let result = exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.get_first_element::<f32>()?)
    }

    fn param(&self, idx: usize) -> Result<Vec<f32>> {
        Ok(self.state[idx].to_vec::<f32>()?)
    }
}

fn to_literal(d: &DataBatch) -> Result<xla::Literal> {
    let lit = match d {
        DataBatch::I32(v, shape) => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(v.as_slice()).reshape(&dims)?
        }
        DataBatch::F32(v, shape) => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(v.as_slice()).reshape(&dims)?
        }
    };
    Ok(lit)
}

fn to_lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}
