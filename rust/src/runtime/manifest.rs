//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  Parses `artifacts/manifest.json` into typed structs and
//! knows each variant's flat input/output calling convention.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::mup::Role;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Transformer,
    Mlp,
    ResMlp,
}

impl Arch {
    fn parse(s: &str) -> Result<Arch> {
        Ok(match s {
            "transformer" => Arch::Transformer,
            "mlp" => Arch::Mlp,
            "resmlp" => Arch::ResMlp,
            other => bail!("unknown arch {other}"),
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Train,
    Eval,
    Coord,
}

impl Kind {
    fn parse(s: &str) -> Result<Kind> {
        Ok(match s {
            "train" => Kind::Train,
            "eval" => Kind::Eval,
            "coord" => Kind::Coord,
            other => bail!("unknown kind {other}"),
        })
    }
}

/// One parameter tensor as described by the manifest.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: Role,
    pub fan_in: usize,
    pub fan_out: usize,
    /// "normal" | "zeros" | "ones"
    pub init: String,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct DataInput {
    pub name: String,
    /// "f32" | "i32"
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// Golden values recorded at AOT time for cross-language verification.
#[derive(Debug, Clone)]
pub struct Golden {
    pub seed: u64,
    pub losses: Vec<f64>,
    pub lr: f64,
}

/// Model-shape fields shared by the experiment drivers; arch-specific
/// fields are optional.
#[derive(Debug, Clone, Default)]
pub struct ModelConfig {
    pub fields: BTreeMap<String, f64>,
}

impl ModelConfig {
    pub fn get(&self, key: &str) -> Option<usize> {
        self.fields.get(key).map(|v| *v as usize)
    }

    pub fn req(&self, key: &str) -> usize {
        self.get(key)
            .unwrap_or_else(|| panic!("config missing {key}"))
    }

    pub fn str_fields(&self) -> &BTreeMap<String, f64> {
        &self.fields
    }
}

#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub arch: Arch,
    pub kind: Kind,
    /// "adam" | "sgd"
    pub opt: String,
    pub hlo_path: PathBuf,
    pub config: ModelConfig,
    /// string-valued config fields (e.g. ln = pre|post, act, loss)
    pub config_str: BTreeMap<String, String>,
    pub data_inputs: Vec<DataInput>,
    pub n_state: usize,
    pub probes: Vec<String>,
    pub params: Vec<ParamInfo>,
    pub golden: Option<Golden>,
}

impl Variant {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Flat input count for this variant's executable.
    pub fn n_inputs(&self) -> usize {
        let p = self.n_params();
        match self.kind {
            Kind::Train | Kind::Coord => self.data_inputs.len() + p * (1 + self.n_state) + 2,
            Kind::Eval => self.data_inputs.len() + p + 1,
        }
    }

    /// Flat output count (loss + new params/state [+ probes]).
    pub fn n_outputs(&self) -> usize {
        let p = self.n_params();
        match self.kind {
            Kind::Train => 1 + p * (1 + self.n_state),
            Kind::Coord => 1 + p * (1 + self.n_state) + self.probes.len(),
            Kind::Eval => 1,
        }
    }

    /// Estimated training FLOPs per step (fwd+bwd ≈ 6·params·tokens for
    /// token models, 6·params·batch for vector models) — the currency of
    /// the paper's tuning-budget comparisons (§7.1, App. F.4).
    pub fn flops_per_step(&self) -> f64 {
        let params = self.total_numel() as f64;
        let items = match self.arch {
            Arch::Transformer => (self.config.req("batch") * self.config.req("seq")) as f64,
            _ => self.config.req("batch") as f64,
        };
        6.0 * params * items
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let json = crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let mut variants = BTreeMap::new();
        for v in json.req("variants").as_arr().context("variants not array")? {
            let var = parse_variant(v, dir)?;
            variants.insert(var.name.clone(), var);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            variants,
        })
    }

    pub fn get(&self, name: &str) -> Result<&Variant> {
        self.variants.get(name).with_context(|| {
            format!(
                "variant {name} not in manifest ({} known); run `make artifacts`",
                self.variants.len()
            )
        })
    }

    /// Names matching a predicate (used by `list-artifacts`).
    pub fn names(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }
}

fn parse_variant(v: &Json, dir: &Path) -> Result<Variant> {
    let name = v.req("name").as_str().context("name")?.to_string();
    let mut config = ModelConfig::default();
    let mut config_str = BTreeMap::new();
    if let Json::Obj(m) = v.req("config") {
        for (k, val) in m {
            match val {
                Json::Num(n) => {
                    config.fields.insert(k.clone(), *n);
                }
                Json::Str(s) => {
                    config_str.insert(k.clone(), s.clone());
                }
                _ => {}
            }
        }
    }
    let params = v
        .req("params")
        .as_arr()
        .context("params")?
        .iter()
        .map(|p| {
            let role_s = p.req("role").as_str().context("role")?;
            Ok(ParamInfo {
                name: p.req("name").as_str().context("pname")?.to_string(),
                shape: p
                    .req("shape")
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
                role: Role::parse(role_s)
                    .with_context(|| format!("bad role {role_s}"))?,
                fan_in: p.req("fan_in").as_usize().context("fan_in")?,
                fan_out: p.req("fan_out").as_usize().context("fan_out")?,
                init: p.req("init").as_str().context("init")?.to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let data_inputs = v
        .req("data_inputs")
        .as_arr()
        .context("data_inputs")?
        .iter()
        .map(|d| DataInput {
            name: d.req("name").as_str().unwrap().to_string(),
            dtype: d.req("dtype").as_str().unwrap().to_string(),
            shape: d
                .req("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect(),
        })
        .collect();
    let golden = match v.get("golden") {
        Some(g) if !g.is_null() => Some(Golden {
            seed: g.req("seed").as_f64().context("gseed")? as u64,
            losses: g
                .req("losses")
                .as_arr()
                .context("glosses")?
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect(),
            lr: g.req("lr").as_f64().context("glr")?,
        }),
        _ => None,
    };
    Ok(Variant {
        arch: Arch::parse(v.req("arch").as_str().context("arch")?)?,
        kind: Kind::parse(v.req("kind").as_str().context("kind")?)?,
        opt: v.req("opt").as_str().context("opt")?.to_string(),
        hlo_path: dir.join(v.req("hlo").as_str().context("hlo")?),
        config,
        config_str,
        data_inputs,
        n_state: v.req("n_state").as_usize().context("n_state")?,
        probes: v
            .req("probes")
            .as_arr()
            .context("probes")?
            .iter()
            .map(|p| p.as_str().unwrap().to_string())
            .collect(),
        params,
        golden,
        name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> String {
        r#"{"version": 1, "variants": [
            {"name": "t1", "arch": "transformer", "kind": "train", "opt": "adam",
             "hlo": "t1.hlo.txt",
             "config": {"vocab": 64, "seq": 32, "batch": 16, "d_model": 128,
                        "n_layer": 2, "n_head": 4, "d_head": 32, "d_ffn": 512,
                        "ln": "pre"},
             "data_inputs": [{"name": "tokens", "dtype": "i32", "shape": [16, 33]}],
             "n_state": 2, "probes": [],
             "params": [
               {"name": "embed", "shape": [64, 128], "role": "input",
                "fan_in": 64, "fan_out": 128, "init": "normal"},
               {"name": "unembed", "shape": [128, 64], "role": "output",
                "fan_in": 128, "fan_out": 64, "init": "zeros"}],
             "golden": {"seed": 7, "losses": [4.1, 4.0], "lr": 0.001}}
        ]}"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("mutransfer_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.get("t1").unwrap();
        assert_eq!(v.arch, Arch::Transformer);
        assert_eq!(v.kind, Kind::Train);
        assert_eq!(v.n_params(), 2);
        assert_eq!(v.config.req("d_model"), 128);
        assert_eq!(v.config_str.get("ln").unwrap(), "pre");
        assert_eq!(v.params[0].role, Role::Input);
        assert_eq!(v.params[1].init, "zeros");
        let g = v.golden.as_ref().unwrap();
        assert_eq!(g.seed, 7);
        assert_eq!(g.losses, vec![4.1, 4.0]);
        // calling convention: tokens + 2p + 2*2p... n_inputs = 1 + 2*(1+2) + 2 = 9
        assert_eq!(v.n_inputs(), 1 + 2 * 3 + 2);
        assert_eq!(v.n_outputs(), 1 + 2 * 3);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn flops_estimate() {
        let dir = std::env::temp_dir().join("mutransfer_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.get("t1").unwrap();
        let numel = (64 * 128 + 128 * 64) as f64;
        assert_eq!(v.flops_per_step(), 6.0 * numel * (16.0 * 32.0));
    }
}
