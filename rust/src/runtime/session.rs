//! Training session: the hot path, backend-agnostic.  One session = one
//! model being trained (one trial of a sweep, or the end-to-end example).
//!
//! The cross-backend invariants — variant-kind checks, init validation
//! against the param specs, the data-input arity check, and the 1-based
//! Adam step counter in `hp_vec[7]` — live in [`SessionCore`], so each
//! [`crate::runtime::Backend`] implements only the math.  The core is
//! generic over the session pointer's bound: [`TrainSession`] wraps a
//! plain `dyn BackendSession` for single-threaded callers, while the
//! sweep scheduler's worker threads drive a
//! `SessionCore<dyn BackendSession + Send>` obtained through
//! [`crate::runtime::Backend::session_send`] (see `train::prepare`).

use anyhow::{bail, Context, Result};

use super::backend::{BackendSession, ModelState};
pub use super::backend::{DataBatch, Probe, StepInputs};
use super::manifest::{Kind, Variant};
use super::Runtime;

/// Check a host-side init against a variant's param specs and reject eval
/// variants — shared by every session-construction path (`TrainSession`,
/// `train::prepare`) so the backend only ever sees validated inputs.
pub fn validate_init(variant: &Variant, variant_name: &str, init: &[Vec<f32>]) -> Result<()> {
    if variant.kind == Kind::Eval {
        bail!("{variant_name} is an eval variant; use the train/coord one");
    }
    if init.len() != variant.n_params() {
        bail!(
            "init has {} tensors, variant {} has {}",
            init.len(),
            variant_name,
            variant.n_params()
        );
    }
    for (p, data) in variant.params.iter().zip(init) {
        if data.len() != p.numel() {
            bail!("param {} expects {} elements, got {}", p.name, p.numel(), data.len());
        }
    }
    Ok(())
}

/// The invariant-owning wrapper around a backend session.  `S` is the
/// session pointer's bound: `dyn BackendSession` (single-threaded) or
/// `dyn BackendSession + Send` (sweep worker threads).  When `S: Send`,
/// the whole core is `Send` — `Variant` is plain data.
pub struct SessionCore<S: BackendSession + ?Sized> {
    pub variant: Variant,
    inner: Box<S>,
    /// number of optimizer steps taken (drives Adam bias correction)
    pub steps_done: usize,
}

impl<S: BackendSession + ?Sized> SessionCore<S> {
    /// Wrap an already-constructed backend session.  Callers must have
    /// run [`validate_init`] (the backends assume validated shapes).
    pub fn new(variant: Variant, inner: Box<S>) -> SessionCore<S> {
        SessionCore {
            variant,
            inner,
            steps_done: 0,
        }
    }

    /// One optimizer step.  Returns the training loss *before* the update.
    pub fn step(&mut self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        let (loss, _probes) = self.step_inner(data, inputs, false)?;
        Ok(loss)
    }

    /// One step that also returns the coordinate-check probe tensors
    /// (requires a `coord` variant).
    pub fn step_with_probes(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
    ) -> Result<(f32, Vec<Probe>)> {
        if self.variant.kind != Kind::Coord {
            bail!("step_with_probes requires a coord variant");
        }
        self.step_inner(data, inputs, true)
    }

    fn step_inner(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
        want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)> {
        let p = self.variant.n_params();
        if inputs.lr_vec.len() != p {
            bail!("lr_vec has {} entries, expected {p}", inputs.lr_vec.len());
        }
        if !inputs.gmul_vec.is_empty() && inputs.gmul_vec.len() != p {
            bail!(
                "gmul_vec has {} entries, expected 0 or {p}",
                inputs.gmul_vec.len()
            );
        }
        if data.len() != self.variant.data_inputs.len() {
            bail!("expected {} data inputs", self.variant.data_inputs.len());
        }
        // Adam bias correction wants the 1-based step index.
        let mut hp = inputs.hp_vec;
        if self.variant.opt == "adam" {
            hp[7] = (self.steps_done + 1) as f32;
        }
        let out = self
            .inner
            .step(data, &inputs.lr_vec, &inputs.gmul_vec, &hp, want_probes)?;
        self.steps_done += 1;
        Ok(out)
    }

    /// Forward-only loss on a batch with the *current* parameters.
    pub fn eval(&self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        if data.len() != self.variant.data_inputs.len() {
            bail!("expected {} data inputs", self.variant.data_inputs.len());
        }
        self.inner.eval(data, &inputs.hp_vec)
    }

    /// Copy a parameter tensor back to the host (diagnostics / checkpoints).
    pub fn param(&self, idx: usize) -> Result<Vec<f32>> {
        self.inner.param(idx)
    }

    /// Snapshot the backend's full mutable state (params + optimizer
    /// moments).  `Ok(None)` when the backend declines the capability
    /// (PJRT) — checkpointing callers then no-op.
    pub fn state(&self) -> Result<Option<ModelState>> {
        self.inner.state()
    }

    /// Restore backend state *and* the step counter from a snapshot (the
    /// counter drives Adam bias correction through `hp_vec[7]`, so the two
    /// must move together).  `Ok(false)` when the backend declines — the
    /// caller keeps its freshly-initialized session and runs from step 0.
    pub fn restore(&mut self, state: &ModelState, steps_done: usize) -> Result<bool> {
        if self.inner.restore(state)? {
            self.steps_done = steps_done;
            Ok(true)
        } else {
            Ok(false)
        }
    }
}

pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    core: SessionCore<dyn BackendSession>,
}

impl<'rt> TrainSession<'rt> {
    /// Build a session from host-side initial parameters (one `Vec<f32>`
    /// per tensor, in manifest order).  Opt-state starts at zero.
    pub fn new(
        rt: &'rt Runtime,
        variant_name: &str,
        init: Vec<Vec<f32>>,
    ) -> Result<TrainSession<'rt>> {
        let variant = rt.manifest().get(variant_name)?.clone();
        validate_init(&variant, variant_name, &init)?;
        let inner = rt
            .backend()
            .session(rt.manifest(), &variant, init)
            .with_context(|| {
                format!("creating {} session for {variant_name}", rt.backend().name())
            })?;
        Ok(TrainSession {
            rt,
            core: SessionCore::new(variant, inner),
        })
    }

    pub fn variant(&self) -> &Variant {
        &self.core.variant
    }

    pub fn steps_done(&self) -> usize {
        self.core.steps_done
    }

    /// One optimizer step.  Returns the training loss *before* the update.
    pub fn step(&mut self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        self.core.step(data, inputs)
    }

    /// One step that also returns the coordinate-check probe tensors
    /// (requires a `coord` variant).
    pub fn step_with_probes(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
    ) -> Result<(f32, Vec<Probe>)> {
        self.core.step_with_probes(data, inputs)
    }

    /// Forward-only loss on a batch with the *current* parameters.
    pub fn eval(&self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        self.core.eval(data, inputs)
    }

    /// Copy a parameter tensor back to the host (diagnostics / checkpoints).
    pub fn param(&self, idx: usize) -> Result<Vec<f32>> {
        self.core.param(idx)
    }

    /// Snapshot the full session state; `None` if the backend declines
    /// (see [`SessionCore::state`]).
    pub fn state(&self) -> Result<Option<ModelState>> {
        self.core.state()
    }

    /// Restore state + step counter; `false` if the backend declines
    /// (see [`SessionCore::restore`]).
    pub fn restore(&mut self, state: &ModelState, steps_done: usize) -> Result<bool> {
        self.core.restore(state, steps_done)
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}
