//! Training session: the hot path.  One session = one model being trained
//! (one trial of a sweep, or the end-to-end example).

use anyhow::{bail, Context, Result};

use super::manifest::{Kind, Variant};
use super::Runtime;

/// A host-side batch ready to become a PJRT literal.
#[derive(Debug, Clone)]
pub enum DataBatch {
    I32(Vec<i32>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
}

impl DataBatch {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            DataBatch::I32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v.as_slice()).reshape(&dims)?
            }
            DataBatch::F32(v, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v.as_slice()).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// A probe tensor copied back to the host (coordinate checking).
#[derive(Debug, Clone)]
pub struct Probe {
    pub name: String,
    pub data: Vec<f32>,
}

/// Hyperparameter inputs fed to the executable every step.
#[derive(Debug, Clone)]
pub struct StepInputs {
    /// per-tensor effective LR (μP scale × master LR × schedule)
    pub lr_vec: Vec<f32>,
    /// slots 0..7 — see python/compile/model.py HP_* constants
    pub hp_vec: [f32; 8],
}

pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    pub variant: Variant,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    eval_exe: Option<std::rc::Rc<xla::PjRtLoadedExecutable>>,
    /// params followed by n_state moment blocks, each n_params literals
    state: Vec<xla::Literal>,
    /// number of optimizer steps taken (drives Adam bias correction)
    pub steps_done: usize,
}

impl<'rt> TrainSession<'rt> {
    /// Build a session from host-side initial parameters (one `Vec<f32>`
    /// per tensor, in manifest order).  Opt-state starts at zero.
    pub fn new(rt: &'rt Runtime, variant_name: &str, init: Vec<Vec<f32>>) -> Result<TrainSession<'rt>> {
        let variant = rt.manifest().get(variant_name)?.clone();
        if variant.kind == Kind::Eval {
            bail!("{variant_name} is an eval variant; use the train/coord one");
        }
        if init.len() != variant.n_params() {
            bail!(
                "init has {} tensors, variant {} has {}",
                init.len(),
                variant_name,
                variant.n_params()
            );
        }
        let exe = rt.executable(variant_name)?;
        // eval twin, if the registry shipped one (train variants do)
        let eval_name = format!("{}__eval", variant.name.trim_end_matches("__coord"));
        let eval_exe = rt.executable(&eval_name).ok();

        let mut state = Vec::with_capacity(variant.n_params() * (1 + variant.n_state));
        for (p, data) in variant.params.iter().zip(&init) {
            if data.len() != p.numel() {
                bail!("param {} expects {} elements, got {}", p.name, p.numel(), data.len());
            }
            state.push(to_lit_f32(data, &p.shape)?);
        }
        for _ in 0..variant.n_state {
            for p in &variant.params {
                state.push(to_lit_f32(&vec![0.0; p.numel()], &p.shape)?);
            }
        }
        Ok(TrainSession {
            rt,
            variant,
            exe,
            eval_exe,
            state,
            steps_done: 0,
        })
    }

    /// One optimizer step.  Returns the training loss *before* the update.
    pub fn step(&mut self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        let (loss, _probes) = self.step_inner(data, inputs, false)?;
        Ok(loss)
    }

    /// One step that also returns the coordinate-check probe tensors
    /// (requires a `coord` variant).
    pub fn step_with_probes(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
    ) -> Result<(f32, Vec<Probe>)> {
        if self.variant.kind != Kind::Coord {
            bail!("step_with_probes requires a coord variant");
        }
        self.step_inner(data, inputs, true)
    }

    fn step_inner(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
        want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)> {
        let p = self.variant.n_params();
        if inputs.lr_vec.len() != p {
            bail!("lr_vec has {} entries, expected {p}", inputs.lr_vec.len());
        }
        if data.len() != self.variant.data_inputs.len() {
            bail!("expected {} data inputs", self.variant.data_inputs.len());
        }
        // Adam bias correction wants the 1-based step index.
        let mut hp = inputs.hp_vec;
        if self.variant.opt == "adam" {
            hp[7] = (self.steps_done + 1) as f32;
        }
        let data_lits: Vec<xla::Literal> =
            data.iter().map(|d| d.to_literal()).collect::<Result<_>>()?;
        let lr_lit = to_lit_f32(&inputs.lr_vec, &[p])?;
        let hp_lit = to_lit_f32(&hp, &[8])?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.variant.n_inputs());
        args.extend(data_lits.iter());
        args.extend(self.state.iter());
        args.push(&lr_lit);
        args.push(&hp_lit);

        let result = self.exe.execute::<&xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut outs = tuple.to_tuple()?;
        if outs.len() != self.variant.n_outputs() {
            bail!(
                "executable returned {} outputs, manifest says {}",
                outs.len(),
                self.variant.n_outputs()
            );
        }
        let probes = if want_probes {
            let names = self.variant.probes.clone();
            let tail = outs.split_off(outs.len() - names.len());
            names
                .into_iter()
                .zip(tail)
                .map(|(name, lit)| {
                    Ok(Probe {
                        name,
                        data: lit.to_vec::<f32>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?
        } else if self.variant.kind == Kind::Coord {
            outs.truncate(outs.len() - self.variant.probes.len());
            Vec::new()
        } else {
            Vec::new()
        };
        let loss = outs[0].get_first_element::<f32>()?;
        self.state = outs.split_off(1);
        self.steps_done += 1;
        Ok((loss, probes))
    }

    /// Forward-only loss on a batch with the *current* parameters, via the
    /// eval twin executable.  Borrows the resident param literals (no state
    /// copy).
    pub fn eval(&self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        let exe = self
            .eval_exe
            .as_ref()
            .context("no eval twin artifact for this variant")?;
        let data_lits: Vec<xla::Literal> =
            data.iter().map(|d| d.to_literal()).collect::<Result<_>>()?;
        let hp_lit = to_lit_f32(&inputs.hp_vec, &[8])?;
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(data_lits.iter());
        args.extend(self.state.iter().take(self.variant.n_params()));
        args.push(&hp_lit);
        let result = exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.get_first_element::<f32>()?)
    }

    /// Copy a parameter tensor back to the host (diagnostics / checkpoints).
    pub fn param(&self, idx: usize) -> Result<Vec<f32>> {
        Ok(self.state[idx].to_vec::<f32>()?)
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}

fn to_lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}
