//! Training session: the hot path, backend-agnostic.  One session = one
//! model being trained (one trial of a sweep, or the end-to-end example).
//!
//! The session owns the cross-backend invariants — variant-kind checks,
//! init validation against the param specs, the data-input arity check,
//! and the 1-based Adam step counter in `hp_vec[7]` — so each
//! [`crate::runtime::Backend`] implements only the math.

use anyhow::{bail, Context, Result};

use super::backend::BackendSession;
pub use super::backend::{DataBatch, Probe, StepInputs};
use super::manifest::{Kind, Variant};
use super::Runtime;

pub struct TrainSession<'rt> {
    rt: &'rt Runtime,
    pub variant: Variant,
    inner: Box<dyn BackendSession>,
    /// number of optimizer steps taken (drives Adam bias correction)
    pub steps_done: usize,
}

impl<'rt> TrainSession<'rt> {
    /// Build a session from host-side initial parameters (one `Vec<f32>`
    /// per tensor, in manifest order).  Opt-state starts at zero.
    pub fn new(
        rt: &'rt Runtime,
        variant_name: &str,
        init: Vec<Vec<f32>>,
    ) -> Result<TrainSession<'rt>> {
        let variant = rt.manifest().get(variant_name)?.clone();
        if variant.kind == Kind::Eval {
            bail!("{variant_name} is an eval variant; use the train/coord one");
        }
        if init.len() != variant.n_params() {
            bail!(
                "init has {} tensors, variant {} has {}",
                init.len(),
                variant_name,
                variant.n_params()
            );
        }
        for (p, data) in variant.params.iter().zip(&init) {
            if data.len() != p.numel() {
                bail!("param {} expects {} elements, got {}", p.name, p.numel(), data.len());
            }
        }
        let inner = rt
            .backend()
            .session(rt.manifest(), &variant, init)
            .with_context(|| {
                format!("creating {} session for {variant_name}", rt.backend().name())
            })?;
        Ok(TrainSession {
            rt,
            variant,
            inner,
            steps_done: 0,
        })
    }

    /// One optimizer step.  Returns the training loss *before* the update.
    pub fn step(&mut self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        let (loss, _probes) = self.step_inner(data, inputs, false)?;
        Ok(loss)
    }

    /// One step that also returns the coordinate-check probe tensors
    /// (requires a `coord` variant).
    pub fn step_with_probes(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
    ) -> Result<(f32, Vec<Probe>)> {
        if self.variant.kind != Kind::Coord {
            bail!("step_with_probes requires a coord variant");
        }
        self.step_inner(data, inputs, true)
    }

    fn step_inner(
        &mut self,
        data: &[DataBatch],
        inputs: &StepInputs,
        want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)> {
        let p = self.variant.n_params();
        if inputs.lr_vec.len() != p {
            bail!("lr_vec has {} entries, expected {p}", inputs.lr_vec.len());
        }
        if data.len() != self.variant.data_inputs.len() {
            bail!("expected {} data inputs", self.variant.data_inputs.len());
        }
        // Adam bias correction wants the 1-based step index.
        let mut hp = inputs.hp_vec;
        if self.variant.opt == "adam" {
            hp[7] = (self.steps_done + 1) as f32;
        }
        let out = self.inner.step(data, &inputs.lr_vec, &hp, want_probes)?;
        self.steps_done += 1;
        Ok(out)
    }

    /// Forward-only loss on a batch with the *current* parameters.
    pub fn eval(&self, data: &[DataBatch], inputs: &StepInputs) -> Result<f32> {
        if data.len() != self.variant.data_inputs.len() {
            bail!("expected {} data inputs", self.variant.data_inputs.len());
        }
        self.inner.eval(data, &inputs.hp_vec)
    }

    /// Copy a parameter tensor back to the host (diagnostics / checkpoints).
    pub fn param(&self, idx: usize) -> Result<Vec<f32>> {
        self.inner.param(idx)
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt
    }
}
