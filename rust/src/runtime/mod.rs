//! PJRT runtime: load AOT artifacts (HLO text), compile once per variant,
//! and drive training/eval loops with host-resident state.
//!
//! Layering (DESIGN.md §1): Python lowers each model variant once at build
//! time; at run time this module is the *only* code that talks to XLA.
//! The tuner/sweep/experiment layers above deal purely in losses and HP
//! assignments.
//!
//! State handling: PJRT (via the `xla` crate 0.1.6) returns a computation's
//! outputs as a single tuple buffer, so params/opt-state round-trip through
//! host `Literal`s each step (`decompose_tuple` is a move, the dominant
//! cost is one memcpy each way).  On this CPU backend that is a few
//! percent of step time at our sizes — measured in EXPERIMENTS.md §Perf —
//! and it buys a dependency-free runtime.  Executables are cached per
//! variant and shared by every trial in a sweep.

pub mod manifest;
pub mod session;

pub use manifest::{Arch, Kind, Manifest, ParamInfo, Variant};
pub use session::{DataBatch, TrainSession};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

/// Owns the PJRT client, the manifest, and the executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (or fetch from cache) the executable for a variant.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let var = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            var.hlo_path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("loading HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached (telemetry).
    pub fn cache_size(&self) -> usize {
        self.cache.borrow().len()
    }
}
