//! Execution runtime: a [`Manifest`] of model variants plus a pluggable
//! [`Backend`] that runs their train/eval/coord steps.
//!
//! Layering (DESIGN.md §1): the tuner/sweep/experiment layers above deal
//! purely in losses and HP assignments; [`TrainSession`] is the only
//! surface they drive.  Two backends implement it:
//!
//! * [`native`] (default) — pure-Rust forward/backward and fused
//!   per-tensor-LR Adam/SGD updates executed directly from the manifest's
//!   param specs.  No Python, no XLA, no artifacts directory: the variant
//!   registry is built in ([`native::registry`]), so `Runtime::native()`
//!   works on any box and the whole verification story (golden
//!   trajectories, coordinate checks, sweeps) runs hermetically.  Its
//!   sessions are `Send` and it implements [`Backend::session_send`] /
//!   unbounded [`Backend::parallelism`], which is what the multi-worker
//!   sweep scheduler (`Sweep::run` with `workers > 1`) fans out through.
//! * `pjrt` (cargo feature `pjrt`, off by default) — loads AOT-lowered HLO
//!   text artifacts produced by `python/compile/aot.py` and executes them
//!   through XLA via the `xla` crate.  State round-trips through host
//!   literals each step; executables are cached per variant and shared by
//!   every trial in a sweep.
//!
//! [`Runtime::new`] prefers PJRT when it is compiled in *and* an artifacts
//! manifest exists at the given path, and falls back to the native backend
//! otherwise — so every caller (CLI, examples, benches, tests) is
//! backend-agnostic.

pub mod backend;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod session;

pub use backend::{Backend, BackendSession, DataBatch, ModelState, Probe, StepInputs};
pub use manifest::{Arch, Kind, Manifest, ParamInfo, Variant};
pub use session::{SessionCore, TrainSession};

use std::path::Path;

use anyhow::Result;

/// Owns the manifest and the execution backend.
pub struct Runtime {
    manifest: Manifest,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// The hermetic default: pure-Rust execution over the built-in variant
    /// registry (mirrors `python/compile/aot.py::build_registry`).
    pub fn native() -> Runtime {
        Runtime {
            manifest: native::registry::builtin_manifest(),
            backend: Box::new(native::NativeBackend),
        }
    }

    /// Generic constructor: PJRT when compiled with the `pjrt` feature and
    /// `artifacts_dir` holds a manifest; the native backend otherwise.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            if artifacts_dir.join("manifest.json").exists() {
                return Runtime::pjrt(artifacts_dir);
            }
        }
        let _ = artifacts_dir;
        Ok(Runtime::native())
    }

    /// PJRT/XLA execution of the AOT artifacts in `artifacts_dir`.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = pjrt::PjrtBackend::new()?;
        Ok(Runtime {
            manifest,
            backend: Box::new(backend),
        })
    }

    /// Any manifest + any backend (tests, future remote executors).
    pub fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Runtime {
        Runtime { manifest, backend }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_has_builtin_variants() {
        let rt = Runtime::native();
        assert_eq!(rt.backend().name(), "native");
        for name in [
            "tfm_post_w32_d2",
            "tfm_post_w32_d2__eval",
            "tfm_post_w32_d2__coord",
            "tfm_pre_w128_d2",
            "mlp_w64",
            "resmlp_w32",
        ] {
            assert!(rt.manifest().get(name).is_ok(), "{name} missing");
        }
    }

    #[test]
    fn new_falls_back_to_native_without_artifacts() {
        let dir = std::env::temp_dir().join("mutransfer_no_artifacts_here");
        let _ = std::fs::create_dir_all(&dir);
        let rt = Runtime::new(&dir).unwrap();
        assert!(rt.manifest().get("mlp_w64").is_ok());
    }

    /// Mock backend echoing hp_vec[7] as the loss: pins the Backend trait
    /// contract — `with_backend` dispatch, init validation, and the
    /// session-maintained 1-based Adam step counter.
    struct MockBackend;
    struct MockSession;

    impl Backend for MockBackend {
        fn name(&self) -> &'static str {
            "mock"
        }

        fn session(
            &self,
            _manifest: &Manifest,
            _variant: &Variant,
            _init: Vec<Vec<f32>>,
        ) -> Result<Box<dyn BackendSession>> {
            Ok(Box::new(MockSession))
        }
    }

    impl BackendSession for MockSession {
        fn step(
            &mut self,
            _data: &[DataBatch],
            _lr_vec: &[f32],
            _gmul: &[f32],
            hp_vec: &[f32; 8],
            _want_probes: bool,
        ) -> Result<(f32, Vec<Probe>)> {
            Ok((hp_vec[7], Vec::new()))
        }

        fn eval(&self, _data: &[DataBatch], _hp_vec: &[f32; 8]) -> Result<f32> {
            Ok(0.5)
        }

        fn param(&self, _idx: usize) -> Result<Vec<f32>> {
            Ok(Vec::new())
        }
    }

    #[test]
    fn with_backend_dispatches_and_session_drives_step_counter() {
        let rt = Runtime::with_backend(
            native::registry::builtin_manifest(),
            Box::new(MockBackend),
        );
        assert_eq!(rt.backend().name(), "mock");
        let v = rt.manifest().get("tfm_post_w32_d2").unwrap().clone();
        let init: Vec<Vec<f32>> = v.params.iter().map(|p| vec![0.0; p.numel()]).collect();
        let mut s = TrainSession::new(&rt, "tfm_post_w32_d2", init).unwrap();
        let data = vec![DataBatch::I32(Vec::new(), Vec::new())];
        let inputs = StepInputs {
            lr_vec: vec![0.0; v.n_params()],
            gmul_vec: vec![],
            hp_vec: [0.0; 8],
        };
        // adam variant: the session must overwrite hp[7] with 1, 2, ...
        assert_eq!(s.step(&data, &inputs).unwrap(), 1.0);
        assert_eq!(s.step(&data, &inputs).unwrap(), 2.0);
        assert_eq!(s.steps_done(), 2);
        assert_eq!(s.eval(&data, &inputs).unwrap(), 0.5);
        // wrong init length must be rejected before reaching the backend
        assert!(TrainSession::new(&rt, "tfm_post_w32_d2", Vec::new()).is_err());
    }
}
