//! Pure-Rust execution backend: the hermetic default.
//!
//! Runs the MLP and Transformer (and residual-MLP) train/eval/coord steps
//! — forward, hand-derived reverse-mode backward, and the fused
//! per-tensor-LR SGD/Adam update — directly from the manifest's param
//! specs.  No XLA, no Python, no artifacts directory; the variant registry
//! ([`registry`]) is compiled in.  Numerics mirror the JAX graphs through
//! the finite-difference-verified numpy reference
//! (`python/tools/native_ref.py`); the golden-trajectory fixture
//! (`rust/tests/fixtures/goldens.json`) pins agreement to 1e-3 relative.
//!
//! Unlike the PJRT client, every concrete type here is `Send` (asserted
//! in the tests below), which is what lets this backend implement the
//! `Send`-bounded session path ([`crate::runtime::Backend::session_send`])
//! and report unbounded [`crate::runtime::Backend::parallelism`] — the
//! sweep scheduler fans trials out across worker threads through those
//! two capabilities (`sweep::Sweep::run` with `workers > 1`).

pub mod mlp;
pub mod optim;
pub mod registry;
pub mod tensor;
pub mod transformer;

use anyhow::Result;

use super::backend::{Backend, BackendSession};
use super::manifest::{Arch, Manifest, Variant};

/// Stateless factory: all state lives in the per-variant sessions.
pub struct NativeBackend;

/// Either concrete native session, pre-boxing: both are `Send`, so the
/// same constructor serves the plain and the `Send`-bounded trait paths.
enum NativeSession {
    Tfm(transformer::TfmSession),
    Net(mlp::SgdNetSession),
}

fn build_session(variant: &Variant, init: Vec<Vec<f32>>) -> Result<NativeSession> {
    Ok(match variant.arch {
        Arch::Transformer => NativeSession::Tfm(transformer::TfmSession::new(variant, init)?),
        Arch::Mlp | Arch::ResMlp => NativeSession::Net(mlp::SgdNetSession::new(variant, init)?),
    })
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn session(
        &self,
        _manifest: &Manifest,
        variant: &Variant,
        init: Vec<Vec<f32>>,
    ) -> Result<Box<dyn BackendSession>> {
        Ok(match build_session(variant, init)? {
            NativeSession::Tfm(s) => Box::new(s),
            NativeSession::Net(s) => Box::new(s),
        })
    }

    /// Sessions are self-contained and `Send`; any number may run at
    /// once.  Callers (the sweep scheduler) choose the actual worker
    /// count from core count / CLI flags.
    fn parallelism(&self) -> usize {
        usize::MAX
    }

    fn session_send(
        &self,
        _manifest: &Manifest,
        variant: &Variant,
        init: Vec<Vec<f32>>,
    ) -> Result<Option<Box<dyn BackendSession + Send>>> {
        Ok(Some(match build_session(variant, init)? {
            NativeSession::Tfm(s) => Box::new(s),
            NativeSession::Net(s) => Box::new(s),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{DataBatch, StepInputs};
    use crate::runtime::{Runtime, TrainSession};

    fn zeros_init(variant: &Variant) -> Vec<Vec<f32>> {
        variant
            .params
            .iter()
            .map(|p| match p.init.as_str() {
                "ones" => vec![1.0; p.numel()],
                _ => vec![0.0; p.numel()],
            })
            .collect()
    }

    /// With all-zero weights the LM must emit uniform logits: loss ln(V),
    /// exactly, on any token batch — a closed-form anchor with no RNG.
    #[test]
    fn zero_init_transformer_loss_is_log_vocab() {
        let rt = Runtime::native();
        let v = rt.manifest().get("tfm_post_w32_d2").unwrap().clone();
        let mut s = TrainSession::new(&rt, "tfm_post_w32_d2", zeros_init(&v)).unwrap();
        let b = v.config.req("batch");
        let seq = v.config.req("seq");
        let tokens: Vec<i32> = (0..b * (seq + 1)).map(|i| (i % 64) as i32).collect();
        let data = vec![DataBatch::I32(tokens, vec![b, seq + 1])];
        let inputs = StepInputs {
            lr_vec: vec![0.0; v.n_params()],
            gmul_vec: vec![],
            hp_vec: [0.125, 1.0, 1.0, 0.9, 0.999, 1e-8, 0.0, 1.0],
        };
        let loss = s.step(&data, &inputs).unwrap() as f64;
        assert!((loss - 64f64.ln()).abs() < 1e-5, "loss {loss}");
        // zero LR: a second step sees identical params → identical loss
        let loss2 = s.step(&data, &inputs).unwrap() as f64;
        assert_eq!(loss, loss2);
    }

    /// Same anchor for the MLP (zero w3 → uniform softmax → ln(d_out)) and
    /// its eval twin path.
    #[test]
    fn zero_init_mlp_loss_is_log_classes() {
        let rt = Runtime::native();
        let v = rt.manifest().get("mlp_w64").unwrap().clone();
        let s = TrainSession::new(&rt, "mlp_w64", zeros_init(&v)).unwrap();
        let b = v.config.req("batch");
        let d = v.config.req("d_in");
        let data = vec![
            DataBatch::F32(vec![0.5; b * d], vec![b, d]),
            DataBatch::I32((0..b).map(|i| (i % 10) as i32).collect(), vec![b]),
        ];
        let inputs = StepInputs {
            lr_vec: vec![0.0; v.n_params()],
            gmul_vec: vec![],
            hp_vec: [1.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        };
        let loss = s.eval(&data, &inputs).unwrap() as f64;
        assert!((loss - 10f64.ln()).abs() < 1e-5, "loss {loss}");
    }

    /// Every concrete native type must be Send (the whole point vs the
    /// PJRT client) — including the stateful sessions, not just the
    /// field-less factory.
    #[test]
    fn native_backend_and_sessions_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<NativeBackend>();
        assert_send::<transformer::TfmSession>();
        assert_send::<mlp::SgdNetSession>();
    }

    /// The Send-session capability: the native backend hands out a
    /// session that really crosses a thread boundary and computes the
    /// same closed-form anchor there.
    #[test]
    fn session_send_works_across_threads() {
        let rt = Runtime::native();
        assert_eq!(rt.backend().parallelism(), usize::MAX);
        let v = rt.manifest().get("mlp_w64").unwrap().clone();
        let session = rt
            .backend()
            .session_send(rt.manifest(), &v, zeros_init(&v))
            .unwrap()
            .expect("native backend must offer Send sessions");
        let b = v.config.req("batch");
        let d = v.config.req("d_in");
        let loss = std::thread::spawn(move || {
            let data = vec![
                DataBatch::F32(vec![0.5; b * d], vec![b, d]),
                DataBatch::I32((0..b).map(|i| (i % 10) as i32).collect(), vec![b]),
            ];
            session.eval(&data, &[1.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]).unwrap() as f64
        })
        .join()
        .unwrap();
        assert!((loss - 10f64.ln()).abs() < 1e-5, "loss {loss}");
    }
}
