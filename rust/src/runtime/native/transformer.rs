//! Native decoder-only Transformer LM: forward, reverse-mode backward, and
//! the fused per-tensor-LR Adam step (model.py `make_transformer_steps`).
//!
//! Line-by-line mirror of `python/tools/native_ref.py::tfm_fwd_bwd`, whose
//! gradients are finite-difference-verified by `tools/check_grads.py` and
//! whose trajectories anchor `rust/tests/fixtures/goldens.json`.  Pre- and
//! post-layernorm residual wirings are both supported (Fig. 1 uses post,
//! most transfer figures pre).
//!
//! hp_vec slots (model.py HP_*): 0 attn logit scale, 1 output-logit
//! multiplier, 2 embedding multiplier, 3 β₁, 4 β₂, 5 ε, 6 weight decay,
//! 7 one-based Adam step (maintained by the session).

use anyhow::{bail, Result};

use crate::model::TfmConfig;
use crate::runtime::backend::{BackendSession, DataBatch, ModelState, Probe};
use crate::runtime::manifest::{Kind, Variant};

use super::optim::adam_update;
use super::tensor::{
    add, axpy, layernorm, layernorm_bwd, mm, mm_into, mm_nt, mm_nt_into, mm_tn, mm_tn_into,
    pack_head, relu, relu_bwd, scale_in_place, softmax_ctx_fused, unpack_head, xent, LnCache,
};

/// Parameters per block in the manifest layout.
const PB: usize = 10;
/// Offsets inside a block.
const LN1_G: usize = 0;
const LN1_B: usize = 1;
const WQ: usize = 2;
const WK: usize = 3;
const WV: usize = 4;
const WO: usize = 5;
const LN2_G: usize = 6;
const LN2_B: usize = 7;
const W1: usize = 8;
const W2: usize = 9;

pub struct TfmSession {
    cfg: TfmConfig,
    kind: Kind,
    /// manifest order: embed, pos_embed, blocks, [lnf], unembed
    params: Vec<Vec<f32>>,
    /// Adam first/second moments, parallel to `params`
    ms: Vec<Vec<f32>>,
    vs: Vec<Vec<f32>>,
}

struct BlockCache {
    /// attention input (x for post-LN, LN1(x) for pre-LN)
    attn_in: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// (B*H*S, S) softmax probabilities (causal-masked rows)
    prob: Vec<f32>,
    merged: Vec<f32>,
    /// FFN input (x1 for post-LN, LN2(x1) for pre-LN)
    ffn_in: Vec<f32>,
    u: Vec<f32>,
    r: Vec<f32>,
    ln1: LnCache,
    ln2: LnCache,
}

struct Forward {
    loss: f64,
    /// dlogits already divided by row count (None for eval)
    dlogits: Option<Vec<f32>>,
    x0: Vec<f32>,
    alog0: Vec<f32>,
    xf: Vec<f32>,
    logits: Vec<f32>,
    blocks: Vec<BlockCache>,
    lnf: Option<LnCache>,
    t_in: Vec<usize>,
}

impl TfmSession {
    pub fn new(variant: &Variant, init: Vec<Vec<f32>>) -> Result<TfmSession> {
        let cfg = TfmConfig::from_variant(variant);
        let expected = 2 + cfg.n_layer * PB + if cfg.pre_ln { 2 } else { 0 } + 1;
        if init.len() != expected {
            bail!(
                "transformer layout mismatch: {} tensors, expected {expected}",
                init.len()
            );
        }
        let ms = init.iter().map(|p| vec![0.0; p.len()]).collect();
        let vs = init.iter().map(|p| vec![0.0; p.len()]).collect();
        Ok(TfmSession {
            cfg,
            kind: variant.kind,
            params: init,
            ms,
            vs,
        })
    }

    fn block(&self, i: usize, off: usize) -> &[f32] {
        &self.params[2 + i * PB + off]
    }

    fn unembed_idx(&self) -> usize {
        self.params.len() - 1
    }

    fn tokens(&self, data: &[DataBatch]) -> Result<Vec<i32>> {
        let (c, want) = (&self.cfg, self.cfg.batch * (self.cfg.seq + 1));
        match data {
            [DataBatch::I32(v, shape)] => {
                if v.len() != want || shape != &[c.batch, c.seq + 1] {
                    bail!(
                        "tokens shape {shape:?} != [{}, {}]",
                        c.batch,
                        c.seq + 1
                    );
                }
                Ok(v.clone())
            }
            _ => bail!("transformer expects one i32 token batch"),
        }
    }

    /// Causal attention sublayer.  Returns (out, attn_logit_probe, cache
    /// pieces); `h` is (R, D).
    ///
    /// Per (batch, head) the strided `q`/`k`/`v` columns are gathered into
    /// contiguous head-major (S, dh) panels so the logit matrix is one
    /// `mm_nt` GEMM and the softmax+context path is the fused blocked
    /// kernel — no strided `dh`-length dot loops.  The full (S, S) logit
    /// GEMM includes causally-masked cells; `softmax_ctx_fused` overwrites
    /// them with exact zeros, matching the numpy reference's mask-then-
    /// softmax.
    #[allow(clippy::type_complexity)]
    fn attn_fwd(
        &self,
        i: usize,
        h: &[f32],
        scale: f32,
        want_alog: bool,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let _sp = crate::obs::trace::span("attn_fwd");
        let c = &self.cfg;
        let (bsz, s, d, da, nh, dh) = (c.batch, c.seq, c.d_model, c.d_attn(), c.n_head, c.d_head);
        let rows = bsz * s;
        let q = mm(h, self.block(i, WQ), rows, d, da);
        let k = mm(h, self.block(i, WK), rows, d, da);
        let v = mm(h, self.block(i, WV), rows, d, da);
        let mut prob = vec![0.0f32; bsz * nh * s * s];
        let mut alog = if want_alog {
            vec![0.0f32; bsz * nh * s * s]
        } else {
            Vec::new()
        };
        let mut merged = vec![0.0f32; rows * da];
        // head-major scratch panels, reused across (batch, head)
        let mut qh = vec![0.0f32; s * dh];
        let mut kh = vec![0.0f32; s * dh];
        let mut vh = vec![0.0f32; s * dh];
        let mut ctx = vec![0.0f32; s * dh];
        for b in 0..bsz {
            for hh in 0..nh {
                let head = hh * dh;
                pack_head(&q, &mut qh, b * s, s, da, head, dh);
                pack_head(&k, &mut kh, b * s, s, da, head, dh);
                pack_head(&v, &mut vh, b * s, s, da, head, dh);
                // logits = (q·scale) · kᵀ, as in the reference
                scale_in_place(&mut qh, scale);
                let blk = (b * nh + hh) * s * s;
                let scores = &mut prob[blk..blk + s * s];
                mm_nt_into(scores, &qh, &kh, s, dh, s);
                if want_alog {
                    for qi in 0..s {
                        alog[blk + qi * s..blk + qi * s + qi + 1]
                            .copy_from_slice(&scores[qi * s..qi * s + qi + 1]);
                    }
                }
                softmax_ctx_fused(scores, &vh, s, dh, &mut ctx);
                unpack_head(&ctx, &mut merged, b * s, s, da, head, dh);
            }
        }
        let out = mm(&merged, self.block(i, WO), rows, da, d);
        (out, alog, q, k, v, prob, merged)
    }

    /// Backward through the attention sublayer; returns d(attn_in) and
    /// accumulates weight grads.
    ///
    /// Mirrors the numpy reference's dense einsums on head-major panels:
    /// dprob = dctx·Vᵀ, dV = Pᵀ·dctx, dmasked = P⊙(dprob − ⟨dprob, P⟩),
    /// dQ = (dmasked·K)·scale, dK = dmaskedᵀ·(Q·scale).  All products run
    /// over the full key range — masked columns carry exact-zero
    /// probabilities, so they contribute nothing for finite operands but
    /// still poison the gradients when a Q/K/V panel holds NaN/Inf (the
    /// old per-element loop's `dmasked == 0` skip violated tensor.rs's
    /// no-zero-skip invariant and could hide a diverging trial from
    /// divergence detection).
    fn attn_bwd(
        &self,
        i: usize,
        dout: &[f32],
        scale: f32,
        cache: &BlockCache,
        grads: &mut [Vec<f32>],
    ) -> Vec<f32> {
        let _sp = crate::obs::trace::span("attn_bwd");
        let c = &self.cfg;
        let (bsz, s, d, da, nh, dh) = (c.batch, c.seq, c.d_model, c.d_attn(), c.n_head, c.d_head);
        let rows = bsz * s;
        let gb = 2 + i * PB;
        axpy(&mut grads[gb + WO], &mm_tn(&cache.merged, dout, rows, da, d));
        let dmerged = mm_nt(dout, self.block(i, WO), rows, d, da);
        let mut dq = vec![0.0f32; rows * da];
        let mut dk = vec![0.0f32; rows * da];
        let mut dv = vec![0.0f32; rows * da];
        // head-major scratch panels, reused across (batch, head)
        let mut qh = vec![0.0f32; s * dh];
        let mut kh = vec![0.0f32; s * dh];
        let mut vh = vec![0.0f32; s * dh];
        let mut dctx = vec![0.0f32; s * dh];
        let mut dpanel = vec![0.0f32; s * dh];
        let mut dprob = vec![0.0f32; s * s];
        for b in 0..bsz {
            for hh in 0..nh {
                let head = hh * dh;
                pack_head(&cache.q, &mut qh, b * s, s, da, head, dh);
                pack_head(&cache.k, &mut kh, b * s, s, da, head, dh);
                pack_head(&cache.v, &mut vh, b * s, s, da, head, dh);
                pack_head(&dmerged, &mut dctx, b * s, s, da, head, dh);
                let blk = (b * nh + hh) * s * s;
                let pblk = &cache.prob[blk..blk + s * s];
                // dprob = dctx · vᵀ
                dprob.fill(0.0);
                mm_nt_into(&mut dprob, &dctx, &vh, s, dh, s);
                // dv = probᵀ · dctx
                dpanel.fill(0.0);
                mm_tn_into(&mut dpanel, pblk, &dctx, s, s, dh);
                unpack_head(&dpanel, &mut dv, b * s, s, da, head, dh);
                // softmax backward rowwise, in place over dprob
                for qi in 0..s {
                    let p = &pblk[qi * s..(qi + 1) * s];
                    let g = &mut dprob[qi * s..(qi + 1) * s];
                    let mut sdp = 0.0f32;
                    for (gv, pv) in g.iter().zip(p) {
                        sdp += gv * pv;
                    }
                    for (gv, pv) in g.iter_mut().zip(p) {
                        *gv = pv * (*gv - sdp);
                    }
                }
                // dq = (dmasked · k) · scale
                dpanel.fill(0.0);
                mm_into(&mut dpanel, &dprob, &kh, s, s, dh);
                scale_in_place(&mut dpanel, scale);
                unpack_head(&dpanel, &mut dq, b * s, s, da, head, dh);
                // dk = dmaskedᵀ · (q · scale)
                scale_in_place(&mut qh, scale);
                dpanel.fill(0.0);
                mm_tn_into(&mut dpanel, &dprob, &qh, s, s, dh);
                unpack_head(&dpanel, &mut dk, b * s, s, da, head, dh);
            }
        }
        let h = &cache.attn_in;
        axpy(&mut grads[gb + WQ], &mm_tn(h, &dq, rows, d, da));
        axpy(&mut grads[gb + WK], &mm_tn(h, &dk, rows, d, da));
        axpy(&mut grads[gb + WV], &mm_tn(h, &dv, rows, d, da));
        let mut dh = mm_nt(&dq, self.block(i, WQ), rows, da, d);
        axpy(&mut dh, &mm_nt(&dk, self.block(i, WK), rows, da, d));
        axpy(&mut dh, &mm_nt(&dv, self.block(i, WV), rows, da, d));
        dh
    }

    /// FFN sublayer forward: relu(h·w1)·w2.
    fn ffn_fwd(&self, i: usize, h: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let c = &self.cfg;
        let rows = c.batch * c.seq;
        let u = mm(h, self.block(i, W1), rows, c.d_model, c.d_ffn);
        let r = relu(&u);
        let f = mm(&r, self.block(i, W2), rows, c.d_ffn, c.d_model);
        (f, u, r)
    }

    fn ffn_bwd(
        &self,
        i: usize,
        df: &[f32],
        cache: &BlockCache,
        grads: &mut [Vec<f32>],
    ) -> Vec<f32> {
        let c = &self.cfg;
        let rows = c.batch * c.seq;
        let gb = 2 + i * PB;
        axpy(&mut grads[gb + W2], &mm_tn(&cache.r, df, rows, c.d_ffn, c.d_model));
        let mut du = mm_nt(df, self.block(i, W2), rows, c.d_model, c.d_ffn);
        relu_bwd(&mut du, &cache.u);
        axpy(&mut grads[gb + W1], &mm_tn(&cache.ffn_in, &du, rows, c.d_model, c.d_ffn));
        mm_nt(&du, self.block(i, W1), rows, c.d_ffn, c.d_model)
    }

    /// Full forward pass; computes dlogits too unless eval-only.
    fn forward(&self, tokens: &[i32], hp: &[f32; 8], eval_only: bool) -> Forward {
        let c = &self.cfg;
        let (bsz, s, d, v) = (c.batch, c.seq, c.d_model, c.vocab);
        let rows = bsz * s;
        let (attn_scale, output_scale, embed_scale) = (hp[0], hp[1], hp[2]);

        let mut t_in = Vec::with_capacity(rows);
        let mut t_gt = Vec::with_capacity(rows);
        for b in 0..bsz {
            for j in 0..s {
                t_in.push(tokens[b * (s + 1) + j] as usize);
                t_gt.push(tokens[b * (s + 1) + j + 1] as usize);
            }
        }

        let embed = &self.params[0];
        let pos = &self.params[1];
        let mut x = vec![0.0f32; rows * d];
        for r in 0..rows {
            let tok = t_in[r];
            let p = (r % s) * d;
            for j in 0..d {
                x[r * d + j] = (embed[tok * d + j] + pos[p + j]) * embed_scale;
            }
        }
        let x0 = x.clone();

        let mut blocks = Vec::with_capacity(c.n_layer);
        let mut alog0 = Vec::new();
        for i in 0..c.n_layer {
            let g1 = self.block(i, LN1_G);
            let b1 = self.block(i, LN1_B);
            let g2 = self.block(i, LN2_G);
            let b2 = self.block(i, LN2_B);
            let want_alog = i == 0;
            let cache = if c.pre_ln {
                let (h1, ln1) = layernorm(&x, g1, b1, rows, d);
                let (a, alog, q, k, vv, prob, merged) =
                    self.attn_fwd(i, &h1, attn_scale, want_alog);
                let x1 = add(&x, &a);
                let (h2, ln2) = layernorm(&x1, g2, b2, rows, d);
                let (f, u, rr) = self.ffn_fwd(i, &h2);
                x = add(&x1, &f);
                if want_alog {
                    alog0 = alog;
                }
                BlockCache {
                    attn_in: h1,
                    q,
                    k,
                    v: vv,
                    prob,
                    merged,
                    ffn_in: h2,
                    u,
                    r: rr,
                    ln1,
                    ln2,
                }
            } else {
                let (a, alog, q, k, vv, prob, merged) = self.attn_fwd(i, &x, attn_scale, want_alog);
                let attn_in = std::mem::take(&mut x);
                let y1 = add(&attn_in, &a);
                let (x1, ln1) = layernorm(&y1, g1, b1, rows, d);
                let (f, u, rr) = self.ffn_fwd(i, &x1);
                let y2 = add(&x1, &f);
                let (x2, ln2) = layernorm(&y2, g2, b2, rows, d);
                x = x2;
                if want_alog {
                    alog0 = alog;
                }
                BlockCache {
                    attn_in,
                    q,
                    k,
                    v: vv,
                    prob,
                    merged,
                    ffn_in: x1,
                    u,
                    r: rr,
                    ln1,
                    ln2,
                }
            };
            blocks.push(cache);
        }

        let (xf, lnf) = if c.pre_ln {
            let li = 2 + c.n_layer * PB;
            let (xf, cache) = layernorm(&x, &self.params[li], &self.params[li + 1], rows, d);
            (xf, Some(cache))
        } else {
            (x, None)
        };

        let unembed = &self.params[self.unembed_idx()];
        let mut logits = mm(&xf, unembed, rows, d, v);
        for l in logits.iter_mut() {
            *l *= output_scale;
        }
        let (loss, dlogits) = xent(&logits, &t_gt, v);
        Forward {
            loss,
            dlogits: if eval_only { None } else { Some(dlogits) },
            x0,
            alog0,
            xf,
            logits,
            blocks,
            lnf,
            t_in,
        }
    }

    /// Reverse pass; returns per-tensor grads in manifest order.
    fn backward(&self, fwd: &Forward, hp: &[f32; 8]) -> Vec<Vec<f32>> {
        let c = &self.cfg;
        let (bsz, s, d, v) = (c.batch, c.seq, c.d_model, c.vocab);
        let rows = bsz * s;
        let (attn_scale, output_scale, embed_scale) = (hp[0], hp[1], hp[2]);
        let mut grads: Vec<Vec<f32>> = self.params.iter().map(|p| vec![0.0; p.len()]).collect();

        let mut dlogits = fwd.dlogits.clone().expect("backward needs train forward");
        for g in dlogits.iter_mut() {
            *g *= output_scale;
        }
        let un = self.unembed_idx();
        axpy(&mut grads[un], &mm_tn(&fwd.xf, &dlogits, rows, d, v));
        let dxf = mm_nt(&dlogits, &self.params[un], rows, v, d);

        let mut dx = if c.pre_ln {
            let li = 2 + c.n_layer * PB;
            let (g_slice, rest) = grads.split_at_mut(li + 1);
            let dg = g_slice.last_mut().unwrap();
            let db = &mut rest[0];
            layernorm_bwd(
                &dxf,
                &self.params[li],
                fwd.lnf.as_ref().unwrap(),
                rows,
                d,
                dg,
                db,
            )
        } else {
            dxf
        };

        for i in (0..c.n_layer).rev() {
            let gb = 2 + i * PB;
            let cache = &fwd.blocks[i];
            if c.pre_ln {
                // x2 = x1 + FFN(LN2(x1)); x1 = x + attn(LN1(x))
                let dh2 = self.ffn_bwd(i, &dx, cache, &mut grads);
                let dln2 = {
                    let (a, b) = grads.split_at_mut(gb + LN2_B);
                    layernorm_bwd(
                        &dh2,
                        self.params[gb + LN2_G].as_slice(),
                        &cache.ln2,
                        rows,
                        d,
                        &mut a[gb + LN2_G],
                        &mut b[0],
                    )
                };
                let mut dx1 = dx;
                axpy(&mut dx1, &dln2);
                let dh1 = self.attn_bwd(i, &dx1, attn_scale, cache, &mut grads);
                let dln1 = {
                    let (a, b) = grads.split_at_mut(gb + LN1_B);
                    layernorm_bwd(
                        &dh1,
                        self.params[gb + LN1_G].as_slice(),
                        &cache.ln1,
                        rows,
                        d,
                        &mut a[gb + LN1_G],
                        &mut b[0],
                    )
                };
                dx = dx1;
                axpy(&mut dx, &dln1);
            } else {
                // x2 = LN2(x1 + FFN(x1)); x1 = LN1(x + attn(x))
                let dy2 = {
                    let (a, b) = grads.split_at_mut(gb + LN2_B);
                    layernorm_bwd(
                        &dx,
                        self.params[gb + LN2_G].as_slice(),
                        &cache.ln2,
                        rows,
                        d,
                        &mut a[gb + LN2_G],
                        &mut b[0],
                    )
                };
                let mut dx1 = dy2.clone();
                axpy(&mut dx1, &self.ffn_bwd(i, &dy2, cache, &mut grads));
                let dy1 = {
                    let (a, b) = grads.split_at_mut(gb + LN1_B);
                    layernorm_bwd(
                        &dx1,
                        self.params[gb + LN1_G].as_slice(),
                        &cache.ln1,
                        rows,
                        d,
                        &mut a[gb + LN1_G],
                        &mut b[0],
                    )
                };
                dx = dy1.clone();
                axpy(&mut dx, &self.attn_bwd(i, &dy1, attn_scale, cache, &mut grads));
            }
        }

        // x0 = (embed[tokens] + pos) * embed_scale
        for r in 0..rows {
            let tok = fwd.t_in[r];
            let p = (r % s) * d;
            for j in 0..d {
                let ds = dx[r * d + j] * embed_scale;
                grads[0][tok * d + j] += ds;
                grads[1][p + j] += ds;
            }
        }
        grads
    }
}

impl BackendSession for TfmSession {
    fn step(
        &mut self,
        data: &[DataBatch],
        lr_vec: &[f32],
        gmul: &[f32],
        hp_vec: &[f32; 8],
        want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)> {
        let tokens = self.tokens(data)?;
        let fwd = self.forward(&tokens, hp_vec, false);
        let probes = if want_probes && self.kind == Kind::Coord {
            vec![
                Probe { name: "embed_out".into(), data: fwd.x0.clone() },
                Probe { name: "attn_logits_l0".into(), data: fwd.alog0.clone() },
                Probe { name: "block_out".into(), data: fwd.xf.clone() },
                Probe { name: "logits".into(), data: fwd.logits.clone() },
            ]
        } else {
            Vec::new()
        };
        let grads = self.backward(&fwd, hp_vec);
        let _sp = crate::obs::trace::span("optimizer");
        let (b1, b2, eps, wd, t) = (hp_vec[3], hp_vec[4], hp_vec[5], hp_vec[6], hp_vec[7]);
        for i in 0..self.params.len() {
            let gm = if gmul.is_empty() { 1.0 } else { gmul[i] };
            adam_update(
                &mut self.params[i],
                &grads[i],
                &mut self.ms[i],
                &mut self.vs[i],
                lr_vec[i],
                gm,
                b1,
                b2,
                eps,
                wd,
                t,
            );
        }
        Ok((fwd.loss as f32, probes))
    }

    fn eval(&self, data: &[DataBatch], hp_vec: &[f32; 8]) -> Result<f32> {
        let tokens = self.tokens(data)?;
        Ok(self.forward(&tokens, hp_vec, true).loss as f32)
    }

    fn param(&self, idx: usize) -> Result<Vec<f32>> {
        let p = self.params.len();
        match idx / p {
            0 => Ok(self.params[idx].clone()),
            1 => Ok(self.ms[idx - p].clone()),
            2 => Ok(self.vs[idx - 2 * p].clone()),
            _ => bail!("state index {idx} out of range ({} tensors)", 3 * p),
        }
    }

    /// Full state capture for checkpointing: params, then the Adam m and v
    /// blocks (the `param(idx)` order).
    fn state(&self) -> Result<Option<ModelState>> {
        let mut tensors = Vec::with_capacity(self.params.len() * 3);
        tensors.extend(self.params.iter().cloned());
        tensors.extend(self.ms.iter().cloned());
        tensors.extend(self.vs.iter().cloned());
        Ok(Some(ModelState {
            tensors,
            n_params: self.params.len(),
        }))
    }

    fn restore(&mut self, state: &ModelState) -> Result<bool> {
        let p = self.params.len();
        if state.n_params != p || state.tensors.len() != 3 * p {
            bail!(
                "transformer state mismatch: snapshot has {} params / {} tensors, session wants {p} / {}",
                state.n_params,
                state.tensors.len(),
                3 * p
            );
        }
        for (i, t) in state.tensors.iter().enumerate() {
            let want = self.params[i % p].len();
            if t.len() != want {
                bail!("state tensor {i} has {} elements, session wants {want}", t.len());
            }
        }
        for i in 0..p {
            self.params[i].copy_from_slice(&state.tensors[i]);
            self.ms[i].copy_from_slice(&state.tensors[p + i]);
            self.vs[i].copy_from_slice(&state.tensors[2 * p + i]);
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::rng::det_fill;

    /// A minimal post-LN session whose only populated tensors are block
    /// 0's attention weights — enough to drive `attn_fwd`/`attn_bwd`
    /// directly (the unused slots stay empty).
    fn attn_session(cfg: TfmConfig, scale: f32) -> TfmSession {
        let (d, da) = (cfg.d_model, cfg.d_attn());
        let mut params: Vec<Vec<f32>> = vec![Vec::new(); 2 + PB + 1];
        params[2 + WQ] = det_fill(d * da, 11, scale);
        params[2 + WK] = det_fill(d * da, 12, scale);
        params[2 + WV] = det_fill(d * da, 13, scale);
        params[2 + WO] = det_fill(da * d, 14, scale);
        let ms = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let vs = params.iter().map(|p| vec![0.0; p.len()]).collect();
        TfmSession {
            cfg,
            kind: Kind::Train,
            params,
            ms,
            vs,
        }
    }

    fn tiny_cfg() -> TfmConfig {
        TfmConfig {
            vocab: 7,
            seq: 5,
            batch: 2,
            d_model: 6,
            n_layer: 1,
            n_head: 2,
            d_head: 3,
            d_ffn: 8,
            pre_ln: false,
        }
    }

    fn empty_ln() -> LnCache {
        LnCache {
            xhat: Vec::new(),
            rstd: Vec::new(),
        }
    }

    #[allow(clippy::type_complexity)]
    fn cache_from_fwd(
        h: &[f32],
        parts: (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>),
    ) -> BlockCache {
        let (_, _, q, k, v, prob, merged) = parts;
        BlockCache {
            attn_in: h.to_vec(),
            q,
            k,
            v,
            prob,
            merged,
            ffn_in: Vec::new(),
            u: Vec::new(),
            r: Vec::new(),
            ln1: empty_ln(),
            ln2: empty_ln(),
        }
    }

    fn zero_grads(s: &TfmSession) -> Vec<Vec<f32>> {
        s.params.iter().map(|p| vec![0.0; p.len()]).collect()
    }

    /// attn_bwd's d(attn_in) and dWQ against central finite differences of
    /// the scalar J(h) = Σ attn(h) ⊙ W — a direct regression test for the
    /// head-major GEMM backward.
    #[test]
    fn attn_bwd_finite_difference() {
        let cfg = tiny_cfg();
        let rows = cfg.batch * cfg.seq;
        let d = cfg.d_model;
        let attn_scale = 0.6f32;
        let mut sess = attn_session(cfg, 0.5);
        let h0 = det_fill(rows * d, 21, 0.5);
        let w = det_fill(rows * d, 22, 0.5);
        let j = |s: &TfmSession, h: &[f32]| -> f64 {
            let (out, ..) = s.attn_fwd(0, h, attn_scale, false);
            out.iter().zip(&w).map(|(&o, &wv)| (o * wv) as f64).sum()
        };
        let fwd = sess.attn_fwd(0, &h0, attn_scale, false);
        let cache = cache_from_fwd(&h0, fwd);
        let mut grads = zero_grads(&sess);
        let dh = sess.attn_bwd(0, &w, attn_scale, &cache, &mut grads);
        let eps = 3e-3f32;
        // d(attn_in): probe a spread of coordinates
        let mut hp = h0.clone();
        for idx in (0..rows * d).step_by(7) {
            hp[idx] = h0[idx] + eps;
            let jp = j(&sess, &hp);
            hp[idx] = h0[idx] - eps;
            let jm = j(&sess, &hp);
            hp[idx] = h0[idx];
            let num = (jp - jm) / (2.0 * eps as f64);
            let ana = dh[idx] as f64;
            assert!(
                (num - ana).abs() < 1e-2 * (1.0 + ana.abs()),
                "dh[{idx}]: analytic {ana} vs numeric {num}"
            );
        }
        // dWQ: perturb the weight itself
        let gq = grads[2 + WQ].clone();
        for idx in (0..gq.len()).step_by(5) {
            let orig = sess.params[2 + WQ][idx];
            sess.params[2 + WQ][idx] = orig + eps;
            let jp = j(&sess, &h0);
            sess.params[2 + WQ][idx] = orig - eps;
            let jm = j(&sess, &h0);
            sess.params[2 + WQ][idx] = orig;
            let num = (jp - jm) / (2.0 * eps as f64);
            let ana = gq[idx] as f64;
            assert!(
                (num - ana).abs() < 1e-2 * (1.0 + ana.abs()),
                "dWQ[{idx}]: analytic {ana} vs numeric {num}"
            );
        }
    }

    /// Regression for the old `dmasked == 0.0 { continue }` shortcut: a
    /// key row holding Inf whose softmax probability underflowed to exact
    /// zero must still poison dq (0·Inf = NaN), so a diverging trial
    /// cannot report finite gradients.  The old skip read neither krow nor
    /// qrow in that case and returned fully finite gradients here.
    #[test]
    fn attn_bwd_zero_prob_nonfinite_k_poisons() {
        let cfg = TfmConfig {
            vocab: 7,
            seq: 2,
            batch: 1,
            d_model: 2,
            n_layer: 1,
            n_head: 1,
            d_head: 2,
            d_ffn: 4,
            pre_ln: false,
        };
        let (s, da) = (cfg.seq, cfg.d_attn());
        let rows = cfg.batch * s;
        let sess = attn_session(cfg, 0.5);
        let h = vec![0.25f32; rows * sess.cfg.d_model];
        let q = vec![0.5f32; rows * da];
        let mut k = vec![0.5f32; rows * da];
        k[0] = f32::INFINITY; // key row 0 diverged
        let v = vec![1.0f32; rows * da];
        // row qi=0 attends only to key 0 (prob 1); row qi=1's probability
        // on key 0 underflowed to exactly 0 — the old code skipped it.
        let prob = vec![1.0f32, 0.0, 0.0, 1.0];
        let merged = vec![1.0f32; rows * da];
        let cache = BlockCache {
            attn_in: h,
            q,
            k,
            v,
            prob,
            merged,
            ffn_in: Vec::new(),
            u: Vec::new(),
            r: Vec::new(),
            ln1: empty_ln(),
            ln2: empty_ln(),
        };
        let mut grads = zero_grads(&sess);
        let dout = vec![1.0f32; rows * sess.cfg.d_model];
        let dh = sess.attn_bwd(0, &dout, 0.7, &cache, &mut grads);
        assert!(
            dh.iter().any(|x| !x.is_finite()),
            "d(attn_in) must be poisoned by the Inf key row: {dh:?}"
        );
        assert!(
            grads[2 + WQ].iter().any(|x| !x.is_finite()),
            "dWQ must be poisoned: {:?}",
            grads[2 + WQ]
        );
    }
}
