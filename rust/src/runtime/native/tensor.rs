//! Dense f32 primitives for the native backend — cache-blocked kernels.
//!
//! Row-major `Vec<f32>` throughout; shapes are tracked by the callers
//! (model code).  The GEMM family (`mm`/`mm_tn`/`mm_nt` and their `_into`
//! scratch-reusing variants) shares one panel-packed, register-tiled core:
//! B is packed into `NR`-wide column panels per (`KC`×`NC`) cache block and
//! an `MR`×`NR` microkernel (4×-unrolled over A rows, autovectorizable over
//! the panel width) accumulates into C.  Per output element the summation
//! still runs k-ascending (KC blocks in order, k ascending inside each
//! block), so results are bitwise run-to-run deterministic; only the
//! *grouping* of partial sums differs from the naive loops, which keeps the
//! drift against the numpy golden reference (`rust/tests/golden.rs`) well
//! inside its 1e-3 envelope (observed ≤ ~1e-5 per step; the blocked-vs-naive
//! property test in `rust/tests/properties.rs` pins ≤ 1e-5 relative per
//! GEMM).  The original naive loops are kept in [`naive`] as the reference
//! for equivalence tests and the bench baseline
//! (`benches/step_latency.rs`).
//!
//! Numerics mirror `python/compile/kernels/ref.py` (layernorm eps, stable
//! softmax); the blocked loop structure itself is transcribed index-for-
//! index in `python/tools/sim_rust_backend.py` and diffed there against the
//! finite-difference-verified numpy reference.

pub const LN_EPS: f32 = 1e-5;

/// Microkernel rows — the 4× unroll over A.
pub const MR: usize = 4;
/// B-panel width (microkernel accumulator row; SIMD-friendly).
pub const NR: usize = 16;
/// k-dimension cache block (panel depth).
const KC: usize = 256;
/// n-dimension cache block; a multiple of `NR`.
const NC: usize = 256;

// No zero-skip shortcuts anywhere in this module: 0·Inf/NaN must poison
// the output exactly as in the numpy reference, or diverged trials could
// report finite losses and the sweep's divergence detection would miss
// them.  Packing may zero-pad panel *tail lanes*, but those lanes are
// never written back to C, so padding cannot mask non-finite inputs.

/// Pack a (`kb`×`nb`) block of row-major `b` (full row stride `n`) into
/// `NR`-wide column panels: panel `p` holds columns `j0 + p·NR ..`,
/// row-major inside the panel with stride `NR` (tail lanes zero-padded).
fn pack_b(b: &[f32], k0: usize, kb: usize, j0: usize, nb: usize, n: usize, out: &mut Vec<f32>) {
    let npan = (nb + NR - 1) / NR;
    out.clear();
    out.resize(npan * kb * NR, 0.0);
    for p in 0..npan {
        let jl = j0 + p * NR;
        let w = NR.min(j0 + nb - jl);
        let dst0 = p * kb * NR;
        for l in 0..kb {
            let src = (k0 + l) * n + jl;
            let dst = dst0 + l * NR;
            out[dst..dst + w].copy_from_slice(&b[src..src + w]);
        }
    }
}

/// Same panel layout, but the source is row-major (`n`×`k`) and is packed
/// transposed — the B side of `mm_nt`.
fn pack_bt(
    b: &[f32],
    k0: usize,
    kb: usize,
    j0: usize,
    nb: usize,
    kstride: usize,
    out: &mut Vec<f32>,
) {
    let npan = (nb + NR - 1) / NR;
    out.clear();
    out.resize(npan * kb * NR, 0.0);
    for p in 0..npan {
        let jl = j0 + p * NR;
        let w = NR.min(j0 + nb - jl);
        let dst0 = p * kb * NR;
        for jr in 0..w {
            let src = (jl + jr) * kstride + k0;
            for l in 0..kb {
                out[dst0 + l * NR + jr] = b[src + l];
            }
        }
    }
}

/// Transpose one k-block of a (`k`×`m`) matrix into row-major (`m`×`kb`) —
/// the A side of `mm_tn`, so the microkernel always reads A rows
/// contiguously.
fn pack_at(a: &[f32], k0: usize, kb: usize, m: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(m * kb, 0.0);
    for i in 0..m {
        for l in 0..kb {
            out[i * kb + l] = a[(k0 + l) * m + i];
        }
    }
}

/// `mr`×`w` microkernel: C-block += A-strip · B-panel over `kb` steps.
/// `a_off`/`a_stride` address the strip's rows inside `a`; `panel` is the
/// packed `kb`×`NR` B panel; accumulators live in registers and are added
/// to C once per call (k-ascending order per element is preserved).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro(
    a: &[f32],
    a_off: usize,
    a_stride: usize,
    mr: usize,
    panel: &[f32],
    kb: usize,
    c: &mut [f32],
    c_off: usize,
    c_stride: usize,
    w: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    if mr == MR {
        // fast path: four A-row broadcasts against the NR-wide panel
        for l in 0..kb {
            let bl = &panel[l * NR..(l + 1) * NR];
            let a0 = a[a_off + l];
            let a1 = a[a_off + a_stride + l];
            let a2 = a[a_off + 2 * a_stride + l];
            let a3 = a[a_off + 3 * a_stride + l];
            for j in 0..NR {
                let bv = bl[j];
                acc[0][j] += a0 * bv;
                acc[1][j] += a1 * bv;
                acc[2][j] += a2 * bv;
                acc[3][j] += a3 * bv;
            }
        }
    } else {
        for l in 0..kb {
            let bl = &panel[l * NR..(l + 1) * NR];
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                let av = a[a_off + r * a_stride + l];
                for j in 0..NR {
                    accr[j] += av * bl[j];
                }
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(mr) {
        let base = c_off + r * c_stride;
        let crow = &mut c[base..base + w];
        for (cv, &av) in crow.iter_mut().zip(accr.iter()) {
            *cv += av;
        }
    }
}

/// Drive the microkernel over all row strips × panels of one packed
/// (`kb`×`nb`) B block.  `a_col0`/`a_stride` locate the matching A block.
#[allow(clippy::too_many_arguments)]
fn kernel_block(
    c: &mut [f32],
    a: &[f32],
    a_col0: usize,
    a_stride: usize,
    m: usize,
    panel: &[f32],
    kb: usize,
    j0: usize,
    nb: usize,
    n: usize,
) {
    let npan = (nb + NR - 1) / NR;
    let mut i0 = 0;
    while i0 < m {
        let mr = MR.min(m - i0);
        for p in 0..npan {
            let jl = j0 + p * NR;
            let w = NR.min(j0 + nb - jl);
            micro(
                a,
                i0 * a_stride + a_col0,
                a_stride,
                mr,
                &panel[p * kb * NR..(p + 1) * kb * NR],
                kb,
                c,
                i0 * n + jl,
                n,
                w,
            );
        }
        i0 += mr;
    }
}

// Per-thread packing scratch: the GEMMs sit in the per-(batch, head)
// attention hot loop, where a fresh panel allocation per call would rival
// the math for the small head shapes.  Sessions are single-threaded and
// sweep workers are distinct threads, so thread-locals add no contention
// and cannot change results (packing is a pure copy).  Nothing here is
// re-entrant: kernel_block/micro never call back into the drivers.
thread_local! {
    static PACK_PANEL: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    static PACK_AT: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// c += a · b, a: (m, k), b: (k, n).  `c` is typically freshly zeroed by
/// the allocating wrappers; accumulate semantics let callers reuse scratch.
pub fn mm_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let _sp = crate::obs::trace::span_mnk("gemm", m, k, n);
    PACK_PANEL.with(|pp| {
        let mut panel = pp.borrow_mut();
        for k0 in (0..k).step_by(KC) {
            let kb = KC.min(k - k0);
            for j0 in (0..n).step_by(NC) {
                let nb = NC.min(n - j0);
                pack_b(b, k0, kb, j0, nb, n, &mut panel);
                kernel_block(c, a, k0, k, m, &panel, kb, j0, nb, n);
            }
        }
    });
}

/// c = a · b, a: (m, k), b: (k, n).
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    mm_into(&mut c, a, b, m, k, n);
    c
}

/// c += aᵀ · b, a: (k, m), b: (k, n) — the weight-gradient contraction
/// (xᵀ · dy summed over rows).
pub fn mm_tn_into(c: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    // effective output-rows/contraction/output-cols — FLOPs = 2·m·k·n
    let _sp = crate::obs::trace::span_mnk("gemm", m, k, n);
    PACK_AT.with(|pa| {
        PACK_PANEL.with(|pp| {
            let mut at = pa.borrow_mut();
            let mut panel = pp.borrow_mut();
            for k0 in (0..k).step_by(KC) {
                let kb = KC.min(k - k0);
                pack_at(a, k0, kb, m, &mut at);
                for j0 in (0..n).step_by(NC) {
                    let nb = NC.min(n - j0);
                    pack_b(b, k0, kb, j0, nb, n, &mut panel);
                    kernel_block(c, &at, 0, kb, m, &panel, kb, j0, nb, n);
                }
            }
        });
    });
}

/// c = aᵀ · b, a: (k, m), b: (k, n).
pub fn mm_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    mm_tn_into(&mut c, a, b, k, m, n);
    c
}

/// c += a · bᵀ, a: (m, k), b: (n, k) — the input-gradient contraction
/// (dy · Wᵀ).
pub fn mm_nt_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let _sp = crate::obs::trace::span_mnk("gemm", m, k, n);
    PACK_PANEL.with(|pp| {
        let mut panel = pp.borrow_mut();
        for k0 in (0..k).step_by(KC) {
            let kb = KC.min(k - k0);
            for j0 in (0..n).step_by(NC) {
                let nb = NC.min(n - j0);
                pack_bt(b, k0, kb, j0, nb, k, &mut panel);
                kernel_block(c, a, k0, k, m, &panel, kb, j0, nb, n);
            }
        }
    });
}

/// c = a · bᵀ, a: (m, k), b: (n, k).
pub fn mm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    mm_nt_into(&mut c, a, b, m, k, n);
    c
}

/// The pre-rewrite naive loops, kept as the reference implementation:
/// equivalence tests pin the blocked kernels against these, and
/// `benches/step_latency.rs` uses them as the speedup baseline.
pub mod naive {
    /// c = a · b, a: (m, k), b: (k, n).
    pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (l, &av) in arow.iter().enumerate() {
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        c
    }

    /// c = aᵀ · b, a: (k, m), b: (k, n).
    pub fn mm_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        let mut c = vec![0.0f32; m * n];
        for l in 0..k {
            let arow = &a[l * m..(l + 1) * m];
            let brow = &b[l * n..(l + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
        c
    }

    /// c = a · bᵀ, a: (m, k), b: (n, k).
    pub fn mm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for l in 0..k {
                    acc += arow[l] * brow[l];
                }
                crow[j] = acc;
            }
        }
        c
    }
}

/// Gather one attention head into a contiguous head-major (`s`×`dh`)
/// panel: `dst[si] = src[row0 + si][off..off + dh]`.
pub fn pack_head(
    src: &[f32],
    dst: &mut [f32],
    row0: usize,
    s: usize,
    stride: usize,
    off: usize,
    dh: usize,
) {
    debug_assert_eq!(dst.len(), s * dh);
    for si in 0..s {
        let sb = (row0 + si) * stride + off;
        dst[si * dh..(si + 1) * dh].copy_from_slice(&src[sb..sb + dh]);
    }
}

/// Scatter a head-major (`s`×`dh`) panel back into interleaved rows —
/// the inverse of [`pack_head`].
pub fn unpack_head(
    src: &[f32],
    dst: &mut [f32],
    row0: usize,
    s: usize,
    stride: usize,
    off: usize,
    dh: usize,
) {
    debug_assert_eq!(src.len(), s * dh);
    for si in 0..s {
        let db = (row0 + si) * stride + off;
        dst[db..db + dh].copy_from_slice(&src[si * dh..(si + 1) * dh]);
    }
}

/// Fused causal softmax + context accumulate for one head: `scores` is
/// the (`s`×`s`) attention-logit matrix (row `qi` has `qi + 1` causally
/// active entries; the rest may hold garbage from the full logit GEMM).
/// Each row is softmaxed in place (tail zeroed, [`softmax_prefix`]
/// convention) and immediately accumulated into `ctx = P · V` with a
/// 4×-unrolled key loop, while the row is still cache-hot.  The context
/// product runs over the *full* key range: masked probabilities are exact
/// zeros, so a non-finite V row poisons the context exactly as the numpy
/// reference's dense `prob @ v` does.
pub fn softmax_ctx_fused(scores: &mut [f32], v: &[f32], s: usize, dh: usize, ctx: &mut [f32]) {
    debug_assert_eq!(scores.len(), s * s);
    debug_assert_eq!(v.len(), s * dh);
    debug_assert_eq!(ctx.len(), s * dh);
    for qi in 0..s {
        let row = &mut scores[qi * s..(qi + 1) * s];
        softmax_prefix(row, qi + 1);
        let crow = &mut ctx[qi * dh..(qi + 1) * dh];
        crow.fill(0.0);
        let mut kj = 0;
        while kj + MR <= s {
            let p0 = row[kj];
            let p1 = row[kj + 1];
            let p2 = row[kj + 2];
            let p3 = row[kj + 3];
            let v0 = &v[kj * dh..(kj + 1) * dh];
            let v1 = &v[(kj + 1) * dh..(kj + 2) * dh];
            let v2 = &v[(kj + 2) * dh..(kj + 3) * dh];
            let v3 = &v[(kj + 3) * dh..(kj + 4) * dh];
            for t in 0..dh {
                crow[t] += p0 * v0[t] + p1 * v1[t] + p2 * v2[t] + p3 * v3[t];
            }
            kj += MR;
        }
        while kj < s {
            let p = row[kj];
            let vr = &v[kj * dh..(kj + 1) * dh];
            for t in 0..dh {
                crow[t] += p * vr[t];
            }
            kj += 1;
        }
    }
}

/// Accumulate `src` into `dst`.
pub fn axpy(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Elementwise sum of two tensors.
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// y = max(x, 0) elementwise — the shared activation kernel.  Mirrors the
/// reference's `np.maximum(u, 0)`: a NaN input propagates (a diverging
/// trial must stay visibly diverged), unlike `f32::max`, which would
/// return the non-NaN operand.
pub fn relu(x: &[f32]) -> Vec<f32> {
    x.iter()
        .map(|&v| if v > 0.0 || v.is_nan() { v } else { 0.0 })
        .collect()
}

/// In-place relu backward: `du ⊙ (u > 0)`, exactly the reference's mask
/// multiply — the gradient is zeroed wherever `u` is not positive,
/// *including* NaN `u` (NaN > 0 is false), so the two languages agree on
/// non-finite inputs too.
pub fn relu_bwd(du: &mut [f32], u: &[f32]) {
    debug_assert_eq!(du.len(), u.len());
    for (g, &uv) in du.iter_mut().zip(u) {
        *g = if uv > 0.0 { *g } else { 0.0 };
    }
}

/// Broadcast-add a length-`n` bias over each of `rows` rows of `x`.
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, n: usize) {
    debug_assert_eq!(x.len(), rows * n);
    debug_assert_eq!(bias.len(), n);
    for r in 0..rows {
        let row = &mut x[r * n..(r + 1) * n];
        for (xv, &bv) in row.iter_mut().zip(bias) {
            *xv += bv;
        }
    }
}

/// Column sums of a (`rows`×`n`) matrix — bias gradients.
pub fn col_sum(m: &[f32], rows: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(m.len(), rows * n);
    let mut out = vec![0.0f32; n];
    for r in 0..rows {
        let row = &m[r * n..(r + 1) * n];
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Scale a tensor in place.
pub fn scale_in_place(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Layernorm forward cache: normalized activations + reciprocal stds.
pub struct LnCache {
    pub xhat: Vec<f32>,
    pub rstd: Vec<f32>,
}

/// y = (x - mean)/sqrt(var + eps) * g + b over each row of length `d`.
pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], rows: usize, d: usize) -> (Vec<f32>, LnCache) {
    debug_assert_eq!(x.len(), rows * d);
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu *= inv_d;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var *= inv_d;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        for j in 0..d {
            let h = (xr[j] - mu) * rs;
            xhat[r * d + j] = h;
            y[r * d + j] = h * g[j] + b[j];
        }
    }
    (y, LnCache { xhat, rstd })
}

/// Layernorm backward: returns dx; accumulates dg/db.
pub fn layernorm_bwd(
    dy: &[f32],
    g: &[f32],
    cache: &LnCache,
    rows: usize,
    d: usize,
    dg: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    debug_assert_eq!(dy.len(), rows * d);
    let mut dx = vec![0.0f32; rows * d];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &cache.xhat[r * d..(r + 1) * d];
        let rs = cache.rstd[r];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            m1 += dxh;
            m2 += dxh * xh[j];
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        m1 *= inv_d;
        m2 *= inv_d;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dx[r * d + j] = rs * (dxh - m1 - xh[j] * m2);
        }
    }
    dx
}

/// In-place stable softmax over the first `active` entries of `row`;
/// entries `active..` are set to 0 (the causal-mask convention).
pub fn softmax_prefix(row: &mut [f32], active: usize) {
    let mut m = f32::NEG_INFINITY;
    for &v in &row[..active] {
        if v > m {
            m = v;
        }
    }
    let mut sum = 0.0f32;
    for v in row[..active].iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row[..active].iter_mut() {
        *v *= inv;
    }
    for v in row[active..].iter_mut() {
        *v = 0.0;
    }
}

/// Mean softmax-cross-entropy over `rows` rows of `n` logits; returns
/// (loss, dlogits) where dlogits = (softmax - onehot)/rows, mirroring
/// `native_ref.xent_fwd`.
pub fn xent(logits: &[f32], targets: &[usize], n: usize) -> (f64, Vec<f32>) {
    let rows = targets.len();
    debug_assert_eq!(logits.len(), rows * n);
    let mut d = vec![0.0f32; rows * n];
    let inv_rows = 1.0 / rows as f32;
    let mut acc = 0.0f64;
    for r in 0..rows {
        let lr = &logits[r * n..(r + 1) * n];
        let mut m = f32::NEG_INFINITY;
        for &v in lr {
            if v > m {
                m = v;
            }
        }
        let mut sum = 0.0f32;
        for &v in lr {
            sum += (v - m).exp();
        }
        let lse = m + sum.ln();
        acc += (lse - lr[targets[r]]) as f64;
        let inv_sum = 1.0 / sum;
        let dr = &mut d[r * n..(r + 1) * n];
        for j in 0..n {
            dr[j] = (lr[j] - m).exp() * inv_sum * inv_rows;
        }
        dr[targets[r]] -= inv_rows;
    }
    (acc / rows as f64, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::rng::Rng;

    #[test]
    fn mm_small() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = mm(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transposed_matmuls_agree_with_manual_transpose() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3, 2) or (2, 3)
        let b = [1.0f32, -1.0, 0.5, 2.0, 1.5, -0.5];
        // aᵀ·b with a as (3,2), b as (3,2): (2,2)
        let at = [1.0f32, 3.0, 5.0, 2.0, 4.0, 6.0]; // (2,3) manual transpose
        assert_eq!(mm_tn(&a, &b, 3, 2, 2), mm(&at, &b, 2, 3, 2));
        // a·bᵀ with a as (3,2), b as (3,2): (3,3)
        let bt = [1.0f32, 0.5, 1.5, -1.0, 2.0, -0.5]; // (2,3)
        assert_eq!(mm_nt(&a, &b, 3, 2, 3), mm(&a, &bt, 3, 2, 3));
    }

    fn gauss(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f64, tag: &str) {
        assert_eq!(got.len(), want.len(), "{tag}: length");
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            let denom = 1.0f64.max(w.abs() as f64);
            assert!(
                ((g as f64 - w as f64) / denom).abs() < tol,
                "{tag}[{i}]: blocked {g} vs naive {w}"
            );
        }
    }

    /// Blocked and naive kernels agree on shapes crossing every tile
    /// boundary (MR/NR/KC edges, degenerate dims).
    #[test]
    fn blocked_matches_naive_on_edge_shapes() {
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (4, 16, 16),
            (5, 17, 33),
            (9, 40, 21),
            (2, 300, 7),   // k crosses the KC=256 block edge
            (13, 260, 18), // k crosses KC with row/panel tails
            (5, 7, 300),   // n crosses the NC=256 block edge
            (9, 260, 280), // k and n both multi-block, with tails
        ] {
            let a = gauss(&mut rng, m * k);
            let b = gauss(&mut rng, k * n);
            assert_close(
                &mm(&a, &b, m, k, n),
                &naive::mm(&a, &b, m, k, n),
                1e-5,
                &format!("mm {m}x{k}x{n}"),
            );
            let at = gauss(&mut rng, k * m);
            assert_close(
                &mm_tn(&at, &b, k, m, n),
                &naive::mm_tn(&at, &b, k, m, n),
                1e-5,
                &format!("mm_tn {m}x{k}x{n}"),
            );
            let bt = gauss(&mut rng, n * k);
            assert_close(
                &mm_nt(&a, &bt, m, k, n),
                &naive::mm_nt(&a, &bt, m, k, n),
                1e-5,
                &format!("mm_nt {m}x{k}x{n}"),
            );
        }
    }

    /// 0·Inf must poison C in every kernel — the no-zero-skip invariant.
    #[test]
    fn zero_times_inf_poisons_output() {
        let a = vec![0.0f32; 16];
        let b = vec![f32::INFINITY; 16];
        for (c, tag) in [
            (mm(&a, &b, 4, 4, 4), "mm"),
            (mm_tn(&a, &b, 4, 4, 4), "mm_tn"),
            (mm_nt(&a, &b, 4, 4, 4), "mm_nt"),
        ] {
            assert!(c.iter().all(|v| v.is_nan()), "{tag}: {c:?}");
        }
    }

    /// The fused softmax+context path equals softmax_prefix rows followed
    /// by an explicit P·V product.
    #[test]
    fn softmax_ctx_fused_matches_unfused() {
        let (s, dh) = (7, 5);
        let mut rng = Rng::new(9);
        let scores0 = gauss(&mut rng, s * s);
        let v = gauss(&mut rng, s * dh);
        let mut scores = scores0.clone();
        let mut ctx = vec![0.0f32; s * dh];
        softmax_ctx_fused(&mut scores, &v, s, dh, &mut ctx);
        // reference: softmax rows, then dense P·V
        let mut p = scores0;
        for qi in 0..s {
            softmax_prefix(&mut p[qi * s..(qi + 1) * s], qi + 1);
        }
        assert_close(&scores, &p, 1e-7, "fused probs");
        assert_close(&ctx, &naive::mm(&p, &v, s, s, dh), 1e-5, "fused ctx");
    }

    /// A NaN V row must poison context rows even where the causal mask
    /// zeroed its probability (0·NaN = NaN, mirroring numpy's dense
    /// prob @ v).
    #[test]
    fn softmax_ctx_fused_nan_v_poisons_all_rows() {
        let (s, dh) = (5, 3);
        let mut scores = vec![0.1f32; s * s];
        let mut v = vec![1.0f32; s * dh];
        v[(s - 1) * dh] = f32::NAN; // last key row: masked for qi < s-1
        let mut ctx = vec![0.0f32; s * dh];
        softmax_ctx_fused(&mut scores, &v, s, dh, &mut ctx);
        assert!(ctx[0].is_nan(), "row 0 must see 0·NaN poison: {}", ctx[0]);
    }

    #[test]
    fn pack_unpack_head_roundtrip() {
        let (s, stride, dh, off) = (3, 8, 2, 4);
        let src: Vec<f32> = (0..s * stride).map(|i| i as f32).collect();
        let mut panel = vec![0.0f32; s * dh];
        pack_head(&src, &mut panel, 0, s, stride, off, dh);
        assert_eq!(panel, vec![4.0, 5.0, 12.0, 13.0, 20.0, 21.0]);
        let mut dst = vec![0.0f32; s * stride];
        unpack_head(&panel, &mut dst, 0, s, stride, off, dh);
        for si in 0..s {
            for t in 0..dh {
                assert_eq!(dst[si * stride + off + t], src[si * stride + off + t]);
            }
        }
    }

    #[test]
    fn relu_and_bias_helpers() {
        assert_eq!(relu(&[-1.0, 0.0, 2.5]), vec![0.0, 0.0, 2.5]);
        // np.maximum semantics: NaN propagates forward...
        let r = relu(&[f32::NAN, -1.0]);
        assert!(r[0].is_nan() && r[1] == 0.0);
        let mut du = vec![1.0f32, 2.0, 3.0];
        relu_bwd(&mut du, &[-1.0, 0.0, 5.0]);
        assert_eq!(du, vec![0.0, 0.0, 3.0]);
        // ...but the backward mask (u > 0) is false for NaN u, exactly as
        // the reference's `du * (u > 0)`
        let mut du = vec![1.0f32, f32::NAN];
        relu_bwd(&mut du, &[f32::NAN, 2.0]);
        assert_eq!(du[0], 0.0);
        assert!(du[1].is_nan());
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0], 2, 2);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(col_sum(&[1.0, 2.0, 3.0, 4.0], 2, 2), vec![4.0, 6.0]);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0, -2.0, 0.0, 2.0, 8.0];
        let g = [1.0f32; 4];
        let b = [0.0f32; 4];
        let (y, _) = layernorm(&x, &g, &b, 2, 4);
        for r in 0..2 {
            let row = &y[r * 4..(r + 1) * 4];
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5, "mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn layernorm_bwd_finite_difference() {
        let x = [0.3f32, -1.2, 0.7, 2.1, 0.4, -0.8];
        let g = [1.1f32, 0.9, 1.3];
        let b = [0.1f32, -0.2, 0.0];
        let dy = [0.5f32, -0.3, 0.8, 0.2, 0.7, -0.5];
        let (_, cache) = layernorm(&x, &g, &b, 2, 3);
        let mut dg = vec![0.0f32; 3];
        let mut db = vec![0.0f32; 3];
        let dx = layernorm_bwd(&dy, &g, &cache, 2, 3, &mut dg, &mut db);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layernorm(x, &g, &b, 2, 3);
            y.iter().zip(&dy).map(|(&a, &w)| (a * w) as f64).sum()
        };
        let mut xp = x;
        for i in 0..x.len() {
            let eps = 1e-3f32;
            xp[i] = x[i] + eps;
            let lp = loss(&xp);
            xp[i] = x[i] - eps;
            let lm = loss(&xp);
            xp[i] = x[i];
            let num = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 2e-3,
                "dx[{i}] analytic {} vs numeric {num}",
                dx[i]
            );
        }
    }

    #[test]
    fn softmax_prefix_masks_tail() {
        let mut row = [1.0f32, 2.0, 3.0, 99.0];
        softmax_prefix(&mut row, 3);
        assert_eq!(row[3], 0.0);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn xent_uniform_logits() {
        let logits = vec![0.0f32; 2 * 5];
        let (loss, d) = xent(&logits, &[1, 3], 5);
        assert!((loss - (5f64).ln()).abs() < 1e-6);
        // gradient rows sum to zero
        for r in 0..2 {
            let s: f32 = d[r * 5..(r + 1) * 5].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        assert!(d[5 + 3] < 0.0 && d[5] > 0.0);
    }
}
