//! Dense f32 primitives for the native backend.
//!
//! Row-major `Vec<f32>` throughout; shapes are tracked by the callers
//! (model code), which keeps these kernels monomorphic and loop-shaped so
//! the compiler can vectorize them.  Numerics mirror
//! `python/compile/kernels/ref.py` (layernorm eps, stable softmax) — the
//! golden-trajectory tests bound the drift against the numpy reference at
//! 1e-3 relative over multi-step trajectories.

pub const LN_EPS: f32 = 1e-5;

/// c = a · b, a: (m, k), b: (k, n).
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    // No zero-skip shortcuts: 0·Inf/NaN must poison the output exactly as
    // in the numpy reference, or diverged trials could report finite
    // losses and the sweep's divergence detection would miss them.
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (l, &av) in arow.iter().enumerate() {
            let brow = &b[l * n..(l + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// c = aᵀ · b, a: (k, m), b: (k, n) — the weight-gradient contraction
/// (xᵀ · dy summed over rows).
pub fn mm_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for l in 0..k {
        let arow = &a[l * m..(l + 1) * m];
        let brow = &b[l * n..(l + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// c = a · bᵀ, a: (m, k), b: (n, k) — the input-gradient contraction
/// (dy · Wᵀ).
pub fn mm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += arow[l] * brow[l];
            }
            crow[j] = acc;
        }
    }
    c
}

/// Accumulate `src` into `dst`.
pub fn axpy(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Elementwise sum of two tensors.
pub fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Layernorm forward cache: normalized activations + reciprocal stds.
pub struct LnCache {
    pub xhat: Vec<f32>,
    pub rstd: Vec<f32>,
}

/// y = (x - mean)/sqrt(var + eps) * g + b over each row of length `d`.
pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], rows: usize, d: usize) -> (Vec<f32>, LnCache) {
    debug_assert_eq!(x.len(), rows * d);
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu *= inv_d;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var *= inv_d;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        for j in 0..d {
            let h = (xr[j] - mu) * rs;
            xhat[r * d + j] = h;
            y[r * d + j] = h * g[j] + b[j];
        }
    }
    (y, LnCache { xhat, rstd })
}

/// Layernorm backward: returns dx; accumulates dg/db.
pub fn layernorm_bwd(
    dy: &[f32],
    g: &[f32],
    cache: &LnCache,
    rows: usize,
    d: usize,
    dg: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    debug_assert_eq!(dy.len(), rows * d);
    let mut dx = vec![0.0f32; rows * d];
    let inv_d = 1.0 / d as f32;
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xh = &cache.xhat[r * d..(r + 1) * d];
        let rs = cache.rstd[r];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            m1 += dxh;
            m2 += dxh * xh[j];
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        m1 *= inv_d;
        m2 *= inv_d;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dx[r * d + j] = rs * (dxh - m1 - xh[j] * m2);
        }
    }
    dx
}

/// In-place stable softmax over the first `active` entries of `row`;
/// entries `active..` are set to 0 (the causal-mask convention).
pub fn softmax_prefix(row: &mut [f32], active: usize) {
    let mut m = f32::NEG_INFINITY;
    for &v in &row[..active] {
        if v > m {
            m = v;
        }
    }
    let mut sum = 0.0f32;
    for v in row[..active].iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row[..active].iter_mut() {
        *v *= inv;
    }
    for v in row[active..].iter_mut() {
        *v = 0.0;
    }
}

/// Mean softmax-cross-entropy over `rows` rows of `n` logits; returns
/// (loss, dlogits) where dlogits = (softmax - onehot)/rows, mirroring
/// `native_ref.xent_fwd`.
pub fn xent(logits: &[f32], targets: &[usize], n: usize) -> (f64, Vec<f32>) {
    let rows = targets.len();
    debug_assert_eq!(logits.len(), rows * n);
    let mut d = vec![0.0f32; rows * n];
    let inv_rows = 1.0 / rows as f32;
    let mut acc = 0.0f64;
    for r in 0..rows {
        let lr = &logits[r * n..(r + 1) * n];
        let mut m = f32::NEG_INFINITY;
        for &v in lr {
            if v > m {
                m = v;
            }
        }
        let mut sum = 0.0f32;
        for &v in lr {
            sum += (v - m).exp();
        }
        let lse = m + sum.ln();
        acc += (lse - lr[targets[r]]) as f64;
        let inv_sum = 1.0 / sum;
        let dr = &mut d[r * n..(r + 1) * n];
        for j in 0..n {
            dr[j] = (lr[j] - m).exp() * inv_sum * inv_rows;
        }
        dr[targets[r]] -= inv_rows;
    }
    (acc / rows as f64, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_small() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = mm(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transposed_matmuls_agree_with_manual_transpose() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3, 2) or (2, 3)
        let b = [1.0f32, -1.0, 0.5, 2.0, 1.5, -0.5];
        // aᵀ·b with a as (3,2), b as (3,2): (2,2)
        let at = [1.0f32, 3.0, 5.0, 2.0, 4.0, 6.0]; // (2,3) manual transpose
        assert_eq!(mm_tn(&a, &b, 3, 2, 2), mm(&at, &b, 2, 3, 2));
        // a·bᵀ with a as (3,2), b as (3,2): (3,3)
        let bt = [1.0f32, 0.5, 1.5, -1.0, 2.0, -0.5]; // (2,3)
        assert_eq!(mm_nt(&a, &b, 3, 2, 3), mm(&a, &bt, 3, 2, 3));
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0, -2.0, 0.0, 2.0, 8.0];
        let g = [1.0f32; 4];
        let b = [0.0f32; 4];
        let (y, _) = layernorm(&x, &g, &b, 2, 4);
        for r in 0..2 {
            let row = &y[r * 4..(r + 1) * 4];
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5, "mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn layernorm_bwd_finite_difference() {
        let x = [0.3f32, -1.2, 0.7, 2.1, 0.4, -0.8];
        let g = [1.1f32, 0.9, 1.3];
        let b = [0.1f32, -0.2, 0.0];
        let dy = [0.5f32, -0.3, 0.8, 0.2, 0.7, -0.5];
        let (_, cache) = layernorm(&x, &g, &b, 2, 3);
        let mut dg = vec![0.0f32; 3];
        let mut db = vec![0.0f32; 3];
        let dx = layernorm_bwd(&dy, &g, &cache, 2, 3, &mut dg, &mut db);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layernorm(x, &g, &b, 2, 3);
            y.iter().zip(&dy).map(|(&a, &w)| (a * w) as f64).sum()
        };
        let mut xp = x;
        for i in 0..x.len() {
            let eps = 1e-3f32;
            xp[i] = x[i] + eps;
            let lp = loss(&xp);
            xp[i] = x[i] - eps;
            let lm = loss(&xp);
            xp[i] = x[i];
            let num = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 2e-3,
                "dx[{i}] analytic {} vs numeric {num}",
                dx[i]
            );
        }
    }

    #[test]
    fn softmax_prefix_masks_tail() {
        let mut row = [1.0f32, 2.0, 3.0, 99.0];
        softmax_prefix(&mut row, 3);
        assert_eq!(row[3], 0.0);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn xent_uniform_logits() {
        let logits = vec![0.0f32; 2 * 5];
        let (loss, d) = xent(&logits, &[1, 3], 5);
        assert!((loss - (5f64).ln()).abs() < 1e-6);
        // gradient rows sum to zero
        for r in 0..2 {
            let s: f32 = d[r * 5..(r + 1) * 5].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        assert!(d[5 + 3] < 0.0 && d[5] > 0.0);
    }
}
