//! Fused per-tensor-LR optimizer steps, mirroring the Pallas kernels'
//! oracles (`python/compile/kernels/ref.py::{adam,sgd}_update_ref`)
//! operation-for-operation so golden trajectories agree across backends.

/// Adam with bias correction and decoupled weight decay; `t` is the
/// 1-based step count (fed through hp_vec slot 7 by the session).
/// `gmul` scales the raw gradient *before* it enters the moments — the
/// per-tensor fold residue of parametrizations that fold their weight
/// multipliers into the stored tensors (u-μP); it must touch the moments
/// rather than the LR because ε breaks Adam's scale invariance.
/// `gmul = 1.0` is bitwise inert (IEEE `1.0·g == g`).
///
/// The fused zip walk mirrors the blocked tensor kernels' style: one
/// forward pass over equal-length slices with no index bounds checks, and
/// the per-element operation order is exactly the reference formula (the
/// golden trajectories pin it), so the rewrite cannot change numerics.
// assign_op_pattern is allowed because `p = p - a - b` is the reference
// formula's exact operation order; `p -= a + b` would round differently.
#[allow(clippy::too_many_arguments, clippy::assign_op_pattern)]
pub fn adam_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    gmul: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    wd: f32,
    t: f32,
) {
    debug_assert!(g.len() == p.len() && m.len() == p.len() && v.len() == p.len());
    let bc1 = 1.0 - beta1.powf(t);
    let bc2 = 1.0 - beta2.powf(t);
    for (((pv, &gv), mv), vv) in p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
        let gv = gmul * gv;
        *mv = beta1 * *mv + (1.0 - beta1) * gv;
        *vv = beta2 * *vv + (1.0 - beta2) * gv * gv;
        let mhat = *mv / bc1;
        let vhat = *vv / bc2;
        *pv = *pv - lr * (mhat / (vhat.sqrt() + eps)) - lr * wd * *pv;
    }
}

/// Heavy-ball SGD: m ← μ·m + gmul·g; p ← p − lr·(m + wd·p).  See
/// [`adam_update`] for `gmul`; feeding it into the momentum keeps the
/// folded trajectory exactly the unfolded one under any μ.
#[allow(clippy::assign_op_pattern)]
pub fn sgd_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    lr: f32,
    gmul: f32,
    momentum: f32,
    wd: f32,
) {
    debug_assert!(g.len() == p.len() && m.len() == p.len());
    for ((pv, &gv), mv) in p.iter_mut().zip(g).zip(m.iter_mut()) {
        let gv = gmul * gv;
        *mv = momentum * *mv + gv;
        *pv = *pv - lr * (*mv + wd * *pv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_matches_ref_formula() {
        let mut p = vec![1.0f32, -2.0];
        let g = vec![0.5f32, 0.25];
        let mut m = vec![0.1f32, 0.0];
        sgd_update(&mut p, &g, &mut m, 0.1, 1.0, 0.9, 0.01);
        // m = 0.9*0.1 + 0.5 = 0.59; p = 1 - 0.1*(0.59 + 0.01*1) = 0.94
        assert!((m[0] - 0.59).abs() < 1e-6);
        assert!((p[0] - 0.94).abs() < 1e-6);
        assert!((m[1] - 0.25).abs() < 1e-6);
        assert!((p[1] - (-2.0 - 0.1 * (0.25 - 0.02))).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // with zero state, t=1: mhat = g, vhat = g² → update ≈ lr·sign(g)
        let mut p = vec![0.0f32, 0.0];
        let g = vec![0.3f32, -0.7];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adam_update(&mut p, &g, &mut m, &mut v, 0.01, 1.0, 0.9, 0.999, 1e-8, 0.0, 1.0);
        assert!((p[0] + 0.01).abs() < 1e-4, "{}", p[0]);
        assert!((p[1] - 0.01).abs() < 1e-4, "{}", p[1]);
    }

    #[test]
    fn gmul_one_is_bitwise_inert() {
        let mut p1 = vec![0.37f32, -1.25, 4.0];
        let g = vec![0.311f32, -0.07, 2.5];
        let mut m1 = vec![0.011f32, -0.4, 0.0];
        let mut v1 = vec![0.002f32, 0.3, 0.0];
        let (mut p2, mut m2, mut v2) = (p1.clone(), m1.clone(), v1.clone());
        adam_update(&mut p1, &g, &mut m1, &mut v1, 0.01, 1.0, 0.9, 0.999, 1e-8, 0.1, 3.0);
        // reference: the pre-gmul formula, inlined with gv used directly
        {
            let (bc1, bc2) = (1.0 - 0.9f32.powf(3.0), 1.0 - 0.999f32.powf(3.0));
            for (((pv, &gv), mv), vv) in
                p2.iter_mut().zip(&g).zip(m2.iter_mut()).zip(v2.iter_mut())
            {
                *mv = 0.9 * *mv + (1.0 - 0.9) * gv;
                *vv = 0.999 * *vv + (1.0 - 0.999) * gv * gv;
                let mhat = *mv / bc1;
                let vhat = *vv / bc2;
                *pv = *pv - 0.01 * (mhat / (vhat.sqrt() + 1e-8)) - 0.01 * 0.1 * *pv;
            }
        }
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn gmul_folds_like_rescaled_gradient() {
        // gmul = k must equal feeding k·g with gmul = 1 (both optimizers)
        let k = 0.125f32;
        let g = vec![0.3f32, -0.7];
        let kg: Vec<f32> = g.iter().map(|x| k * x).collect();
        let mut p1 = vec![0.1f32, 0.2];
        let mut m1 = vec![0.0f32; 2];
        let mut v1 = vec![0.0f32; 2];
        let (mut p2, mut m2, mut v2) = (p1.clone(), m1.clone(), v1.clone());
        adam_update(&mut p1, &g, &mut m1, &mut v1, 0.01, k, 0.9, 0.999, 1e-8, 0.0, 1.0);
        adam_update(&mut p2, &kg, &mut m2, &mut v2, 0.01, 1.0, 0.9, 0.999, 1e-8, 0.0, 1.0);
        assert_eq!(p1, p2);
        let mut q1 = vec![0.1f32, 0.2];
        let mut n1 = vec![0.05f32, 0.0];
        let (mut q2, mut n2) = (q1.clone(), n1.clone());
        sgd_update(&mut q1, &g, &mut n1, 0.1, k, 0.9, 0.0);
        sgd_update(&mut q2, &kg, &mut n2, 0.1, 1.0, 0.9, 0.0);
        assert_eq!(q1, q2);
        assert_eq!(n1, n2);
    }

    #[test]
    fn adam_bias_correction_uses_step() {
        let mut p1 = vec![0.0f32];
        let mut m1 = vec![0.05f32];
        let mut v1 = vec![0.01f32];
        let mut p2 = p1.clone();
        let mut m2 = m1.clone();
        let mut v2 = v1.clone();
        let g = vec![0.1f32];
        adam_update(&mut p1, &g, &mut m1, &mut v1, 0.01, 1.0, 0.9, 0.999, 1e-8, 0.0, 1.0);
        adam_update(&mut p2, &g, &mut m2, &mut v2, 0.01, 1.0, 0.9, 0.999, 1e-8, 0.0, 5.0);
        assert!(p1[0] != p2[0], "step count must change the update");
    }
}
