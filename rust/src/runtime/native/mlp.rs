//! Native MLP (Section 3 / Fig. 3) and residual MLP (Tab. 12 ResNet
//! stand-in) with SGD+momentum — model.py `make_mlp_steps` /
//! `make_resmlp_steps` mirrored from `python/tools/native_ref.py`
//! (`mlp_fwd_bwd` / `resmlp_fwd_bwd`, finite-difference-verified).
//!
//! hp_vec slots (model.py HP_SGD_*): 0 output-logit multiplier,
//! 1 momentum, 2 weight decay.

use anyhow::{bail, Result};

use crate::model::{MlpConfig, ResMlpConfig};
use crate::runtime::backend::{BackendSession, DataBatch, ModelState, Probe};
use crate::runtime::manifest::{Arch, Variant};

use super::optim::sgd_update;
use super::tensor::{
    add_bias, axpy, col_sum, layernorm, layernorm_bwd, mm, mm_nt, mm_tn, relu, relu_bwd, xent,
};

#[derive(Clone, Copy, PartialEq)]
enum Act {
    Relu,
    Tanh,
}

#[derive(Clone, Copy, PartialEq)]
enum Loss {
    Xent,
    Mse,
}

enum Net {
    Mlp { cfg: MlpConfig, act: Act, loss: Loss },
    ResMlp { cfg: ResMlpConfig },
}

/// One SGD-family model: owns params + momentum buffers.
pub struct SgdNetSession {
    net: Net,
    params: Vec<Vec<f32>>,
    ms: Vec<Vec<f32>>,
}

impl SgdNetSession {
    pub fn new(variant: &Variant, init: Vec<Vec<f32>>) -> Result<SgdNetSession> {
        let net = match variant.arch {
            Arch::Mlp => {
                let act = match variant.config_str.get("act").map(|s| s.as_str()) {
                    Some("tanh") => Act::Tanh,
                    _ => Act::Relu,
                };
                let loss = match variant.config_str.get("loss").map(|s| s.as_str()) {
                    Some("mse") => Loss::Mse,
                    _ => Loss::Xent,
                };
                Net::Mlp {
                    cfg: MlpConfig::from_variant(variant),
                    act,
                    loss,
                }
            }
            Arch::ResMlp => Net::ResMlp {
                cfg: ResMlpConfig::from_variant(variant),
            },
            Arch::Transformer => bail!("transformer handled by TfmSession"),
        };
        let ms = init.iter().map(|p| vec![0.0; p.len()]).collect();
        Ok(SgdNetSession {
            net,
            params: init,
            ms,
        })
    }

    fn batch(&self, data: &[DataBatch]) -> Result<(Vec<f32>, Vec<usize>)> {
        let (batch, d_in, d_out) = match &self.net {
            Net::Mlp { cfg, .. } => (cfg.batch, cfg.d_in, cfg.d_out),
            Net::ResMlp { cfg } => (cfg.batch, cfg.d_in, cfg.d_out),
        };
        match data {
            [DataBatch::F32(x, xs), DataBatch::I32(y, ys)] => {
                if x.len() != batch * d_in || xs != &[batch, d_in] {
                    bail!("x shape {xs:?} != [{batch}, {d_in}]");
                }
                if y.len() != batch || ys != &[batch] {
                    bail!("y shape {ys:?} != [{batch}]");
                }
                let mut targets = Vec::with_capacity(batch);
                for &c in y {
                    if c < 0 || c as usize >= d_out {
                        bail!("class label {c} outside 0..{d_out}");
                    }
                    targets.push(c as usize);
                }
                Ok((x.clone(), targets))
            }
            _ => bail!("mlp/resmlp expect (f32 x, i32 y) data inputs"),
        }
    }

    /// Forward (+ optionally backward).  Returns (loss, grads).
    fn fwd_bwd(
        &self,
        x: &[f32],
        y: &[usize],
        hp: &[f32; 8],
        want_grads: bool,
    ) -> (f64, Option<Vec<Vec<f32>>>) {
        match &self.net {
            Net::Mlp { cfg, act, loss } => self.mlp_fwd_bwd(cfg, *act, *loss, x, y, hp, want_grads),
            Net::ResMlp { cfg } => self.resmlp_fwd_bwd(cfg, x, y, hp, want_grads),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn mlp_fwd_bwd(
        &self,
        cfg: &MlpConfig,
        act: Act,
        loss_kind: Loss,
        x: &[f32],
        y: &[usize],
        hp: &[f32; 8],
        want_grads: bool,
    ) -> (f64, Option<Vec<Vec<f32>>>) {
        let (b, n, c) = (cfg.batch, cfg.width, cfg.d_out);
        let scale = hp[0];
        // params: w1, b1, w2, b2, w3
        let (w1, b1, w2, b2, w3) = (
            &self.params[0],
            &self.params[1],
            &self.params[2],
            &self.params[3],
            &self.params[4],
        );
        let apply_act = |u: &[f32]| -> Vec<f32> {
            match act {
                Act::Relu => relu(u),
                Act::Tanh => u.iter().map(|&v| v.tanh()).collect(),
            }
        };
        let mut u1 = mm(x, w1, b, cfg.d_in, n);
        add_bias(&mut u1, b1, b, n);
        let h1 = apply_act(&u1);
        let mut u2 = mm(&h1, w2, b, n, n);
        add_bias(&mut u2, b2, b, n);
        let h2 = apply_act(&u2);
        let mut logits = mm(&h2, w3, b, n, c);
        for l in logits.iter_mut() {
            *l *= scale;
        }
        let (loss, mut dlogits) = match loss_kind {
            Loss::Xent => xent(&logits, y, c),
            Loss::Mse => {
                // mean((logits - onehot)²) over all B·C elements
                let nel = (b * c) as f32;
                let mut acc = 0.0f64;
                let mut d = vec![0.0f32; b * c];
                for r in 0..b {
                    for j in 0..c {
                        let diff = logits[r * c + j] - if y[r] == j { 1.0 } else { 0.0 };
                        acc += (diff as f64) * (diff as f64);
                        d[r * c + j] = diff * (2.0 / nel);
                    }
                }
                (acc / nel as f64, d)
            }
        };
        if !want_grads {
            return (loss, None);
        }
        for g in dlogits.iter_mut() {
            *g *= scale;
        }
        let dact = |du: &mut Vec<f32>, u: &[f32], h: &[f32]| match act {
            Act::Relu => relu_bwd(du, u),
            Act::Tanh => {
                for (g, &hv) in du.iter_mut().zip(h) {
                    *g *= 1.0 - hv * hv;
                }
            }
        };
        let gw3 = mm_tn(&h2, &dlogits, b, n, c);
        let mut du2 = mm_nt(&dlogits, w3, b, c, n);
        dact(&mut du2, &u2, &h2);
        let gw2 = mm_tn(&h1, &du2, b, n, n);
        let gb2 = col_sum(&du2, b, n);
        let mut du1 = mm_nt(&du2, w2, b, n, n);
        dact(&mut du1, &u1, &h1);
        let gw1 = mm_tn(x, &du1, b, cfg.d_in, n);
        let gb1 = col_sum(&du1, b, n);
        (loss, Some(vec![gw1, gb1, gw2, gb2, gw3]))
    }

    /// Residual-MLP block param: `params[1 + i*4 + off]`
    /// (layout: w_in, [ln_g, ln_b, w1, w2] × n_block, ln_f_g, ln_f_b, w_out).
    fn rblock(&self, i: usize, off: usize) -> &[f32] {
        &self.params[1 + i * 4 + off]
    }

    fn resmlp_fwd_bwd(
        &self,
        cfg: &ResMlpConfig,
        x: &[f32],
        y: &[usize],
        hp: &[f32; 8],
        want_grads: bool,
    ) -> (f64, Option<Vec<Vec<f32>>>) {
        let (b, n, c, nb) = (cfg.batch, cfg.width, cfg.d_out, cfg.n_block);
        let scale = hp[0];
        let pb = 4;
        let lnf_g = &self.params[1 + nb * pb];
        let lnf_b = &self.params[1 + nb * pb + 1];
        let w_out = &self.params[1 + nb * pb + 2];

        let mut h = mm(x, &self.params[0], b, cfg.d_in, n);
        let mut caches = Vec::with_capacity(nb);
        for i in 0..nb {
            let (z, lnc) = layernorm(&h, self.rblock(i, 0), self.rblock(i, 1), b, n);
            let u = mm(&z, self.rblock(i, 2), b, n, n);
            let r = relu(&u);
            let f = mm(&r, self.rblock(i, 3), b, n, n);
            axpy(&mut h, &f);
            caches.push((z, lnc, u, r));
        }
        let (hf, lnfc) = layernorm(&h, lnf_g, lnf_b, b, n);
        let mut logits = mm(&hf, w_out, b, n, c);
        for l in logits.iter_mut() {
            *l *= scale;
        }
        let (loss, mut dlogits) = xent(&logits, y, c);
        if !want_grads {
            return (loss, None);
        }
        let mut grads: Vec<Vec<f32>> = self.params.iter().map(|p| vec![0.0; p.len()]).collect();
        for g in dlogits.iter_mut() {
            *g *= scale;
        }
        let iw_out = 1 + nb * pb + 2;
        axpy(&mut grads[iw_out], &mm_tn(&hf, &dlogits, b, n, c));
        let dhf = mm_nt(&dlogits, w_out, b, c, n);
        let mut dh = {
            let (a, rest) = grads.split_at_mut(1 + nb * pb + 1);
            layernorm_bwd(
                &dhf,
                lnf_g,
                &lnfc,
                b,
                n,
                a.last_mut().unwrap(),
                &mut rest[0],
            )
        };
        for i in (0..nb).rev() {
            let (z, lnc, u, r) = &caches[i];
            let gb = 1 + i * pb;
            axpy(&mut grads[gb + 3], &mm_tn(r, &dh, b, n, n));
            let mut du = mm_nt(&dh, self.rblock(i, 3), b, n, n);
            relu_bwd(&mut du, u);
            axpy(&mut grads[gb + 2], &mm_tn(z, &du, b, n, n));
            let dz = mm_nt(&du, self.rblock(i, 2), b, n, n);
            let d = {
                let (a, rest) = grads.split_at_mut(gb + 1);
                layernorm_bwd(
                    &dz,
                    self.rblock(i, 0),
                    lnc,
                    b,
                    n,
                    a.last_mut().unwrap(),
                    &mut rest[0],
                )
            };
            axpy(&mut dh, &d);
        }
        axpy(&mut grads[0], &mm_tn(x, &dh, b, cfg.d_in, n));
        (loss, Some(grads))
    }
}

impl BackendSession for SgdNetSession {
    fn step(
        &mut self,
        data: &[DataBatch],
        lr_vec: &[f32],
        gmul: &[f32],
        hp_vec: &[f32; 8],
        _want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)> {
        let (x, y) = self.batch(data)?;
        let (loss, grads) = self.fwd_bwd(&x, &y, hp_vec, true);
        let grads = grads.expect("train step computes grads");
        let _sp = crate::obs::trace::span("optimizer");
        let (momentum, wd) = (hp_vec[1], hp_vec[2]);
        for i in 0..self.params.len() {
            let gm = if gmul.is_empty() { 1.0 } else { gmul[i] };
            sgd_update(
                &mut self.params[i],
                &grads[i],
                &mut self.ms[i],
                lr_vec[i],
                gm,
                momentum,
                wd,
            );
        }
        Ok((loss as f32, Vec::new()))
    }

    fn eval(&self, data: &[DataBatch], hp_vec: &[f32; 8]) -> Result<f32> {
        let (x, y) = self.batch(data)?;
        Ok(self.fwd_bwd(&x, &y, hp_vec, false).0 as f32)
    }

    fn param(&self, idx: usize) -> Result<Vec<f32>> {
        let p = self.params.len();
        match idx / p {
            0 => Ok(self.params[idx].clone()),
            1 => Ok(self.ms[idx - p].clone()),
            _ => bail!("state index {idx} out of range ({} tensors)", 2 * p),
        }
    }

    /// Full state capture for checkpointing: params, then the SGD momentum
    /// block (the `param(idx)` order).
    fn state(&self) -> Result<Option<ModelState>> {
        let mut tensors = Vec::with_capacity(self.params.len() * 2);
        tensors.extend(self.params.iter().cloned());
        tensors.extend(self.ms.iter().cloned());
        Ok(Some(ModelState {
            tensors,
            n_params: self.params.len(),
        }))
    }

    fn restore(&mut self, state: &ModelState) -> Result<bool> {
        let p = self.params.len();
        if state.n_params != p || state.tensors.len() != 2 * p {
            bail!(
                "mlp state mismatch: snapshot has {} params / {} tensors, session wants {p} / {}",
                state.n_params,
                state.tensors.len(),
                2 * p
            );
        }
        for (i, t) in state.tensors.iter().enumerate() {
            let want = self.params[i % p].len();
            if t.len() != want {
                bail!("state tensor {i} has {} elements, session wants {want}", t.len());
            }
        }
        for i in 0..p {
            self.params[i].copy_from_slice(&state.tensors[i]);
            self.ms[i].copy_from_slice(&state.tensors[p + i]);
        }
        Ok(true)
    }
}
