//! Built-in variant registry: the Rust mirror of
//! `python/compile/aot.py::build_registry`, so the native backend serves
//! the exact same experiment surface (names, shapes, calling conventions)
//! without any artifacts directory.
//!
//! Keep in lockstep with aot.py — `rust/tests/golden.rs` cross-checks the
//! param layouts against `crate::model`'s spec builders for every entry,
//! and (when a PJRT artifacts manifest is present) the two registries must
//! agree name-for-name.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::model::{mlp_specs, resmlp_specs, transformer_specs, MlpConfig, ResMlpConfig, TfmConfig};
use crate::runtime::manifest::{Arch, DataInput, Kind, Manifest, ModelConfig, Variant};

/// Probe names emitted by coord variants (model.py order).
pub const COORD_PROBES: [&str; 4] = ["embed_out", "attn_logits_l0", "block_out", "logits"];

fn tfm_config_fields(c: &TfmConfig) -> (ModelConfig, BTreeMap<String, String>) {
    let mut config = ModelConfig::default();
    for (k, v) in [
        ("vocab", c.vocab),
        ("seq", c.seq),
        ("batch", c.batch),
        ("d_model", c.d_model),
        ("n_layer", c.n_layer),
        ("n_head", c.n_head),
        ("d_head", c.d_head),
        ("d_ffn", c.d_ffn),
    ] {
        config.fields.insert(k.to_string(), v as f64);
    }
    let mut s = BTreeMap::new();
    s.insert("ln".into(), if c.pre_ln { "pre" } else { "post" }.into());
    (config, s)
}

fn tfm_variant(name: &str, kind: Kind, c: &TfmConfig) -> Variant {
    let (config, config_str) = tfm_config_fields(c);
    Variant {
        name: name.to_string(),
        arch: Arch::Transformer,
        kind,
        opt: "adam".into(),
        hlo_path: PathBuf::from(format!("builtin:{name}")),
        config,
        config_str,
        data_inputs: vec![DataInput {
            name: "tokens".into(),
            dtype: "i32".into(),
            shape: vec![c.batch, c.seq + 1],
        }],
        n_state: 2,
        probes: if kind == Kind::Coord {
            COORD_PROBES.iter().map(|s| s.to_string()).collect()
        } else {
            Vec::new()
        },
        params: transformer_specs(c),
        golden: None,
    }
}

fn mlp_variant(name: &str, kind: Kind, c: &MlpConfig, act: &str, loss: &str) -> Variant {
    let mut config = ModelConfig::default();
    for (k, v) in [
        ("d_in", c.d_in),
        ("width", c.width),
        ("d_out", c.d_out),
        ("batch", c.batch),
    ] {
        config.fields.insert(k.to_string(), v as f64);
    }
    let mut config_str = BTreeMap::new();
    config_str.insert("act".into(), act.to_string());
    config_str.insert("loss".into(), loss.to_string());
    Variant {
        name: name.to_string(),
        arch: Arch::Mlp,
        kind,
        opt: "sgd".into(),
        hlo_path: PathBuf::from(format!("builtin:{name}")),
        config,
        config_str,
        data_inputs: vec![
            DataInput {
                name: "x".into(),
                dtype: "f32".into(),
                shape: vec![c.batch, c.d_in],
            },
            DataInput {
                name: "y".into(),
                dtype: "i32".into(),
                shape: vec![c.batch],
            },
        ],
        n_state: 1,
        probes: Vec::new(),
        params: mlp_specs(c),
        golden: None,
    }
}

fn resmlp_variant(name: &str, kind: Kind, c: &ResMlpConfig) -> Variant {
    let mut config = ModelConfig::default();
    for (k, v) in [
        ("d_in", c.d_in),
        ("width", c.width),
        ("n_block", c.n_block),
        ("d_out", c.d_out),
        ("batch", c.batch),
    ] {
        config.fields.insert(k.to_string(), v as f64);
    }
    Variant {
        name: name.to_string(),
        arch: Arch::ResMlp,
        kind,
        opt: "sgd".into(),
        hlo_path: PathBuf::from(format!("builtin:{name}")),
        config,
        config_str: BTreeMap::new(),
        data_inputs: vec![
            DataInput {
                name: "x".into(),
                dtype: "f32".into(),
                shape: vec![c.batch, c.d_in],
            },
            DataInput {
                name: "y".into(),
                dtype: "i32".into(),
                shape: vec![c.batch],
            },
        ],
        n_state: 1,
        probes: Vec::new(),
        params: resmlp_specs(c),
        golden: None,
    }
}

/// Default transformer shape at width `w` (aot.py `tfm_dims`): n_head
/// fixed at 4, d_head = w/4, d_ffn = 4·w.
fn tfm_dims(w: usize, n_layer: usize, pre_ln: bool) -> TfmConfig {
    TfmConfig {
        vocab: 64,
        seq: 32,
        batch: 16,
        d_model: w,
        n_layer,
        n_head: 4,
        d_head: w / 4,
        d_ffn: 4 * w,
        pre_ln,
    }
}

fn mlp_cfg(width: usize) -> MlpConfig {
    MlpConfig {
        d_in: 256,
        width,
        d_out: 10,
        batch: 64,
    }
}

/// The full artifact set of aot.py, natively (DESIGN.md §4's experiment
/// index names these variants).
pub fn builtin_manifest() -> Manifest {
    let mut out: Vec<Variant> = Vec::new();
    let mut tfm = |name: String, c: TfmConfig| {
        out.push(tfm_variant(&name, Kind::Train, &c));
        out.push(tfm_variant(&format!("{name}__eval"), Kind::Eval, &c));
    };

    // Post-LN width family (Fig. 1 / Fig. 5 / Fig. 7 / Tab. 4)
    for w in [32, 64, 128, 256, 512] {
        tfm(format!("tfm_post_w{w}_d2"), tfm_dims(w, 2, false));
    }
    // Pre-LN width family (Fig. 4 / Fig. 6 / Fig. 19 / Tab. 7 proxy)
    for w in [32, 64, 128, 256, 512] {
        tfm(format!("tfm_pre_w{w}_d2"), tfm_dims(w, 2, true));
    }
    // Depth family at w128 (Fig. 4 depth transfer; pre-LN only — §6.1)
    for d in [4, 8] {
        tfm(format!("tfm_pre_w128_d{d}"), tfm_dims(128, d, true));
    }
    // Sequence-length / batch-size transfer (Fig. 19)
    for s in [16, 64] {
        let mut c = tfm_dims(128, 2, true);
        c.seq = s;
        tfm(format!("tfm_pre_w128_d2_s{s}"), c);
    }
    for b in [8, 32] {
        let mut c = tfm_dims(128, 2, true);
        c.batch = b;
        tfm(format!("tfm_pre_w128_d2_b{b}"), c);
    }
    // d_head ablation (Fig. 10): tiny d_head at fixed width
    {
        let mut c = tfm_dims(128, 2, true);
        c.d_head = 4;
        c.d_ffn = 512;
        tfm("tfm_pre_w128_d2_hd4".to_string(), c);
    }
    // n_head-as-width family (Fig. 13): fix d_head = 16, scale n_head
    for nh in [2, 4, 8, 16] {
        let c = TfmConfig {
            vocab: 64,
            seq: 32,
            batch: 16,
            d_model: 16 * nh,
            n_layer: 2,
            n_head: nh,
            d_head: 16,
            d_ffn: 64 * nh,
            pre_ln: true,
        };
        tfm(format!("tfm_pre_nh{nh}_hd16"), c);
    }
    // d_ffn-ratio family (Fig. 12): vary width ratio at fixed d_model
    for f in [128, 256, 1024, 2048] {
        let mut c = tfm_dims(128, 2, true);
        c.d_head = 32;
        c.d_ffn = f;
        tfm(format!("tfm_pre_w128_d2_f{f}"), c);
    }
    // Tab. 6 (BERT-style) + Tab. 7 (GPT-3-style) targets
    tfm("tfm_pre_w256_d4".to_string(), tfm_dims(256, 4, true));
    tfm("tfm_pre_w512_d6".to_string(), tfm_dims(512, 6, true));
    tfm("tfm_pre_w512_d4".to_string(), tfm_dims(512, 4, true));

    // Coord variants: post family at every width + pre w128
    for w in [32, 64, 128, 256, 512] {
        out.push(tfm_variant(
            &format!("tfm_post_w{w}_d2__coord"),
            Kind::Coord,
            &tfm_dims(w, 2, false),
        ));
    }
    out.push(tfm_variant(
        "tfm_pre_w128_d2__coord",
        Kind::Coord,
        &tfm_dims(128, 2, true),
    ));
    // Depth coord family at w32 (coord-check invariants for the depth
    // transfer axis: residual branches must stay O(1) as L grows)
    for d in [2, 4, 8] {
        out.push(tfm_variant(
            &format!("tfm_pre_w32_d{d}__coord"),
            Kind::Coord,
            &tfm_dims(32, d, true),
        ));
    }

    // MLP family (Fig. 3 / Fig. 9)
    for w in [64, 128, 256, 512, 1024, 2048] {
        let name = format!("mlp_w{w}");
        out.push(mlp_variant(&name, Kind::Train, &mlp_cfg(w), "relu", "xent"));
        out.push(mlp_variant(&format!("{name}__eval"), Kind::Eval, &mlp_cfg(w), "relu", "xent"));
    }
    for w in [64, 256, 1024] {
        let name = format!("mlp_tanh_w{w}");
        out.push(mlp_variant(&name, Kind::Train, &mlp_cfg(w), "tanh", "xent"));
        out.push(mlp_variant(&format!("{name}__eval"), Kind::Eval, &mlp_cfg(w), "tanh", "xent"));
        let name = format!("mlp_tanhmse_w{w}");
        out.push(mlp_variant(&name, Kind::Train, &mlp_cfg(w), "tanh", "mse"));
        out.push(mlp_variant(&format!("{name}__eval"), Kind::Eval, &mlp_cfg(w), "tanh", "mse"));
    }

    // ResMLP family (Tab. 12 ResNet substitute)
    for w in [32, 64, 128, 256] {
        let c = ResMlpConfig {
            d_in: 256,
            width: w,
            n_block: 4,
            d_out: 10,
            batch: 64,
        };
        let name = format!("resmlp_w{w}");
        out.push(resmlp_variant(&name, Kind::Train, &c));
        out.push(resmlp_variant(&format!("{name}__eval"), Kind::Eval, &c));
    }
    // ResMLP depth pair at w32 (depth-transfer acceptance: tune at
    // n_block 2, land at n_block 8)
    for nb in [2, 8] {
        let c = ResMlpConfig {
            d_in: 256,
            width: 32,
            n_block: nb,
            d_out: 10,
            batch: 64,
        };
        let name = format!("resmlp_w32_nb{nb}");
        out.push(resmlp_variant(&name, Kind::Train, &c));
        out.push(resmlp_variant(&format!("{name}__eval"), Kind::Eval, &c));
    }

    let mut variants = BTreeMap::new();
    for v in out {
        let dup = variants.insert(v.name.clone(), v);
        debug_assert!(dup.is_none(), "duplicate variant name");
    }
    Manifest {
        dir: PathBuf::from("builtin"),
        variants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_mirrors_aot_counts() {
        let m = builtin_manifest();
        // aot.py: 2×(5 post + 5 pre + 2 depth + 2 seq + 2 batch + 1 hd4 +
        // 4 nh + 4 ffn + 3 targets) train+eval pairs + 9 coord
        // (5 post + 1 pre + 3 depth)
        let tfm_pairs = 5 + 5 + 2 + 2 + 2 + 1 + 4 + 4 + 3;
        let mlp_pairs = 6 + 3 + 3;
        let resmlp_pairs = 4 + 2;
        assert_eq!(
            m.variants.len(),
            2 * (tfm_pairs + mlp_pairs + resmlp_pairs) + 9
        );
    }

    #[test]
    fn coord_variants_carry_probes() {
        let m = builtin_manifest();
        let c = m.get("tfm_post_w64_d2__coord").unwrap();
        assert_eq!(c.kind, Kind::Coord);
        assert_eq!(c.probes, COORD_PROBES.to_vec());
        assert_eq!(m.get("tfm_post_w64_d2").unwrap().probes.len(), 0);
    }

    #[test]
    fn calling_conventions_match_manifest_math() {
        let m = builtin_manifest();
        let t = m.get("tfm_post_w32_d2").unwrap();
        assert_eq!(t.n_state, 2);
        assert_eq!(t.data_inputs[0].shape, vec![16, 33]);
        assert_eq!(t.n_outputs(), 1 + t.n_params() * 3);
        let s = m.get("tfm_pre_w128_d2_s16").unwrap();
        assert_eq!(s.config.req("seq"), 16);
        assert_eq!(s.data_inputs[0].shape, vec![16, 17]);
        let mlp = m.get("mlp_tanhmse_w256").unwrap();
        assert_eq!(mlp.config_str.get("act").unwrap(), "tanh");
        assert_eq!(mlp.config_str.get("loss").unwrap(), "mse");
        assert_eq!(mlp.n_state, 1);
    }

    #[test]
    fn flops_positive_for_all_variants() {
        let m = builtin_manifest();
        for name in m.names() {
            let v = m.get(name).unwrap();
            assert!(v.flops_per_step() > 0.0, "{name}");
            assert!(v.total_numel() > 0, "{name}");
        }
    }
}
