//! The execution-backend abstraction.
//!
//! Everything above the runtime (train, sweep, tuner, transfer,
//! coordcheck, exp) composes *steps*: feed a batch plus per-tensor LRs and
//! the hp_vec, get back a loss (and, for coord variants, probe tensors).
//! A [`Backend`] supplies those steps for a manifest [`Variant`]:
//!
//! * [`crate::runtime::native`] — pure-Rust forward/backward/update, no
//!   external dependencies, `Send`, the default;
//! * `crate::runtime::pjrt` (behind the off-by-default `pjrt` cargo
//!   feature) — compiles the AOT-lowered HLO artifacts through XLA.
//!
//! Parallelism is a per-backend capability: [`Backend::parallelism`] says
//! how many sessions may run concurrently and [`Backend::session_send`]
//! hands out a `Send`-bounded session handle for worker threads.  The
//! native backend implements both; PJRT keeps the declining defaults, so
//! the sweep scheduler transparently falls back to sequential execution.
//!
//! The calling convention mirrors `python/compile/model.py`:
//!
//! ```text
//! train:  (data..., params[P], opt_state[S*P], lr_vec[P], hp_vec[8])
//!         -> (loss, params'[P], opt_state'[S*P])
//! eval:   (data..., params[P], hp_vec[8]) -> (loss,)
//! coord:  train + probe tensors
//! ```
//!
//! with the state resident inside the session between steps.

use anyhow::Result;

use super::manifest::{Manifest, Variant};

/// A host-side batch (row-major values + shape).
#[derive(Debug, Clone)]
pub enum DataBatch {
    I32(Vec<i32>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
}

impl DataBatch {
    pub fn shape(&self) -> &[usize] {
        match self {
            DataBatch::I32(_, s) | DataBatch::F32(_, s) => s,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            DataBatch::I32(v, _) => v.len(),
            DataBatch::F32(v, _) => v.len(),
        }
    }
}

/// A probe tensor copied back to the host (coordinate checking, Fig. 5).
#[derive(Debug, Clone)]
pub struct Probe {
    pub name: String,
    pub data: Vec<f32>,
}

/// Host-side copy of everything a [`BackendSession`] owns between steps:
/// the parameter tensors followed by the optimizer-state blocks (Adam m
/// then v; SGD momentum), in the same index order as
/// [`BackendSession::param`].  This is the unit the checkpoint subsystem
/// ([`crate::ckpt`]) persists and restores.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelState {
    /// `n_params` parameter tensors, then whole optimizer-state blocks of
    /// `n_params` tensors each
    pub tensors: Vec<Vec<f32>>,
    pub n_params: usize,
}

impl ModelState {
    pub fn params(&self) -> &[Vec<f32>] {
        &self.tensors[..self.n_params]
    }

    pub fn opt_state(&self) -> &[Vec<f32>] {
        &self.tensors[self.n_params..]
    }
}

/// Hyperparameter inputs fed to the executable every step.
#[derive(Debug, Clone)]
pub struct StepInputs {
    /// per-tensor effective LR (μP scale × master LR × schedule)
    pub lr_vec: Vec<f32>,
    /// per-tensor gradient multiplier fed into the optimizer moments —
    /// the fold residue `k` of parametrizations whose effective-weight
    /// multipliers are folded into the stored tensors (u-μP).  Empty =
    /// all ones (SP/μP); otherwise one entry per parameter tensor.
    pub gmul_vec: Vec<f32>,
    /// slots 0..7 — see python/compile/model.py HP_* constants
    pub hp_vec: [f32; 8],
}

/// An execution engine that can instantiate training sessions for
/// manifest variants.  Object-safe so [`crate::runtime::Runtime`] can hold
/// any backend behind one pointer.
pub trait Backend {
    /// Short identifier for logs/benches ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Create a session for `variant` from host-side initial parameters
    /// (one `Vec<f32>` per tensor, manifest order; already validated
    /// against the param specs).  Optimizer state starts at zero.  The
    /// manifest is passed for backends that need sibling variants (the
    /// PJRT backend resolves the `__eval` twin executable through it).
    fn session(
        &self,
        manifest: &Manifest,
        variant: &Variant,
        init: Vec<Vec<f32>>,
    ) -> Result<Box<dyn BackendSession>>;

    /// How many sessions this backend can usefully drive concurrently —
    /// the sweep scheduler clamps its worker count to this.  The default
    /// (1) means "sequential only"; backends whose sessions are `Send`
    /// (the native one) report `usize::MAX` and let callers pick by core
    /// count.  PJRT keeps the default: its client is not `Send`.
    fn parallelism(&self) -> usize {
        1
    }

    /// `Send`-bounded variant of [`Backend::session`]: a session handle
    /// that may be moved to a worker thread.  Backends whose session
    /// types are not `Send` (PJRT) keep the default `Ok(None)` — the
    /// sweep scheduler then falls back to its sequential loop.  `Ok(None)`
    /// is a capability answer, not an error: `Err` still means session
    /// construction itself failed.
    fn session_send(
        &self,
        manifest: &Manifest,
        variant: &Variant,
        init: Vec<Vec<f32>>,
    ) -> Result<Option<Box<dyn BackendSession + Send>>> {
        let _ = (manifest, variant, init);
        Ok(None)
    }
}

/// One model being trained: owns params + optimizer state between steps.
pub trait BackendSession {
    /// One fused optimizer step; returns the loss *before* the update and,
    /// when `want_probes` (coord variants only), the probe tensors in
    /// `variant.probes` order.  `hp_vec` already carries the 1-based Adam
    /// step counter in slot 7 — [`crate::runtime::TrainSession`] maintains
    /// it so backends stay stateless about step indices.  `gmul` is the
    /// per-tensor gradient multiplier ([`StepInputs::gmul_vec`]); an empty
    /// slice means all ones, and backends that cannot apply a non-trivial
    /// one must error rather than silently train a different model.
    fn step(
        &mut self,
        data: &[DataBatch],
        lr_vec: &[f32],
        gmul: &[f32],
        hp_vec: &[f32; 8],
        want_probes: bool,
    ) -> Result<(f32, Vec<Probe>)>;

    /// Forward-only loss on a batch with the current parameters.
    fn eval(&self, data: &[DataBatch], hp_vec: &[f32; 8]) -> Result<f32>;

    /// Copy a state tensor back to the host: indices `0..n_params` are the
    /// parameters, followed by the optimizer-state blocks.
    fn param(&self, idx: usize) -> Result<Vec<f32>>;

    /// Capability: copy the session's *entire* mutable state (params +
    /// optimizer moments) to the host for checkpointing.  Mirrors the
    /// [`Backend::session_send`] pattern: `Ok(None)` means the backend
    /// declines (PJRT keeps its state device-side and keeps this default;
    /// callers then skip checkpointing), while `Err` means capture itself
    /// failed.  The native backend implements it.
    fn state(&self) -> Result<Option<ModelState>> {
        Ok(None)
    }

    /// Capability: overwrite the session's state from a snapshot.
    /// `Ok(false)` = declined (the caller keeps its freshly-initialized
    /// session and re-runs from step 0); `Err` = the snapshot does not fit
    /// this session (tensor count/length mismatch).
    fn restore(&mut self, state: &ModelState) -> Result<bool> {
        let _ = state;
        Ok(false)
    }
}
