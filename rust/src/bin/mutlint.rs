//! `mutlint` — run the project-invariant lints (DESIGN.md §11) over the
//! tree and fail on any unsuppressed finding.
//!
//! ```text
//! cargo run --release --bin mutlint [ROOT]
//! ```
//!
//! * `ROOT` defaults to the current directory (CI runs it from the repo
//!   root).
//! * Exit 0: clean.  Exit 1: unsuppressed findings.  Exit 2: usage/IO
//!   error.
//! * `MUTLINT_NO_ASSERT=1` reports findings but exits 0 — the same escape
//!   hatch convention as the bench gates (`BENCH_NO_ASSERT=1`).

use mutransfer::analysis::{load_tree, passes};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let root = match args.next() {
        None => PathBuf::from("."),
        Some(a) if a == "--help" || a == "-h" => {
            println!("usage: mutlint [ROOT]");
            println!("lints: {}", passes::LINTS.join(", "));
            println!("suppress with: // mutlint: allow(<lint>, \"<reason>\")");
            return ExitCode::SUCCESS;
        }
        Some(a) => PathBuf::from(a),
    };
    if args.next().is_some() {
        eprintln!("usage: mutlint [ROOT]");
        return ExitCode::from(2);
    }

    let files = match load_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("mutlint: failed to read tree under {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if files.is_empty() {
        eprintln!("mutlint: no .rs files found under {} (expected rust/src)", root.display());
        return ExitCode::from(2);
    }

    let findings = passes::run_all(&files);
    let mut live = 0usize;
    let mut suppressed = 0usize;
    for f in &findings {
        if f.suppressed {
            suppressed += 1;
        } else {
            live += 1;
            println!("{}", f.render());
        }
    }
    println!(
        "mutlint: {} files, {} finding(s) ({} suppressed with reasons)",
        files.len(),
        live,
        suppressed
    );
    if live == 0 {
        return ExitCode::SUCCESS;
    }
    if std::env::var("MUTLINT_NO_ASSERT").is_ok_and(|v| v == "1") {
        println!("mutlint: MUTLINT_NO_ASSERT=1 set; reporting only");
        return ExitCode::SUCCESS;
    }
    ExitCode::FAILURE
}
