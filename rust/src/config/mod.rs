//! Experiment configuration files: a TOML-subset parser + typed run
//! configs, so sweeps are reproducible from checked-in files rather than
//! CLI flags (the "real config system" a framework needs).
//!
//! Supported TOML subset: `[section]` and `[section.sub]` headers,
//! `key = value` with string/float/int/bool/array-of-scalars values, `#`
//! comments.  That covers every config this repo ships; exotic TOML
//! (dates, inline tables, multi-line strings) is intentionally rejected.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::mup::HyperParams;
use crate::train::Schedule;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// section -> key -> value
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let val = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), val);
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.f64_or(section, key, default as f64) as usize
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    /// Materialize the `[hyperparams]` section onto defaults.
    pub fn hyperparams(&self) -> HyperParams {
        let mut hp = HyperParams::default();
        if let Some(s) = self.sections.get("hyperparams") {
            for (k, v) in s {
                if let Some(x) = v.as_f64() {
                    match k.as_str() {
                        "lr" => hp.lr = x,
                        "sigma" => hp.sigma = x,
                        "alpha_output" => hp.alpha_output = x,
                        "alpha_attn" => hp.alpha_attn = x,
                        "alpha_embed" => hp.alpha_embed = x,
                        "lr_emb_ratio" => hp.lr_emb_ratio = x,
                        "beta1" => hp.beta1 = x,
                        "beta2" => hp.beta2 = x,
                        "eps" => hp.eps = x,
                        "weight_decay" => hp.weight_decay = x,
                        "momentum" => hp.momentum = x,
                        _ => {}
                    }
                }
            }
        }
        hp
    }

    /// `[train] schedule = "..."`.
    pub fn schedule(&self) -> Schedule {
        Schedule::named(&self.str_or("train", "schedule", "constant"))
            .unwrap_or(Schedule::Constant)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut vals = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                vals.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(vals));
    }
    if let Ok(n) = s.parse::<f64>() {
        return Ok(Value::Num(n));
    }
    bail!("unparseable value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a run config
[run]
variant = "tfm_post_w128_d2"
steps = 100          # comment after value
seeds = [0, 1, 2]

[train]
schedule = "cosine"

[hyperparams]
lr = 2e-3
alpha_output = 4.0
weight_decay = 0.01
"#;

    #[test]
    fn parses_sections_and_values() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("run", "variant", ""), "tfm_post_w128_d2");
        assert_eq!(c.usize_or("run", "steps", 0), 100);
        match c.get("run", "seeds").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn hyperparams_overlay() {
        let c = Config::parse(SAMPLE).unwrap();
        let hp = c.hyperparams();
        assert_eq!(hp.lr, 2e-3);
        assert_eq!(hp.alpha_output, 4.0);
        assert_eq!(hp.weight_decay, 0.01);
        assert_eq!(hp.beta1, 0.9); // default preserved
    }

    #[test]
    fn schedule_lookup() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.schedule(), Schedule::Cosine);
        let d = Config::parse("").unwrap();
        assert_eq!(d.schedule(), Schedule::Constant);
    }

    #[test]
    fn string_with_hash_kept() {
        let c = Config::parse("[a]\nk = \"x # y\"\n").unwrap();
        assert_eq!(c.str_or("a", "k", ""), "x # y");
    }

    #[test]
    fn errors() {
        assert!(Config::parse("[oops\n").is_err());
        assert!(Config::parse("[a]\nnovalue\n").is_err());
        assert!(Config::parse("[a]\nk = @bogus\n").is_err());
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.f64_or("x", "y", 1.5), 1.5);
        assert_eq!(c.str_or("x", "y", "z"), "z");
    }
}
