//! Statistics substrate: percentiles, moments, regression, bootstrap.
//!
//! Every table in the paper's §7 reports percentiles over repeated tuning
//! trials (Table 4: 25th/50th/75th/100th over 25 trials); Fig. 5 needs
//! coordinate standard deviations and log-log growth-exponent fits.  No
//! stats crate is vendored, so this is built from scratch and unit-tested
//! against hand-computed values.
//!
//! ## Non-finite inputs (diverged trials)
//!
//! Sweeps deliberately include diverging trials, whose `val_loss` decodes
//! from the journal as NaN — so NaN is first-class data here, never a
//! panic.  Ordering statistics treat a NaN as *worse than every real
//! loss*: [`sort_nan_last`] places all NaNs after every finite value (and
//! after ±∞), so a percentile whose rank falls into the NaN tail — e.g.
//! p100 as soon as one trial diverged — is NaN, while lower percentiles
//! stay finite as long as enough finite mass remains.  Interpolation
//! between a finite value and a NaN neighbour is NaN.  Callers that want
//! finite-only semantics filter first (see `exp/tab4`'s percentile rows
//! and `tuner::select_best`).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coordinate-size statistic used by coordinate checking (App. D.1):
/// sqrt(mean(x_i^2)) — the "typical size" of Definition J.1.
pub fn rms(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Total order with every NaN sorted last (after +∞), regardless of the
/// NaN's sign bit — the "diverged is worst" ordering used by all
/// selection and percentile paths.  Never panics, unlike
/// `partial_cmp().unwrap()`, which a single diverged trial used to crash.
pub fn nan_last(a: &f64, b: &f64) -> std::cmp::Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => a.total_cmp(b),
    }
}

/// Sort ascending with NaNs last (see [`nan_last`]).
pub fn sort_nan_last(xs: &mut [f64]) {
    xs.sort_by(nan_last);
}

/// Linear-interpolated percentile, p in [0, 100].  Matches numpy's
/// default ("linear") method on finite inputs; NaNs sort last, so ranks
/// that land in (or interpolate into) the NaN tail return NaN (module
/// docs, "Non-finite inputs").
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    sort_nan_last(&mut v);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// The Table-4-style percentile row (25/50/75/100).  Diverged (NaN)
/// entries rank worst, so p100 is NaN as soon as any trial diverged and
/// the remaining quartiles follow the documented NaN-tail semantics —
/// no panic.
pub fn quartile_row(xs: &[f64]) -> [f64; 4] {
    [
        percentile(xs, 25.0),
        percentile(xs, 50.0),
        percentile(xs, 75.0),
        percentile(xs, 100.0),
    ]
}

/// Least-squares fit y = a + b·x; returns (a, b).
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

/// Growth exponent α in y ≈ C·widthᵅ via log-log regression — the
/// quantitative form of Fig. 5's blow-up claim (α ≈ 0.5 for SP logits,
/// α ≈ 0 under μP).
pub fn growth_exponent(widths: &[f64], values: &[f64]) -> f64 {
    let lx: Vec<f64> = widths.iter().map(|w| w.ln()).collect();
    let ly: Vec<f64> = values.iter().map(|v| v.max(1e-300).ln()).collect();
    linfit(&lx, &ly).1
}

/// Percentile bootstrap confidence interval for the mean.  A NaN input
/// (diverged trial) contaminates every resample that draws it, so with
/// NaNs present the bounds degrade toward NaN deterministically rather
/// than panicking; filter to finite values first for a finite CI.
pub fn bootstrap_mean_ci(
    xs: &[f64],
    iters: usize,
    alpha: f64,
    rng: &mut crate::init::rng::Rng,
) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mut means: Vec<f64> = (0..iters)
        .map(|_| {
            let s: f64 = (0..xs.len()).map(|_| xs[rng.below(xs.len())]).sum();
            s / xs.len() as f64
        })
        .collect();
    sort_nan_last(&mut means);
    (
        percentile(&means, 100.0 * alpha / 2.0),
        percentile(&means, 100.0 * (1.0 - alpha / 2.0)),
    )
}

/// argmin over (value, index); None for empty or all-NaN.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.map(|(_, b)| x < b).unwrap_or(true) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_matches_numpy_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 75.0) - 3.25).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        // unsorted input
        let ys = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&ys, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quartiles() {
        let xs: Vec<f64> = (1..=25).map(|i| i as f64).collect();
        let q = quartile_row(&xs);
        assert_eq!(q, [7.0, 13.0, 19.0, 25.0]);
    }

    #[test]
    fn linfit_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linfit(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn growth_exponent_recovers_power_law() {
        let widths = [64.0, 128.0, 256.0, 512.0, 1024.0];
        let values: Vec<f64> = widths.iter().map(|&w: &f64| 3.0 * w.powf(0.5)).collect();
        assert!((growth_exponent(&widths, &values) - 0.5).abs() < 1e-9);
        let flat: Vec<f64> = widths.iter().map(|_| 2.5).collect();
        assert!(growth_exponent(&widths, &flat).abs() < 1e-9);
    }

    #[test]
    fn rms_known() {
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-6);
        assert_eq!(rms(&[]), 0.0);
    }

    #[test]
    fn sort_nan_last_total_order() {
        let mut xs = [
            f64::NAN,
            1.0,
            f64::NEG_INFINITY,
            -f64::NAN,
            f64::INFINITY,
            -3.0,
        ];
        sort_nan_last(&mut xs);
        assert_eq!(xs[0], f64::NEG_INFINITY);
        assert_eq!(xs[1], -3.0);
        assert_eq!(xs[2], 1.0);
        assert_eq!(xs[3], f64::INFINITY);
        assert!(xs[4].is_nan() && xs[5].is_nan(), "NaNs (either sign) last");
    }

    /// One diverged trial: no panic; p100 is NaN, lower quartiles finite.
    #[test]
    fn percentile_with_nan_tail() {
        let xs = [2.0, f64::NAN, 1.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // rank interpolating into the NaN tail is NaN too
        assert!(percentile(&xs, 90.0).is_nan());
        let q = quartile_row(&xs);
        assert_eq!(q[0], 2.0);
        assert_eq!(q[1], 3.0);
        assert!(q[3].is_nan());
    }

    /// Everything diverged: still no panic, all-NaN row.
    #[test]
    fn quartiles_all_nan() {
        let xs = [f64::NAN, f64::NAN];
        let q = quartile_row(&xs);
        assert!(q.iter().all(|v| v.is_nan()), "{q:?}");
    }

    /// NaN-laden bootstrap must not panic; bounds degrade toward NaN.
    #[test]
    fn bootstrap_ci_tolerates_nan() {
        let mut rng = crate::init::rng::Rng::new(6);
        let mut xs: Vec<f64> = (0..20).map(|_| rng.gaussian()).collect();
        xs.push(f64::NAN);
        let (lo, hi) = bootstrap_mean_ci(&xs, 50, 0.05, &mut rng);
        // with 21 draws per resample a NaN lands in essentially every
        // resample, so both bounds are NaN — the point is the call returns
        assert!(lo.is_nan() || lo.is_finite());
        assert!(hi.is_nan() || hi.is_finite());
    }

    #[test]
    fn argmin_skips_nan() {
        assert_eq!(argmin(&[3.0, f64::NAN, 1.0, 2.0]), Some(2));
        assert_eq!(argmin(&[f64::NAN]), None);
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn bootstrap_ci_brackets_mean() {
        let mut rng = crate::init::rng::Rng::new(5);
        let xs: Vec<f64> = (0..200).map(|_| rng.gaussian() + 10.0).collect();
        let (lo, hi) = bootstrap_mean_ci(&xs, 500, 0.05, &mut rng);
        let m = mean(&xs);
        assert!(lo < m && m < hi, "({lo}, {hi}) vs {m}");
        assert!(hi - lo < 1.0);
    }
}
