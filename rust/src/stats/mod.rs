//! Statistics substrate: percentiles, moments, regression, bootstrap.
//!
//! Every table in the paper's §7 reports percentiles over repeated tuning
//! trials (Table 4: 25th/50th/75th/100th over 25 trials); Fig. 5 needs
//! coordinate standard deviations and log-log growth-exponent fits.  No
//! stats crate is vendored, so this is built from scratch and unit-tested
//! against hand-computed values.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coordinate-size statistic used by coordinate checking (App. D.1):
/// sqrt(mean(x_i^2)) — the "typical size" of Definition J.1.
pub fn rms(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].  Matches numpy's
/// default ("linear") method.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// The Table-4-style percentile row (25/50/75/100).
pub fn quartile_row(xs: &[f64]) -> [f64; 4] {
    [
        percentile(xs, 25.0),
        percentile(xs, 50.0),
        percentile(xs, 75.0),
        percentile(xs, 100.0),
    ]
}

/// Least-squares fit y = a + b·x; returns (a, b).
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

/// Growth exponent α in y ≈ C·widthᵅ via log-log regression — the
/// quantitative form of Fig. 5's blow-up claim (α ≈ 0.5 for SP logits,
/// α ≈ 0 under μP).
pub fn growth_exponent(widths: &[f64], values: &[f64]) -> f64 {
    let lx: Vec<f64> = widths.iter().map(|w| w.ln()).collect();
    let ly: Vec<f64> = values.iter().map(|v| v.max(1e-300).ln()).collect();
    linfit(&lx, &ly).1
}

/// Percentile bootstrap confidence interval for the mean.
pub fn bootstrap_mean_ci(
    xs: &[f64],
    iters: usize,
    alpha: f64,
    rng: &mut crate::init::rng::Rng,
) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mut means: Vec<f64> = (0..iters)
        .map(|_| {
            let s: f64 = (0..xs.len()).map(|_| xs[rng.below(xs.len())]).sum();
            s / xs.len() as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile(&means, 100.0 * alpha / 2.0),
        percentile(&means, 100.0 * (1.0 - alpha / 2.0)),
    )
}

/// argmin over (value, index); None for empty or all-NaN.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.map(|(_, b)| x < b).unwrap_or(true) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_matches_numpy_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 75.0) - 3.25).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        // unsorted input
        let ys = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&ys, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quartiles() {
        let xs: Vec<f64> = (1..=25).map(|i| i as f64).collect();
        let q = quartile_row(&xs);
        assert_eq!(q, [7.0, 13.0, 19.0, 25.0]);
    }

    #[test]
    fn linfit_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linfit(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn growth_exponent_recovers_power_law() {
        let widths = [64.0, 128.0, 256.0, 512.0, 1024.0];
        let values: Vec<f64> = widths.iter().map(|&w: &f64| 3.0 * w.powf(0.5)).collect();
        assert!((growth_exponent(&widths, &values) - 0.5).abs() < 1e-9);
        let flat: Vec<f64> = widths.iter().map(|_| 2.5).collect();
        assert!(growth_exponent(&widths, &flat).abs() < 1e-9);
    }

    #[test]
    fn rms_known() {
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-6);
        assert_eq!(rms(&[]), 0.0);
    }

    #[test]
    fn argmin_skips_nan() {
        assert_eq!(argmin(&[3.0, f64::NAN, 1.0, 2.0]), Some(2));
        assert_eq!(argmin(&[f64::NAN]), None);
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn bootstrap_ci_brackets_mean() {
        let mut rng = crate::init::rng::Rng::new(5);
        let xs: Vec<f64> = (0..200).map(|_| rng.gaussian() + 10.0).collect();
        let (lo, hi) = bootstrap_mean_ci(&xs, 500, 0.05, &mut rng);
        let m = mean(&xs);
        assert!(lo < m && m < hi, "({lo}, {hi}) vs {m}");
        assert!(hi - lo < 1.0);
    }
}
