//! Minimal JSON codec (parser + writer).
//!
//! The vendored crate set has no `serde`, so the manifest loader, the
//! sweep journal and the results store use this hand-rolled implementation.
//! It supports the full JSON grammar we emit from `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers incl. exponents, bools,
//! null) and round-trips `f64` values losslessly enough for loss curves.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Maximum container nesting accepted by both the eager parser and the
/// lazy scanner.  The serve API feeds this codec untrusted network input;
/// without a bound, `[[[[…` recurses once per bracket and overflows the
/// stack long before any allocation limit trips.
pub const MAX_DEPTH: usize = 256;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields; the error message
    /// names the key so schema drift is easy to diagnose.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key: {key}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ---- writer ---------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        // 17 significant digits round-trips f64 exactly
                        let _ = write!(out, "{n:e}");
                    }
                } else {
                    // JSON has no inf/nan; encode as null (divergence marks
                    // are carried separately as booleans).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: src.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

/// Four ascii hex digits at `start` (a `\uXXXX` payload), or `None`.
/// Shared by the eager parser and the lazy scanner so `\u` acceptance can
/// never drift between them; digits are checked explicitly because
/// `from_str_radix` alone also accepts a leading `+`.
fn hex4_at(b: &[u8], start: usize) -> Option<u32> {
    let hex = b.get(start..start + 4)?;
    if !hex.iter().all(|c| c.is_ascii_hexdigit()) {
        return None;
    }
    u32::from_str_radix(std::str::from_utf8(hex).unwrap(), 16).ok()
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting exceeds depth limit"));
        }
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting exceeds depth limit"));
        }
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    /// Four hex digits starting at byte `start` (a `\uXXXX` payload).
    fn hex4(&self, start: usize) -> Result<u32, JsonError> {
        hex4_at(self.b, start).ok_or_else(|| self.err("bad \\u escape"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hi = self.hex4(self.pos + 1)?;
                            self.pos += 4; // now on the escape's last hex digit
                            let mut cp = hi;
                            if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: an immediately following
                                // \uDC00–\uDFFF escape combines into one
                                // supplementary-plane scalar — the serve
                                // API echoes client-supplied job names, so
                                // a uD83D-uDE00 pair must decode to U+1F600
                                // ("😀"), not two replacement chars.
                                if self.b.get(self.pos + 1) == Some(&b'\\')
                                    && self.b.get(self.pos + 2) == Some(&b'u')
                                {
                                    if let Ok(lo) = self.hex4(self.pos + 3) {
                                        if (0xDC00..0xE000).contains(&lo) {
                                            cp = 0x10000
                                                + ((hi - 0xD800) << 10)
                                                + (lo - 0xDC00);
                                            self.pos += 6; // consume "\uXXXX" too
                                        }
                                    }
                                }
                            }
                            // unpaired surrogates map to the replacement char
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x80 => {
                    s.push(b as char);
                    self.pos += 1;
                }
                Some(b) => {
                    // copy one multi-byte scalar, validating only its own
                    // bytes: re-validating the whole remaining input per
                    // character was O(n²), and this parser now sees
                    // untrusted network input through the serve API
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    if self.pos + len > self.b.len() {
                        return Err(self.err("invalid utf-8"));
                    }
                    let st = std::str::from_utf8(&self.b[self.pos..self.pos + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push(st.chars().next().unwrap());
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// lazy scanner
// ---------------------------------------------------------------------------

/// Scan-only JSON access: validate a document or extract one value's raw
/// text span *without building the tree*.
///
/// `GET /jobs/:id/results` documents carry full loss curves; a `?path=`
/// partial read, the `/hp` startup scan and journal tailing only need one
/// or two leaves, so allocating a `BTreeMap` per object line is pure
/// waste.  The scanner walks the same grammar as [`parse`] byte-for-byte
/// — same escape set, same per-scalar UTF-8 validation, same `f64`
/// acceptance on number spans, same [`MAX_DEPTH`] — so
/// `validate(s).is_ok() == parse(s).is_ok()` for every input (pinned by a
/// property test and the fuzz differential target).
pub mod lazy {
    use super::{hex4_at, JsonError, MAX_DEPTH};

    /// Full scan of `src` with no tree construction.  Accepts exactly the
    /// documents [`super::parse`] accepts.
    pub fn validate(src: &str) -> Result<(), JsonError> {
        let mut s = Scanner {
            b: src.as_bytes(),
            pos: 0,
            depth: 0,
        };
        s.ws();
        s.skip_value()?;
        s.ws();
        if s.pos != s.b.len() {
            return Err(s.err("trailing data"));
        }
        Ok(())
    }

    /// Extract the raw text of the value at a dot-separated `path`
    /// (object keys and array indices, e.g. `"best.lr"` or
    /// `"curve.3"`).  Returns `Ok(None)` when the path does not resolve
    /// (missing key, index out of range, or indexing into a scalar);
    /// `Err` on malformed JSON *along the scanned route* — bytes after
    /// the target value are never examined, so run [`validate`] first if
    /// the document itself is untrusted.
    pub fn extract<'a>(src: &'a str, path: &str) -> Result<Option<&'a str>, JsonError> {
        let mut s = Scanner {
            b: src.as_bytes(),
            pos: 0,
            depth: 0,
        };
        s.ws();
        for seg in path.split('.') {
            if seg.is_empty() {
                return Err(s.err("empty path segment"));
            }
            match s.peek() {
                Some(b'{') => {
                    s.pos += 1;
                    s.depth += 1;
                    if s.depth > MAX_DEPTH {
                        return Err(s.err("nesting exceeds depth limit"));
                    }
                    s.ws();
                    if s.peek() == Some(b'}') {
                        return Ok(None);
                    }
                    loop {
                        s.ws();
                        let span = s.skip_string()?;
                        s.ws();
                        s.eat(b':')?;
                        s.ws();
                        if key_matches(src, span, seg)? {
                            break; // descend into this value
                        }
                        s.skip_value()?;
                        s.ws();
                        match s.peek() {
                            Some(b',') => s.pos += 1,
                            Some(b'}') => return Ok(None),
                            _ => return Err(s.err("expected ',' or '}'")),
                        }
                    }
                }
                Some(b'[') => {
                    let Ok(idx) = seg.parse::<usize>() else {
                        return Ok(None); // non-numeric segment on an array
                    };
                    s.pos += 1;
                    s.depth += 1;
                    if s.depth > MAX_DEPTH {
                        return Err(s.err("nesting exceeds depth limit"));
                    }
                    s.ws();
                    if s.peek() == Some(b']') {
                        return Ok(None);
                    }
                    let mut i = 0usize;
                    loop {
                        s.ws();
                        if i == idx {
                            break; // descend into this element
                        }
                        s.skip_value()?;
                        s.ws();
                        match s.peek() {
                            Some(b',') => {
                                s.pos += 1;
                                i += 1;
                            }
                            Some(b']') => return Ok(None),
                            _ => return Err(s.err("expected ',' or ']'")),
                        }
                    }
                }
                _ => return Ok(None), // scalars have no children
            }
        }
        let start = s.pos;
        s.skip_value()?;
        Ok(Some(&src[start..s.pos]))
    }

    /// Compare a scanned key span against a wanted segment, unescaping
    /// only when the raw bytes contain a backslash.
    fn key_matches(src: &str, span: (usize, usize), want: &str) -> Result<bool, JsonError> {
        let raw = &src[span.0..span.1];
        if !raw.as_bytes().contains(&b'\\') {
            return Ok(raw == want);
        }
        // rare path: re-run the eager string decoder on just the quoted
        // slice (already validated by skip_string, so this cannot fail)
        let mut p = super::Parser {
            b: src[span.0 - 1..span.1 + 1].as_bytes(),
            pos: 0,
            depth: 0,
        };
        let k = p.string().map_err(|e| JsonError {
            pos: span.0 - 1 + e.pos,
            msg: e.msg,
        })?;
        Ok(k == want)
    }

    struct Scanner<'a> {
        b: &'a [u8],
        pos: usize,
        depth: usize,
    }

    impl<'a> Scanner<'a> {
        fn err(&self, msg: &str) -> JsonError {
            JsonError {
                pos: self.pos,
                msg: msg.to_string(),
            }
        }

        fn peek(&self) -> Option<u8> {
            self.b.get(self.pos).copied()
        }

        fn ws(&mut self) {
            while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.pos += 1;
            }
        }

        fn eat(&mut self, c: u8) -> Result<(), JsonError> {
            if self.peek() == Some(c) {
                self.pos += 1;
                Ok(())
            } else {
                Err(self.err(&format!("expected '{}'", c as char)))
            }
        }

        fn lit(&mut self, s: &str) -> Result<(), JsonError> {
            if self.b[self.pos..].starts_with(s.as_bytes()) {
                self.pos += s.len();
                Ok(())
            } else {
                Err(self.err(&format!("expected '{s}'")))
            }
        }

        fn skip_value(&mut self) -> Result<(), JsonError> {
            match self.peek() {
                Some(b'{') => self.skip_object(),
                Some(b'[') => self.skip_array(),
                Some(b'"') => self.skip_string().map(|_| ()),
                Some(b't') => self.lit("true"),
                Some(b'f') => self.lit("false"),
                Some(b'n') => self.lit("null"),
                Some(c) if c == b'-' || c.is_ascii_digit() => self.skip_number(),
                _ => Err(self.err("unexpected character")),
            }
        }

        fn skip_object(&mut self) -> Result<(), JsonError> {
            self.eat(b'{')?;
            self.depth += 1;
            if self.depth > MAX_DEPTH {
                return Err(self.err("nesting exceeds depth limit"));
            }
            self.ws();
            if self.peek() == Some(b'}') {
                self.pos += 1;
                self.depth -= 1;
                return Ok(());
            }
            loop {
                self.ws();
                self.skip_string()?;
                self.ws();
                self.eat(b':')?;
                self.ws();
                self.skip_value()?;
                self.ws();
                match self.peek() {
                    Some(b',') => self.pos += 1,
                    Some(b'}') => {
                        self.pos += 1;
                        self.depth -= 1;
                        return Ok(());
                    }
                    _ => return Err(self.err("expected ',' or '}'")),
                }
            }
        }

        fn skip_array(&mut self) -> Result<(), JsonError> {
            self.eat(b'[')?;
            self.depth += 1;
            if self.depth > MAX_DEPTH {
                return Err(self.err("nesting exceeds depth limit"));
            }
            self.ws();
            if self.peek() == Some(b']') {
                self.pos += 1;
                self.depth -= 1;
                return Ok(());
            }
            loop {
                self.ws();
                self.skip_value()?;
                self.ws();
                match self.peek() {
                    Some(b',') => self.pos += 1,
                    Some(b']') => {
                        self.pos += 1;
                        self.depth -= 1;
                        return Ok(());
                    }
                    _ => return Err(self.err("expected ',' or ']'")),
                }
            }
        }

        /// Skip a string, returning the content span between the quotes.
        /// A valid low-surrogate escape after a high surrogate is a valid
        /// `\u` escape on its own, so unlike the eager decoder no pair
        /// lookahead is needed — acceptance is identical either way.
        fn skip_string(&mut self) -> Result<(usize, usize), JsonError> {
            self.eat(b'"')?;
            let start = self.pos;
            loop {
                match self.peek() {
                    None => return Err(self.err("unterminated string")),
                    Some(b'"') => {
                        let end = self.pos;
                        self.pos += 1;
                        return Ok((start, end));
                    }
                    Some(b'\\') => {
                        self.pos += 1;
                        match self.peek() {
                            Some(b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f') => {}
                            Some(b'u') => {
                                if hex4_at(self.b, self.pos + 1).is_none() {
                                    return Err(self.err("bad \\u escape"));
                                }
                                self.pos += 4;
                            }
                            _ => return Err(self.err("bad escape")),
                        }
                        self.pos += 1;
                    }
                    Some(b) if b < 0x80 => self.pos += 1,
                    Some(b) => {
                        let len = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        if self.pos + len > self.b.len() {
                            return Err(self.err("invalid utf-8"));
                        }
                        std::str::from_utf8(&self.b[self.pos..self.pos + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        self.pos += len;
                    }
                }
            }
        }

        fn skip_number(&mut self) -> Result<(), JsonError> {
            let start = self.pos;
            if self.peek() == Some(b'-') {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
            if self.peek() == Some(b'.') {
                self.pos += 1;
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            if matches!(self.peek(), Some(b'e' | b'E')) {
                self.pos += 1;
                if matches!(self.peek(), Some(b'+' | b'-')) {
                    self.pos += 1;
                }
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
            txt.parse::<f64>()
                .map(|_| ())
                .map_err(|_| self.err("bad number"))
        }
    }
}

// convenience builders ------------------------------------------------------

pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

pub fn jnum(n: f64) -> Json {
    Json::Num(n)
}

pub fn jarr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

pub fn jnums(ns: &[f64]) -> Json {
    Json::Arr(ns.iter().map(|&n| Json::Num(n)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("1e-3").unwrap(), Json::Num(0.001));
        assert_eq!(parse("\"hi\"").unwrap(), jstr("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#).unwrap();
        assert_eq!(j.req("d"), &Json::Bool(true));
        let arr = j.req("a").as_arr().unwrap();
        assert_eq!(arr[1], Json::Num(2.0));
        assert_eq!(arr[2].req("b").as_str().unwrap(), "x\ny");
        assert!(arr[2].req("c").is_null());
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1,}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,-3e-4],"b":"q\"uote","c":{"d":[]}}"#,
            "[]",
            "{}",
            r#"[null,true,false,0]"#,
        ];
        for c in cases {
            let j = parse(c).unwrap();
            let s = j.to_string();
            assert_eq!(parse(&s).unwrap(), j, "case {c}");
        }
    }

    #[test]
    fn roundtrip_float_precision() {
        let v = Json::Num(0.1234567890123
);
        let back = parse(&v.to_string()).unwrap();
        assert!((back.as_f64().unwrap() - 0.1234567890123).abs() < 1e-15);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("tab\t nl\n q\" bs\\".to_string());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn nonfinite_encodes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), jstr("A"));
        assert_eq!(parse("\"\\u0041\"").unwrap(), jstr("A"));
        assert_eq!(parse("\"\\u00e9\"").unwrap(), jstr("é"));
    }

    // -- string-escaping round-trips (the serve API echoes client-supplied
    //    job names verbatim, so every class below must survive) ----------

    #[test]
    fn control_chars_roundtrip() {
        let s: String = (1u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let j = Json::Str(s.clone());
        let text = j.to_string();
        // everything below 0x20 must be escaped on the wire
        assert!(!text.chars().any(|c| (c as u32) < 0x20), "raw control byte in {text:?}");
        assert!(text.contains("\\u0001") && text.contains("\\u001f"), "{text}");
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn quote_and_backslash_roundtrip() {
        let j = Json::Str(r#"q" b\ both\" end\\"#.to_string());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
        // and as object keys, which take the same writer path
        let mut o = Json::obj();
        o.set("k\"\\\n", jnum(1.0));
        assert_eq!(parse(&o.to_string()).unwrap(), o);
    }

    #[test]
    fn non_bmp_roundtrip_raw_utf8() {
        // the writer emits supplementary-plane chars as raw UTF-8
        let j = Json::Str("job 😀🎉 \u{10348}".to_string());
        let text = j.to_string();
        assert_eq!(parse(&text).unwrap(), j);
    }

    #[test]
    fn surrogate_pair_escapes_decode_to_one_scalar() {
        // external clients (curl, python json.dumps with ensure_ascii)
        // send non-BMP chars as \u surrogate pairs
        assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap(), jstr("\u{1F600}"));
        assert_eq!(parse("\"a\\ud83d\\ude00b\"").unwrap(), jstr("a\u{1F600}b"));
        // upper-case hex digits too
        assert_eq!(parse("\"\\uD83C\\uDF89\"").unwrap(), jstr("\u{1F389}"));
        // and they round-trip through our writer (which re-emits raw UTF-8)
        let j = parse("\"\\ud800\\udc00\"").unwrap();
        assert_eq!(j, jstr("\u{10000}"));
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn lone_surrogates_become_replacement_chars() {
        // unpaired high surrogate at end of string
        assert_eq!(parse(r#""\ud800""#).unwrap(), jstr("\u{fffd}"));
        // high surrogate followed by a normal char / a non-low escape:
        // only the surrogate is replaced, the rest decodes normally
        assert_eq!(parse(r#""\ud800x""#).unwrap(), jstr("\u{fffd}x"));
        assert_eq!(parse(r#""\ud800A""#).unwrap(), jstr("\u{fffd}A"));
        // lone low surrogate
        assert_eq!(parse(r#""\udc00!""#).unwrap(), jstr("\u{fffd}!"));
        // two high surrogates in a row
        assert_eq!(parse(r#""\ud800\ud800""#).unwrap(), jstr("\u{fffd}\u{fffd}"));
    }

    #[test]
    fn truncated_unicode_escape_is_an_error() {
        assert!(parse(r#""\u00""#).is_err());
        assert!(parse(r#""\uzzzz""#).is_err());
    }

    #[test]
    fn unicode_escape_rejects_sign_digits() {
        // from_str_radix alone would accept "+123"; both paths must not
        assert!(parse(r#""\u+123""#).is_err());
        assert!(lazy::validate(r#""\u+123""#).is_err());
    }

    #[test]
    fn depth_limit_stops_both_parsers() {
        let deep = |n: usize| format!("{}0{}", "[".repeat(n), "]".repeat(n));
        let ok = deep(MAX_DEPTH);
        let too_deep = deep(MAX_DEPTH + 1);
        assert!(parse(&ok).is_ok());
        assert!(lazy::validate(&ok).is_ok());
        assert!(parse(&too_deep).is_err());
        assert!(lazy::validate(&too_deep).is_err());
        // objects count against the same budget
        let mixed = format!("{}1{}", r#"{"k":["#.repeat(129), "]}".repeat(129));
        assert!(parse(&mixed).is_err());
        assert!(lazy::validate(&mixed).is_err());
    }

    #[test]
    fn lazy_validate_agrees_with_parse_on_spot_cases() {
        let cases = [
            "null",
            " false ",
            "42",
            "-3.5e2",
            "1e999",
            "1.",
            "-.5",
            "-",
            "1e",
            "1 2",
            "",
            "{",
            "[1,]",
            r#"{"a":1,}"#,
            "tru",
            r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#,
            "\"\\ud83d\\ude00\"",
            r#""\ud800x""#,
            r#""\u00""#,
            r#""\uzzzz""#,
            "\"raw \u{1} control\"",
            r#"{"k":"unterminated"#,
        ];
        for c in cases {
            assert_eq!(
                parse(c).is_ok(),
                lazy::validate(c).is_ok(),
                "eager/lazy disagree on {c:?}"
            );
        }
    }

    #[test]
    fn lazy_extract_walks_objects_and_arrays() {
        let doc = r#"{"best":{"lr":0.05,"name":"wArm"},"curve":[1.5,2.5,3.5],"n":3}"#;
        assert_eq!(lazy::extract(doc, "n").unwrap(), Some("3"));
        assert_eq!(lazy::extract(doc, "best.lr").unwrap(), Some("0.05"));
        assert_eq!(lazy::extract(doc, "curve.1").unwrap(), Some("2.5"));
        assert_eq!(
            lazy::extract(doc, "best.name").unwrap(),
            Some(r#""wArm""#)
        );
        // whole-subtree extraction returns the raw slice
        let best = lazy::extract(doc, "best").unwrap().unwrap();
        assert_eq!(parse(best).unwrap(), *parse(doc).unwrap().req("best"));
        // misses
        assert_eq!(lazy::extract(doc, "missing").unwrap(), None);
        assert_eq!(lazy::extract(doc, "curve.9").unwrap(), None);
        assert_eq!(lazy::extract(doc, "curve.lr").unwrap(), None);
        assert_eq!(lazy::extract(doc, "n.deeper").unwrap(), None);
        // malformed path / malformed doc
        assert!(lazy::extract(doc, "best..lr").is_err());
        assert!(lazy::extract("{\"a\":", "a").is_err());
    }

    #[test]
    fn lazy_extract_matches_escaped_keys() {
        let doc = r#"{"abc": 7, "tab\tkey": 8}"#;
        assert_eq!(lazy::extract(doc, "abc").unwrap(), Some("7"));
        assert_eq!(lazy::extract(doc, "tab\tkey").unwrap(), Some("8"));
    }
}
