//! Deterministic byte-mutation fuzzing, in pure `std`.
//!
//! `cargo fuzz` needs nightly + libFuzzer; this repo's vendored toolchain
//! has neither, so the fuzz layer is an ordinary test harness instead: a
//! seed corpus on disk (`fuzz/corpus/<target>/`), a xorshift-driven
//! mutator, and a runner that feeds every seed plus `iters` mutations of
//! them through a target under `catch_unwind`.  The contract fuzzing
//! enforces is narrow and absolute: **parsers never panic** — they may
//! reject, they may error, they must not unwind.
//!
//! Determinism: the mutation stream is a pure function of `(seed, iters)`
//! and the corpus bytes, so a CI failure replays locally with the same
//! `FUZZ_ITERS`/seed and the reported iteration index pins the offending
//! input exactly.

use std::path::Path;

use crate::init::rng::Rng;

/// Mutated inputs never grow beyond this (keeps a splice-happy run from
/// allocating without bound).
const MAX_LEN: usize = 64 * 1024;

/// Bytes that disproportionately reach parser edge cases: framing
/// delimiters, string machinery, and the extremes.
const INTERESTING: &[u8] = &[0x00, 0xff, b'\r', b'\n', b'"', b'\\', b' ', b':'];

/// A seed corpus: the files of one `fuzz/corpus/<target>/` directory,
/// sorted by file name so the mutation stream is stable across machines.
pub struct Corpus {
    pub inputs: Vec<Vec<u8>>,
}

impl Corpus {
    /// Load every regular file under `dir`.  An empty (or missing) corpus
    /// is an error — it would silently fuzz nothing.
    pub fn load(dir: &Path) -> Result<Corpus, String> {
        let rd = std::fs::read_dir(dir)
            .map_err(|e| format!("fuzz corpus {}: {e}", dir.display()))?;
        let mut names: Vec<std::path::PathBuf> = rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.is_file())
            .collect();
        names.sort();
        let mut inputs = Vec::with_capacity(names.len());
        for p in &names {
            inputs.push(std::fs::read(p).map_err(|e| format!("{}: {e}", p.display()))?);
        }
        if inputs.is_empty() {
            return Err(format!("fuzz corpus {} is empty", dir.display()));
        }
        Ok(Corpus { inputs })
    }
}

/// One mutated input: `base` transformed by 1–4 random byte-level ops
/// (bit flip, byte overwrite, truncate, span delete, corpus splice,
/// interesting-byte insert), capped at [`MAX_LEN`].
pub fn mutate(rng: &mut Rng, base: &[u8], corpus: &Corpus) -> Vec<u8> {
    let mut out = base.to_vec();
    let ops = 1 + rng.below(4);
    for _ in 0..ops {
        match rng.below(6) {
            0 => {
                // bit flip
                if !out.is_empty() {
                    let i = rng.below(out.len());
                    out[i] ^= 1 << rng.below(8);
                }
            }
            1 => {
                // random byte overwrite
                if !out.is_empty() {
                    let i = rng.below(out.len());
                    out[i] = (rng.next_u64() & 0xff) as u8;
                }
            }
            2 => {
                // truncate to a random prefix (exercises EOF-mid-token)
                if !out.is_empty() {
                    out.truncate(rng.below(out.len()));
                }
            }
            3 => {
                // delete an interior span
                if out.len() >= 2 {
                    let a = rng.below(out.len());
                    let b = (a + 1 + rng.below(16)).min(out.len());
                    out.drain(a..b);
                }
            }
            4 => {
                // splice a chunk of another corpus entry in
                let donor = &corpus.inputs[rng.below(corpus.inputs.len())];
                if !donor.is_empty() {
                    let a = rng.below(donor.len());
                    let b = (a + 1 + rng.below(64)).min(donor.len());
                    let at = rng.below(out.len() + 1);
                    let chunk: Vec<u8> = donor[a..b].to_vec();
                    out.splice(at..at, chunk);
                }
            }
            _ => {
                // insert an interesting byte
                let at = rng.below(out.len() + 1);
                out.insert(at, INTERESTING[rng.below(INTERESTING.len())]);
            }
        }
        if out.len() > MAX_LEN {
            out.truncate(MAX_LEN);
        }
    }
    out
}

/// Run `f` over every raw corpus seed, then over `iters` mutations.  Each
/// call runs under `catch_unwind`; the first panic aborts the run with the
/// target name, iteration index, and an input preview — enough to replay.
pub fn run(
    name: &str,
    corpus: &Corpus,
    seed: u64,
    iters: usize,
    f: impl Fn(&[u8]),
) -> Result<(), String> {
    let check = |tag: &str, input: &[u8]| -> Result<(), String> {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(input)));
        if r.is_err() {
            let preview: String = input
                .iter()
                .take(120)
                .map(|&b| {
                    if (0x20..0x7f).contains(&b) {
                        (b as char).to_string()
                    } else {
                        format!("\\x{b:02x}")
                    }
                })
                .collect();
            return Err(format!(
                "fuzz target {name} panicked on {tag} ({} bytes): {preview}",
                input.len()
            ));
        }
        Ok(())
    };
    for (i, input) in corpus.inputs.iter().enumerate() {
        check(&format!("seed #{i}"), input)?;
    }
    let mut rng = Rng::new(seed ^ 0xF0_5E_ED);
    for i in 0..iters {
        let base = &corpus.inputs[rng.below(corpus.inputs.len())];
        let input = mutate(&mut rng, base, corpus);
        check(&format!("mutation #{i}"), &input)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_corpus() -> Corpus {
        Corpus { inputs: vec![b"GET / HTTP/1.1\r\n\r\n".to_vec(), b"{\"a\":1}".to_vec()] }
    }

    #[test]
    fn mutation_stream_is_deterministic() {
        let c = tiny_corpus();
        let gen = |seed| {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| mutate(&mut rng, &c.inputs[0], &c)).collect::<Vec<_>>()
        };
        assert_eq!(gen(7), gen(7), "same seed must replay the same inputs");
        assert_ne!(gen(7), gen(8), "different seeds must diverge");
    }

    #[test]
    fn mutated_inputs_stay_bounded() {
        let c = Corpus { inputs: vec![vec![b'x'; MAX_LEN]] };
        let mut rng = Rng::new(1);
        for _ in 0..64 {
            assert!(mutate(&mut rng, &c.inputs[0], &c).len() <= MAX_LEN);
        }
    }

    #[test]
    fn run_reports_a_panicking_target() {
        let c = tiny_corpus();
        let err = run("boom", &c, 1, 0, |b| {
            if b.first() == Some(&b'G') {
                panic!("intentional");
            }
        })
        .unwrap_err();
        assert!(err.contains("boom"), "{err}");
        assert!(err.contains("seed #0"), "{err}");
        assert!(run("ok", &c, 1, 50, |_| {}).is_ok());
    }

    #[test]
    fn empty_corpus_is_an_error() {
        let dir = std::env::temp_dir().join(format!("fuzz-empty-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Corpus::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
