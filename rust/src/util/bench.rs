//! Micro-benchmark harness (criterion is not vendored).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! adaptive iteration count targeting a wall-clock budget, and
//! median/mean/stddev reporting.  Used both by the perf pass
//! (EXPERIMENTS.md §Perf) and the per-table end-to-end benches.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  min {:>12}  ±{:.1}%",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            100.0 * self.std_ns / self.mean_ns.max(1.0),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark `f`, spending roughly `budget` wall-clock (after one warmup
/// call).  `f` should perform one logical operation per call.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let target_iters = (budget.as_nanos() / once.as_nanos()).clamp(3, 10_000) as usize;
    let mut samples = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    // total_cmp: timing samples are always finite, but a sort comparator
    // must never be able to panic (the partial_cmp().unwrap() bug class)
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let median = samples[n / 2];
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: median,
        std_ns: var.sqrt(),
        min_ns: samples[0],
    }
}

/// Convenience: bench and print.
pub fn bench_print<F: FnMut()>(name: &str, budget: Duration, f: F) -> BenchStats {
    let s = bench(name, budget, f);
    // mutlint: allow(bus-only-output, "the bench harness's report lines are its stdout contract; benches run outside the daemon")
    println!("{}", s.report());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_duration() {
        let s = bench("sleep", Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(s.median_ns > 1.5e6 && s.median_ns < 30e6, "{}", s.median_ns);
        assert!(s.iters >= 3);
    }

    #[test]
    fn format_ns() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1.5e3), "1.500 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
