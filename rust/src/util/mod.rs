//! Hand-rolled substrates (the vendored crate set has no serde / clap /
//! criterion / rayon): JSON codec, CLI parsing, text tables, a micro
//! benchmark harness, and a worker pool.

pub mod bench;
pub mod cli;
pub mod fsio;
pub mod fuzz;
pub mod json;
pub mod pool;
pub mod prop;
pub mod table;
