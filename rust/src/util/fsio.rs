//! Crash-consistent small-file publishing, shared by the reporter and
//! the serve daemon's registry.
//!
//! Same rule `ckpt/format.rs` enforces for checkpoints: serialize fully,
//! write to a hidden sibling `.<name>.tmp`, fsync, then rename over the
//! final path.  A reader (or a daemon restarted after SIGKILL) therefore
//! sees either the old contents or the new contents — never a torn file.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Atomically publish `bytes` at `path` (tmp-file-then-rename + fsync).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let name = path
        .file_name()
        .with_context(|| format!("write_atomic needs a file path, got {}", path.display()))?
        .to_string_lossy()
        .into_owned();
    let tmp = path.with_file_name(format!(".{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publishes_and_overwrites() {
        let dir = std::env::temp_dir().join("mutransfer_fsio_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("out.json");
        write_atomic(&p, b"{\"v\":1}").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"v\":1}");
        write_atomic(&p, b"{\"v\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"v\":2}");
        // no tmp residue after publish
        assert!(!dir.join(".out.json.tmp").exists());
    }

    #[test]
    fn rejects_pathless_target() {
        assert!(write_atomic(Path::new("/"), b"x").is_err());
    }
}
