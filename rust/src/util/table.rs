//! Fixed-width text tables — the experiment harness prints paper-style
//! rows with these (and CSV for the figure series).

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("=== {} ===\n", self.title));
        }
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                if i > 0 {
                    out.push_str("  ");
                }
                let c = &cells[i];
                out.push_str(c);
                out.push_str(&" ".repeat(widths[i] - c.len()));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// CSV rendering (for figure series that get plotted elsewhere).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a loss-like value, with the paper's "diverged" marker for NaN.
pub fn fmt_loss(x: f64) -> String {
    if x.is_nan() {
        "diverged".to_string()
    } else {
        format!("{x:.4}")
    }
}

pub fn fmt_sci(x: f64) -> String {
    format!("{x:.3e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "loss"]);
        t.row(vec!["x".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("=== demo ==="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns aligned: "loss" starts at same offset in all rows
        let off = lines[1].find("loss").unwrap();
        assert_eq!(&lines[3][off..off + 3], "1.5");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "2".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c\n"));
        assert!(csv.contains("\"x\"\"y\",2"));
    }

    #[test]
    fn fmt_loss_diverged() {
        assert_eq!(fmt_loss(f64::NAN), "diverged");
        assert_eq!(fmt_loss(1.23456), "1.2346");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new("", &["a", "b"]).row(vec!["only-one".into()]);
    }
}
