//! Tiny CLI argument parser (no `clap` in the vendored crate set).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` shapes used by `mutransfer` and the examples.  Unknown flags
//! are an error so typos fail fast instead of silently using defaults.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<Vec<String>>,
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(stripped.to_string(), v);
                } else {
                    a.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn note(&self, key: &str) {
        self.known.borrow_mut().push(key.to_string());
        if self.flags.contains_key(key) {
            self.seen.borrow_mut().push(key.to_string());
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.note(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }

    /// The shared `--workers N` flag (sweep/tuner/transfer parallelism).
    /// Precedence: explicit flag > `MUTRANSFER_WORKERS` env > `default`;
    /// always ≥ 1.
    pub fn workers_or(&self, default: usize) -> usize {
        self.usize_or(
            "workers",
            crate::util::pool::env_workers().unwrap_or(default),
        )
        .max(1)
    }

    /// Call after all `get`s: errors on flags that were provided but never
    /// consumed (catches typos like `--step` for `--steps`).
    pub fn reject_unknown(&self) -> Result<(), String> {
        let known = self.known.borrow();
        let unknown: Vec<_> = self
            .flags
            .keys()
            .filter(|k| !known.iter().any(|s| s == *k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown flag(s): {}; known: {}",
                unknown.join(", "),
                known.join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("exp fig1 --steps 50 --preset=ci --verbose");
        assert_eq!(a.positional, vec!["exp", "fig1"]);
        assert_eq!(a.usize_or("steps", 0), 50);
        assert_eq!(a.str_or("preset", "paper"), "ci");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.usize_or("steps", 100), 100);
        assert_eq!(a.f64_or("lr", 1e-3), 1e-3);
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("x --bogus 3");
        let _ = a.get("real");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn negative_number_value() {
        let a = parse("--z -1.5");
        // "-1.5" doesn't start with "--" so it is consumed as the value
        assert_eq!(a.f64_or("z", 0.0), -1.5);
    }
}
