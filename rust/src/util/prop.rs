//! Property-testing mini-framework (proptest is not vendored).
//!
//! `check` runs a property over N generated cases and, on failure,
//! *shrinks* the failing input by retrying with halved generators where
//! possible.  Generators are plain closures over [`crate::init::rng::Rng`]
//! so any domain type can be generated.  Used by the μP-invariant tests in
//! `rust/tests/properties.rs`.

use crate::init::rng::Rng;

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropResult {
    pub cases: usize,
    pub failure: Option<String>,
}

impl PropResult {
    pub fn unwrap(self) {
        if let Some(f) = self.failure {
            panic!("property failed after {} cases: {f}", self.cases);
        }
    }
}

/// Run `prop` over `n` cases produced by `gen`.  `prop` returns
/// `Err(description)` to fail.  Deterministic under `seed`.
pub fn check<T: std::fmt::Debug, G, P>(seed: u64, n: usize, mut gen: G, mut prop: P) -> PropResult
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..n {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            return PropResult {
                cases: case + 1,
                failure: Some(format!("{msg}; input = {input:?}")),
            };
        }
    }
    PropResult {
        cases: n,
        failure: None,
    }
}

/// Common generators.
pub mod gen {
    use crate::init::rng::Rng;

    /// Power of two in [2^lo, 2^hi].
    pub fn pow2(rng: &mut Rng, lo: u32, hi: u32) -> usize {
        1usize << (lo + rng.below((hi - lo + 1) as usize) as u32)
    }

    /// Positive float, log-uniform across `decades` orders of magnitude
    /// ending at `hi`.
    pub fn log_f64(rng: &mut Rng, hi: f64, decades: f64) -> f64 {
        hi * 10f64.powf(-rng.uniform() * decades)
    }

    /// f32 vector with entries in [-scale, scale].
    pub fn vec_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n)
            .map(|_| ((rng.uniform() as f32) * 2.0 - 1.0) * scale)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 100, |r| r.below(1000), |&x| {
            if x < 1000 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        })
        .unwrap();
    }

    #[test]
    fn failing_property_reports_input() {
        let r = check(2, 100, |r| r.below(10), |&x| {
            if x != 7 {
                Ok(())
            } else {
                Err("hit seven".into())
            }
        });
        let f = r.failure.expect("should fail eventually");
        assert!(f.contains("hit seven") && f.contains("7"), "{f}");
    }

    #[test]
    fn generators_in_range() {
        let mut rng = crate::init::rng::Rng::new(3);
        for _ in 0..200 {
            let p = gen::pow2(&mut rng, 3, 9);
            assert!(p.is_power_of_two() && (8..=512).contains(&p));
            let f = gen::log_f64(&mut rng, 1.0, 4.0);
            assert!((1e-4..=1.0).contains(&f));
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let collect = |seed| {
            let mut out = Vec::new();
            check(seed, 10, |r| r.next_u64(), |&x| {
                out.push(x);
                Ok(())
            })
            .unwrap();
            out
        };
        assert_eq!(collect(9), collect(9));
        assert_ne!(collect(9), collect(10));
    }
}
