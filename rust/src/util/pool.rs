//! Minimal worker pool over `std::thread` (no rayon/tokio vendored).
//!
//! The sweep scheduler fans trials out through [`run_indexed`] (see
//! `Sweep::run`), matching the paper's benefit #4 (small-model tuning
//! parallelizes trivially across a cluster).  The scheduler/journal logic
//! is written — and tested — for arbitrary worker counts.
//!
//! Panic policy: a panicking job must surface to the caller as *its own*
//! panic payload, re-raised after all threads join — never as a derived
//! panic from pool bookkeeping (the old code's `expect("worker died")`
//! masked the payload).  Jobs run with the queue lock released, so a job
//! panic cannot poison the mutex and sibling workers keep draining the
//! queue; should the lock ever be found poisoned anyway (a panic inside
//! `pop` itself), the guard is recovered rather than cascaded, since the
//! `Vec` underneath is still consistent.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

use crate::obs::metrics;

/// Test-only schedule perturbation: seeded yield/sleep injection at the
/// interleaving-sensitive points of [`FairBudget`] (acquire entry, grant,
/// permit/lease release).  Production code pays one relaxed atomic load
/// per point; the `sched_perturb` harness enables it per-thread with a
/// deterministic seed and replays ≥1k distinct schedules.
///
/// Points are only placed where **no lock is held**, so an injected sleep
/// can reorder threads but can never extend a critical section.
pub mod perturb {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Fast-path gate: stays false unless some thread ever opted in, so
    /// the hook is a single relaxed load in production.
    static ANY_ENABLED: AtomicBool = AtomicBool::new(false);

    thread_local! {
        /// xorshift64* state; 0 = this thread not perturbed.
        static STATE: Cell<u64> = const { Cell::new(0) };
    }

    /// Enable perturbation on the calling thread with a deterministic
    /// seed (0 is mapped to a fixed nonzero state).
    pub fn enable_thread(seed: u64) {
        STATE.with(|s| s.set(if seed == 0 { 0x9E3779B97F4A7C15 } else { seed }));
        ANY_ENABLED.store(true, Ordering::Relaxed);
    }

    /// Stop perturbing the calling thread.
    pub fn disable_thread() {
        STATE.with(|s| s.set(0));
    }

    /// A perturbation point: depending on the thread's seeded RNG, do
    /// nothing, yield, or sleep up to ~200µs.  `_tag` names the site for
    /// debugging; decisions depend only on the seed and call order.
    pub fn point(_tag: &str) {
        if !ANY_ENABLED.load(Ordering::Relaxed) {
            return;
        }
        let draw = STATE.with(|s| {
            let mut x = s.get();
            if x == 0 {
                return None;
            }
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            s.set(x);
            Some(x.wrapping_mul(0x2545_F491_4F6C_DD1D))
        });
        if let Some(r) = draw {
            match r % 8 {
                0..=3 => {}
                4 | 5 => std::thread::yield_now(),
                _ => std::thread::sleep(std::time::Duration::from_micros(r % 200)),
            }
        }
    }
}

/// Run `jobs` across `workers` threads, preserving result order.
///
/// `f` must be `Send + Sync`; jobs are pulled from a shared queue so the
/// pool load-balances uneven job durations.  If a job panics, the
/// remaining jobs still run and the original panic payload is re-raised
/// on the calling thread once every worker has finished.
pub fn run_indexed<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send + 'static,
    R: Send + 'static,
    F: Fn(usize, J) -> R + Send + Sync + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        // fast path, avoids thread overhead on the 1-core testbed
        return jobs.into_iter().enumerate().map(|(i, j)| f(i, j)).collect();
    }
    let queue: Arc<Mutex<Vec<(usize, J)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = queue.clone();
        let f = f.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            // Jobs run with the lock released, so job panics never poison
            // this mutex; recovering a poisoned guard (a panic inside
            // `pop` itself) is defensive — the Vec is still consistent,
            // and cascading an unrelated lock panic would mask the
            // original payload.
            let job = queue.lock().unwrap_or_else(|e| e.into_inner()).pop();
            match job {
                Some((i, j)) => {
                    let r = f(i, j);
                    if tx.send((i, r)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    let mut panic_payload = None;
    for h in handles {
        if let Err(p) = h.join() {
            // keep only the first payload; later ones are either the same
            // logical failure or casualties of it
            panic_payload.get_or_insert(p);
        }
    }
    if let Some(p) = panic_payload {
        std::panic::resume_unwind(p);
    }
    out.into_iter()
        .map(|r| r.expect("pool invariant: no panic implies every job completed"))
        .collect()
}

// ---------------------------------------------------------------------------
// fair-share worker budget
// ---------------------------------------------------------------------------

/// A shared pool of worker slots divided *max-min fairly* between
/// concurrent holders (the serve daemon's executor slots: each running
/// job leases the budget and acquires one permit per executing trial).
///
/// Fairness rule: a holder may take a slot when the pool has capacity AND
/// either (a) the holder is below its fair share `ceil(total / holders)`,
/// or (b) no *other* holder is currently waiting — so a lone job still
/// uses the whole budget (work-conserving), but the moment a second job
/// arrives, the first stops taking slots beyond its share and the
/// freed-up slots flow to the newcomer.  One giant sweep therefore cannot
/// starve a small one; it merely keeps whatever share is fair.
///
/// Permits and leases are RAII: dropping a [`BudgetPermit`] frees its
/// slot, dropping a [`BudgetLease`] deregisters the holder (its live
/// permits remain counted against the pool until they drop too).
pub struct FairBudget {
    total: usize,
    inner: Mutex<BudgetState>,
    freed: Condvar,
}

#[derive(Default)]
struct BudgetState {
    used_total: usize,
    next_id: u64,
    /// holder id → (slots in use, acquire calls currently blocked)
    holders: BTreeMap<u64, (usize, usize)>,
}

impl FairBudget {
    pub fn new(total: usize) -> Arc<FairBudget> {
        Arc::new(FairBudget {
            total: total.max(1),
            inner: Mutex::new(BudgetState::default()),
            freed: Condvar::new(),
        })
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Register a holder (one per concurrently-running job).
    pub fn lease(self: &Arc<Self>) -> BudgetLease {
        let mut st = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let id = st.next_id;
        st.next_id += 1;
        st.holders.insert(id, (0, 0));
        BudgetLease { budget: self.clone(), id }
    }

    /// Slots currently in use across all holders (diagnostic: the
    /// perturbation harness asserts this returns to 0 after every
    /// schedule — a nonzero value after all permits dropped is a lost
    /// permit).
    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).used_total
    }

    /// `acquire` calls currently registered as waiting, across all
    /// holders (diagnostic: stale waiting counts — e.g. from an acquire
    /// unwound mid-wait — would permanently cap peers at their fair
    /// share; see [`WaitGuard`]).
    pub fn waiting(&self) -> usize {
        let st = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        st.holders.values().map(|(_, w)| *w).sum()
    }
}

/// Unwind-safety for the waiting count: [`BudgetLease::acquire`] registers
/// itself in the holder's waiting counter before blocking, and that
/// counter feeds every *other* holder's `others_waiting` fairness check.
/// If the acquiring thread unwinds mid-wait (a panic while blocked — e.g.
/// injected by the perturbation harness, or a poison panic surfacing
/// through the condvar), a bare `h.1 += 1` would leak: peers would see a
/// phantom waiter forever and stay capped at fair share with free slots
/// on the table.  The guard is declared *before* the `MutexGuard`, so on
/// unwind the lock is released first (locals drop in reverse declaration
/// order) and the guard can safely re-lock — recovering a poisoned lock —
/// to decrement the count and wake peers.
struct WaitGuard<'a> {
    budget: &'a FairBudget,
    id: u64,
    armed: bool,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut st = self.budget.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(h) = st.holders.get_mut(&self.id) {
            h.1 = h.1.saturating_sub(1);
        }
        drop(st);
        metrics::BUDGET_WAITING.dec();
        self.budget.freed.notify_all();
    }
}

/// One holder's handle on a [`FairBudget`].
pub struct BudgetLease {
    budget: Arc<FairBudget>,
    id: u64,
}

impl BudgetLease {
    /// Block until this holder is entitled to one more worker slot.
    pub fn acquire(&self) -> BudgetPermit {
        let b = &self.budget;
        perturb::point("acquire-enter");
        // Declaration order matters: `wait` before `st`, so on unwind the
        // MutexGuard is released (poisoning the lock) before WaitGuard
        // re-locks (recovering it) to undo the waiting-count increment.
        let mut wait = WaitGuard { budget: b, id: self.id, armed: false };
        let mut st = b.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(h) = st.holders.get_mut(&self.id) {
            h.1 += 1;
            wait.armed = true;
            metrics::BUDGET_WAITING.inc();
        }
        loop {
            let holders = st.holders.len().max(1);
            let share = b.total.div_ceil(holders);
            let mine = st.holders.get(&self.id).map(|h| h.0).unwrap_or(0);
            let others_waiting = st
                .holders
                .iter()
                .any(|(id, (_, w))| *id != self.id && *w > 0);
            if st.used_total < b.total && (mine < share || !others_waiting) {
                st.used_total += 1;
                metrics::BUDGET_OUTSTANDING.inc();
                if let Some(h) = st.holders.get_mut(&self.id) {
                    h.0 += 1;
                    h.1 = h.1.saturating_sub(1);
                }
                if wait.armed {
                    metrics::BUDGET_WAITING.dec();
                }
                wait.armed = false;
                drop(st);
                perturb::point("acquire-granted");
                return BudgetPermit { budget: b.clone(), holder: self.id };
            }
            st = b
                .freed
                .wait_timeout(st, std::time::Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner())
                .0;
        }
    }

    /// Slots this holder currently has in use (test/diagnostic hook).
    pub fn in_use(&self) -> usize {
        let st = self.budget.inner.lock().unwrap_or_else(|e| e.into_inner());
        st.holders.get(&self.id).map(|h| h.0).unwrap_or(0)
    }
}

impl Drop for BudgetLease {
    fn drop(&mut self) {
        perturb::point("lease-drop");
        let mut st = self.budget.inner.lock().unwrap_or_else(|e| e.into_inner());
        // live permits keep their used_total accounting; only the holder's
        // registration (and thus the fair-share denominator) goes away
        st.holders.remove(&self.id);
        drop(st);
        self.budget.freed.notify_all();
    }
}

/// One worker slot; freed on drop.
pub struct BudgetPermit {
    budget: Arc<FairBudget>,
    holder: u64,
}

impl Drop for BudgetPermit {
    fn drop(&mut self) {
        perturb::point("permit-drop");
        let mut st = self.budget.inner.lock().unwrap_or_else(|e| e.into_inner());
        st.used_total = st.used_total.saturating_sub(1);
        metrics::BUDGET_OUTSTANDING.dec();
        if let Some(h) = st.holders.get_mut(&self.holder) {
            h.0 = h.0.saturating_sub(1);
        }
        drop(st);
        self.budget.freed.notify_all();
    }
}

/// Suggested worker count: leave the runtime's XLA execution the whole
/// machine unless there is headroom.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get() / 2).max(1))
        .unwrap_or(1)
}

/// Worker count from the `MUTRANSFER_WORKERS` env var (CI sets it to 4 so
/// the parallel scheduler path is exercised on every push); `None` when
/// unset or unparseable.
pub fn env_workers() -> Option<usize> {
    std::env::var("MUTRANSFER_WORKERS")
        .ok()?
        .parse()
        .ok()
        .filter(|&n| n >= 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_single_worker() {
        let r = run_indexed((0..10).collect(), 1, |_, j: i32| j * 2);
        assert_eq!(r, (0..10).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn preserves_order_multi_worker() {
        let r = run_indexed((0..50).collect(), 4, |_, j: i32| {
            // jitter durations to force out-of-order completion
            std::thread::sleep(std::time::Duration::from_micros((j % 7) as u64 * 50));
            j * j
        });
        assert_eq!(r, (0..50).map(|j| j * j).collect::<Vec<_>>());
    }

    #[test]
    fn empty_jobs() {
        let r: Vec<i32> = run_indexed(Vec::<i32>::new(), 4, |_, j| j);
        assert!(r.is_empty());
    }

    #[test]
    fn index_passed_through() {
        let r = run_indexed(vec!['a', 'b', 'c'], 2, |i, c| format!("{i}{c}"));
        assert_eq!(r, vec!["0a", "1b", "2c"]);
    }

    #[test]
    fn panicking_job_propagates_its_own_payload() {
        // Regression: a worker panic used to surface to the caller as the
        // pool's own `expect("worker died")` panic, masking the job's
        // payload; now the original payload is re-raised after join.
        let payload = std::panic::catch_unwind(|| {
            run_indexed((0..8).collect(), 4, |_, j: i32| {
                if j == 3 {
                    panic!("boom {j}");
                }
                j
            })
        })
        .expect_err("a panicking job must panic the caller");
        let msg = payload
            .downcast_ref::<String>()
            .expect("payload should be the job's own format string");
        assert_eq!(msg, "boom 3");
    }

    #[test]
    fn siblings_finish_despite_a_panicking_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let seen = done.clone();
        let r = std::panic::catch_unwind(|| {
            run_indexed((0..16).collect(), 4, move |_, j: i32| {
                if j == 0 {
                    panic!("first job dies");
                }
                seen.fetch_add(1, Ordering::SeqCst);
                j
            })
        });
        assert!(r.is_err());
        // the other 15 jobs all ran: one worker dying never blocks the rest
        assert_eq!(done.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn lone_holder_uses_whole_budget() {
        let b = FairBudget::new(4);
        let lease = b.lease();
        let permits: Vec<_> = (0..4).map(|_| lease.acquire()).collect();
        assert_eq!(lease.in_use(), 4);
        drop(permits);
        assert_eq!(lease.in_use(), 0);
    }

    #[test]
    fn two_holders_converge_to_fair_split() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = FairBudget::new(4);
        let a = Arc::new(b.lease());
        let c = Arc::new(b.lease());
        // each holder runs 20 short "trials", each holding a permit briefly;
        // record the peak concurrent usage either holder reaches while the
        // other is actively contending
        let peak_a = Arc::new(AtomicUsize::new(0));
        let peak_c = Arc::new(AtomicUsize::new(0));
        let spawn = |lease: Arc<BudgetLease>, peak: Arc<AtomicUsize>| {
            std::thread::spawn(move || {
                let mut handles = Vec::new();
                for _ in 0..4 {
                    let lease = lease.clone();
                    let peak = peak.clone();
                    handles.push(std::thread::spawn(move || {
                        for _ in 0..5 {
                            let _p = lease.acquire();
                            peak.fetch_max(lease.in_use(), Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                    }));
                }
                for h in handles {
                    h.join().unwrap();
                }
            })
        };
        let ta = spawn(a.clone(), peak_a.clone());
        let tc = spawn(c.clone(), peak_c.clone());
        ta.join().unwrap();
        tc.join().unwrap();
        // fair share with 2 holders of a 4-slot budget is 2 each; the cap is
        // only exceeded when the other holder has nothing waiting, and with 4
        // eager threads per holder that window is what the rule permits —
        // both must have made progress and neither may monopolize all slots
        // while the other waits (checked indirectly: both finished).
        assert!(peak_a.load(Ordering::SeqCst) >= 1);
        assert!(peak_c.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn contended_holder_capped_at_fair_share() {
        let b = FairBudget::new(4);
        let big = b.lease();
        let small = Arc::new(b.lease());
        // "big" grabs its fair share (2 of 4)…
        let p1 = big.acquire();
        let p2 = big.acquire();
        // …then "small" starts waiting on another thread
        let small2 = small.clone();
        let waiter = std::thread::spawn(move || {
            let _p = small2.acquire();
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
        // give the waiter time to register; the pool still has 2 free slots,
        // but big is at its share and someone else is (or will be) waiting,
        // so big's next acquire must not race past the newcomer indefinitely
        std::thread::sleep(std::time::Duration::from_millis(20));
        let t0 = std::time::Instant::now();
        let p3 = big.acquire(); // legal once small is no longer waiting
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        drop((p1, p2, p3));
        waiter.join().unwrap();
    }

    #[test]
    fn panicking_holder_does_not_deadlock_peers() {
        // ISSUE-8 audit: a holder that panics mid-lease (permits live)
        // must release everything through RAII unwinding — its slots flow
        // back and a peer's acquire proceeds instead of deadlocking.
        let b = FairBudget::new(2);
        let peer = b.lease();
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let lease = b2.lease();
            let _p1 = lease.acquire();
            let _p2 = lease.acquire();
            panic!("holder dies mid-lease");
        });
        assert!(t.join().is_err(), "holder thread must have panicked");
        // both slots must be reacquirable, promptly
        let p1 = peer.acquire();
        let p2 = peer.acquire();
        drop((p1, p2));
        drop(peer);
        assert_eq!(b.outstanding(), 0, "panicked holder leaked a permit");
        assert_eq!(b.waiting(), 0, "panicked holder leaked a waiting count");
    }

    #[test]
    fn peer_blocked_in_acquire_survives_holder_panic() {
        // Harder variant: the peer is already blocked inside acquire()
        // when the lone-slot holder panics.  The unwind poisons nothing
        // the peer can't recover (poisoned-lock recovery is
        // unwrap_or_else(into_inner) throughout), and the freed slot must
        // reach the sleeper.
        let b = FairBudget::new(1);
        let peer = Arc::new(b.lease());
        let b2 = b.clone();
        let (took, took_rx) = mpsc::channel();
        let holder = std::thread::spawn(move || {
            let lease = b2.lease();
            let _p = lease.acquire();
            took.send(()).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(30));
            panic!("holder dies while a peer waits");
        });
        took_rx.recv().unwrap();
        let (done, done_rx) = mpsc::channel();
        let peer2 = peer.clone();
        let waiter = std::thread::spawn(move || {
            let _p = peer2.acquire(); // blocks until the unwind frees the slot
            done.send(()).unwrap();
        });
        assert!(
            done_rx.recv_timeout(std::time::Duration::from_secs(10)).is_ok(),
            "peer deadlocked behind a panicked holder"
        );
        assert!(holder.join().is_err());
        waiter.join().unwrap();
        drop(peer);
        assert_eq!(b.outstanding(), 0);
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn perturb_points_are_deterministic_noops_when_disabled() {
        // disabled: free (single relaxed load), no state change
        perturb::point("off");
        // enabled with a seed: must not panic or hang, and disable stops it
        perturb::enable_thread(42);
        for _ in 0..64 {
            perturb::point("on");
        }
        perturb::disable_thread();
        perturb::point("off-again");
        // seed 0 maps to a fixed nonzero state instead of disabling
        perturb::enable_thread(0);
        perturb::point("zero-seed");
        perturb::disable_thread();
    }

    #[test]
    fn dropping_lease_with_live_permit_does_not_underflow() {
        let b = FairBudget::new(2);
        let lease = b.lease();
        let permit = lease.acquire();
        drop(lease); // holder deregistered while its permit is live
        drop(permit); // must not panic / underflow
        let fresh = b.lease();
        let p1 = fresh.acquire();
        let p2 = fresh.acquire();
        drop((p1, p2));
    }

    #[test]
    fn env_workers_never_returns_zero() {
        // deliberately does not mutate the (process-global) env: the CI
        // matrix sets MUTRANSFER_WORKERS for the whole test binary
        match env_workers() {
            Some(n) => assert!(n >= 1),
            None => {}
        }
    }
}
