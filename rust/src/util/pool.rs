//! Minimal worker pool over `std::thread` (no rayon/tokio vendored).
//!
//! The sweep scheduler uses it to run trials concurrently.  On this 1-core
//! testbed the default is a single worker (XLA already saturates the
//! core), but the scheduler/journal logic is written — and tested — for
//! arbitrary worker counts, matching the paper's benefit #4 (small-model
//! tuning parallelizes trivially across a cluster).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `jobs` across `workers` threads, preserving result order.
///
/// `f` must be `Send + Sync`; jobs are pulled from a shared queue so the
/// pool load-balances uneven job durations.
pub fn run_indexed<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send + 'static,
    R: Send + 'static,
    F: Fn(usize, J) -> R + Send + Sync + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        // fast path, avoids thread overhead on the 1-core testbed
        return jobs.into_iter().enumerate().map(|(i, j)| f(i, j)).collect();
    }
    let queue: Arc<Mutex<Vec<(usize, J)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = queue.clone();
        let f = f.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((i, j)) => {
                    let r = f(i, j);
                    if tx.send((i, r)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    for h in handles {
        let _ = h.join();
    }
    out.into_iter().map(|r| r.expect("worker died")).collect()
}

/// Suggested worker count: leave the runtime's XLA execution the whole
/// machine unless there is headroom.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get() / 2).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_single_worker() {
        let r = run_indexed((0..10).collect(), 1, |_, j: i32| j * 2);
        assert_eq!(r, (0..10).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn preserves_order_multi_worker() {
        let r = run_indexed((0..50).collect(), 4, |_, j: i32| {
            // jitter durations to force out-of-order completion
            std::thread::sleep(std::time::Duration::from_micros((j % 7) as u64 * 50));
            j * j
        });
        assert_eq!(r, (0..50).map(|j| j * j).collect::<Vec<_>>());
    }

    #[test]
    fn empty_jobs() {
        let r: Vec<i32> = run_indexed(Vec::<i32>::new(), 4, |_, j| j);
        assert!(r.is_empty());
    }

    #[test]
    fn index_passed_through() {
        let r = run_indexed(vec!['a', 'b', 'c'], 2, |i, c| format!("{i}{c}"));
        assert_eq!(r, vec!["0a", "1b", "2c"]);
    }
}
