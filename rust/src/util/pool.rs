//! Minimal worker pool over `std::thread` (no rayon/tokio vendored).
//!
//! The sweep scheduler fans trials out through [`run_indexed`] (see
//! `Sweep::run`), matching the paper's benefit #4 (small-model tuning
//! parallelizes trivially across a cluster).  The scheduler/journal logic
//! is written — and tested — for arbitrary worker counts.
//!
//! Panic policy: a panicking job must surface to the caller as *its own*
//! panic payload, re-raised after all threads join — never as a derived
//! panic from pool bookkeeping (the old code's `expect("worker died")`
//! masked the payload).  Jobs run with the queue lock released, so a job
//! panic cannot poison the mutex and sibling workers keep draining the
//! queue; should the lock ever be found poisoned anyway (a panic inside
//! `pop` itself), the guard is recovered rather than cascaded, since the
//! `Vec` underneath is still consistent.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `jobs` across `workers` threads, preserving result order.
///
/// `f` must be `Send + Sync`; jobs are pulled from a shared queue so the
/// pool load-balances uneven job durations.  If a job panics, the
/// remaining jobs still run and the original panic payload is re-raised
/// on the calling thread once every worker has finished.
pub fn run_indexed<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send + 'static,
    R: Send + 'static,
    F: Fn(usize, J) -> R + Send + Sync + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        // fast path, avoids thread overhead on the 1-core testbed
        return jobs.into_iter().enumerate().map(|(i, j)| f(i, j)).collect();
    }
    let queue: Arc<Mutex<Vec<(usize, J)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = queue.clone();
        let f = f.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            // Jobs run with the lock released, so job panics never poison
            // this mutex; recovering a poisoned guard (a panic inside
            // `pop` itself) is defensive — the Vec is still consistent,
            // and cascading an unrelated lock panic would mask the
            // original payload.
            let job = queue.lock().unwrap_or_else(|e| e.into_inner()).pop();
            match job {
                Some((i, j)) => {
                    let r = f(i, j);
                    if tx.send((i, r)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    let mut panic_payload = None;
    for h in handles {
        if let Err(p) = h.join() {
            // keep only the first payload; later ones are either the same
            // logical failure or casualties of it
            panic_payload.get_or_insert(p);
        }
    }
    if let Some(p) = panic_payload {
        std::panic::resume_unwind(p);
    }
    out.into_iter()
        .map(|r| r.expect("pool invariant: no panic implies every job completed"))
        .collect()
}

/// Suggested worker count: leave the runtime's XLA execution the whole
/// machine unless there is headroom.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| (n.get() / 2).max(1))
        .unwrap_or(1)
}

/// Worker count from the `MUTRANSFER_WORKERS` env var (CI sets it to 4 so
/// the parallel scheduler path is exercised on every push); `None` when
/// unset or unparseable.
pub fn env_workers() -> Option<usize> {
    std::env::var("MUTRANSFER_WORKERS")
        .ok()?
        .parse()
        .ok()
        .filter(|&n| n >= 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_single_worker() {
        let r = run_indexed((0..10).collect(), 1, |_, j: i32| j * 2);
        assert_eq!(r, (0..10).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn preserves_order_multi_worker() {
        let r = run_indexed((0..50).collect(), 4, |_, j: i32| {
            // jitter durations to force out-of-order completion
            std::thread::sleep(std::time::Duration::from_micros((j % 7) as u64 * 50));
            j * j
        });
        assert_eq!(r, (0..50).map(|j| j * j).collect::<Vec<_>>());
    }

    #[test]
    fn empty_jobs() {
        let r: Vec<i32> = run_indexed(Vec::<i32>::new(), 4, |_, j| j);
        assert!(r.is_empty());
    }

    #[test]
    fn index_passed_through() {
        let r = run_indexed(vec!['a', 'b', 'c'], 2, |i, c| format!("{i}{c}"));
        assert_eq!(r, vec!["0a", "1b", "2c"]);
    }

    #[test]
    fn panicking_job_propagates_its_own_payload() {
        // Regression: a worker panic used to surface to the caller as the
        // pool's own `expect("worker died")` panic, masking the job's
        // payload; now the original payload is re-raised after join.
        let payload = std::panic::catch_unwind(|| {
            run_indexed((0..8).collect(), 4, |_, j: i32| {
                if j == 3 {
                    panic!("boom {j}");
                }
                j
            })
        })
        .expect_err("a panicking job must panic the caller");
        let msg = payload
            .downcast_ref::<String>()
            .expect("payload should be the job's own format string");
        assert_eq!(msg, "boom 3");
    }

    #[test]
    fn siblings_finish_despite_a_panicking_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let seen = done.clone();
        let r = std::panic::catch_unwind(|| {
            run_indexed((0..16).collect(), 4, move |_, j: i32| {
                if j == 0 {
                    panic!("first job dies");
                }
                seen.fetch_add(1, Ordering::SeqCst);
                j
            })
        });
        assert!(r.is_err());
        // the other 15 jobs all ran: one worker dying never blocks the rest
        assert_eq!(done.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn env_workers_never_returns_zero() {
        // deliberately does not mutate the (process-global) env: the CI
        // matrix sets MUTRANSFER_WORKERS for the whole test binary
        match env_workers() {
            Some(n) => assert!(n >= 1),
            None => {}
        }
    }
}
