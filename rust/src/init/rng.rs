//! Deterministic RNG substrate.
//!
//! `SplitMix64` is the cross-language contract with the Python build path
//! (`compile/model.py::splitmix64`) — the golden-value integration tests
//! depend on bit-for-bit agreement.  `Pcg64` (a splitmix-seeded xoshiro256++)
//! drives everything stochastic on the Rust side: initialization, data
//! generation, and HP random search.  Everything is seeded explicitly; no
//! global state, so every trial/run in a sweep is exactly reproducible.

/// The canonical splitmix64 step (public-domain reference constants).
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fold a byte string into a u64 via repeated splitmix64 rounds — the
/// repo's identity hash (checkpoint file names, trajectory fingerprints).
/// Not cryptographic; collision-resistant enough for path/config keys.
#[inline]
pub fn fold64(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h = splitmix64(h ^ b as u64);
    }
    h
}

/// u64 -> f64 uniform in [0, 1) using the top 53 bits (same mapping as the
/// Python side).
#[inline]
pub fn u64_to_unit_f64(z: u64) -> f64 {
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Deterministic tensor fill matching `compile.model.det_fill` exactly:
/// elem\[i\] = (U(splitmix64(seed<<32 + i)) - 0.5) * 2 * scale.
pub fn det_fill(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let base = seed << 32;
    (0..n as u64)
        .map(|i| {
            let u = u64_to_unit_f64(splitmix64(base.wrapping_add(i)));
            ((u - 0.5) * 2.0 * scale as f64) as f32
        })
        .collect()
}

/// Deterministic token fill matching `compile.model.det_tokens`.
pub fn det_tokens(n: usize, vocab: u32, seed: u64) -> Vec<i32> {
    let base = seed << 32;
    (0..n as u64)
        .map(|i| (splitmix64(base.wrapping_add(i)) % vocab as u64) as i32)
        .collect()
}

/// The complete serializable state of an [`Rng`]: the xoshiro256++ word
/// state (which encodes both the seed and the stream position) plus the
/// Box-Muller spare.  `Rng::state()` / `Rng::from_state()` round-trip it
/// exactly, so a data stream interrupted mid-draw provably resumes in the
/// same order — the checkpoint subsystem ([`crate::ckpt`]) persists this
/// for stateful data sources (the built-in sources are (seed, step)-pure
/// and don't need it, but the API is load-bearing for anything that
/// consumes an `Rng` incrementally).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

impl RngState {
    /// Fixed-width encoding for binary checkpoints: the four state words,
    /// a spare-present flag, and the spare's raw f64 bits.
    pub fn to_words(&self) -> [u64; 6] {
        [
            self.s[0],
            self.s[1],
            self.s[2],
            self.s[3],
            self.gauss_spare.is_some() as u64,
            self.gauss_spare.unwrap_or(0.0).to_bits(),
        ]
    }

    pub fn from_words(w: &[u64]) -> Result<RngState, String> {
        if w.len() != 6 {
            return Err(format!("RngState wants 6 words, got {}", w.len()));
        }
        Ok(RngState {
            s: [w[0], w[1], w[2], w[3]],
            gauss_spare: if w[4] != 0 {
                Some(f64::from_bits(w[5]))
            } else {
                None
            },
        })
    }
}

/// xoshiro256++ — fast, high-quality, tiny; seeded via splitmix64 per the
/// reference recommendation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Box-Muller spare
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut s = [0u64; 4];
        let mut x = seed;
        for slot in s.iter_mut() {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            *slot = splitmix64(x);
        }
        Rng {
            s,
            gauss_spare: None,
        }
    }

    /// Capture the full generator state (seed *and* stream position).
    /// `Rng::from_state(&rng.state())` continues the exact same stream —
    /// including a pending Box-Muller spare — so interrupted data
    /// generation resumes bit-for-bit (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState {
            s: self.s,
            gauss_spare: self.gauss_spare,
        }
    }

    /// Rebuild a generator from a captured state.
    pub fn from_state(state: &RngState) -> Rng {
        Rng {
            s: state.s,
            gauss_spare: state.gauss_spare,
        }
    }

    /// Derive an independent stream; used to give each (trial, run, step)
    /// its own reproducible generator.
    pub fn fork(&self, stream: u64) -> Rng {
        let mix = splitmix64(self.s[0] ^ splitmix64(stream.wrapping_mul(0x9E3779B97F4A7C15)));
        Rng::new(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        u64_to_unit_f64(self.next_u64())
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Log-uniform in [lo, hi) (both must be positive) — the standard HP
    /// search distribution (App. F.4 samples LRs from 10^U(-4,-1)).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.range(lo.ln(), hi.ln())).exp()
    }

    /// Integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // rejection-free modulo bias is negligible for our n << 2^64
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (exact, no tables).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// N(0, std^2) f32 vector.
    pub fn gaussian_vec(&mut self, n: usize, std: f64) -> Vec<f32> {
        (0..n).map(|_| (self.gaussian() * std) as f32).collect()
    }

    /// Zipf-distributed index in [0, n) with exponent `s` via inverse-CDF
    /// on a precomputed table would be overkill; this uses rejection-free
    /// cumulative search acceptable for n <= a few hundred (our vocab).
    pub fn zipf(&mut self, n: usize, s: f64, cdf: &[f64]) -> usize {
        debug_assert_eq!(cdf.len(), n);
        let u = self.uniform() * cdf[n - 1];
        match cdf.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(n - 1),
        }
    }
}

/// Precompute an (unnormalized) Zipf CDF for `Rng::zipf`.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut acc = 0.0;
    (1..=n)
        .map(|k| {
            acc += 1.0 / (k as f64).powf(s);
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Anchors shared with python/tests/test_model.py
        assert_eq!(splitmix64(0), 0xE220A8397B1DCDAF);
        assert_eq!(splitmix64(1), 0x910A2DEC89025CC1);
        assert_eq!(splitmix64(0xDEADBEEF), 0x4ADFB90F68C9EB9B);
    }

    #[test]
    fn det_fill_bounded_and_deterministic() {
        let a = det_fill(256, 7, 0.02);
        let b = det_fill(256, 7, 0.02);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.abs() <= 0.02));
        let c = det_fill(256, 8, 0.02);
        assert_ne!(a, c);
    }

    #[test]
    fn det_tokens_in_range() {
        let t = det_tokens(1000, 64, 3);
        assert!(t.iter().all(|&v| (0..64).contains(&v)));
        // should hit most of the vocab over 1000 draws
        let distinct: std::collections::HashSet<_> = t.iter().collect();
        assert!(distinct.len() > 32);
    }

    #[test]
    fn rng_uniform_moments() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn rng_gaussian_moments() {
        let mut r = Rng::new(7);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn rng_streams_independent() {
        let base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
        // re-fork reproduces
        let mut a2 = base.fork(0);
        let xa2: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        assert_eq!(xa, xa2);
    }

    #[test]
    fn log_uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = r.log_uniform(1e-4, 1e-1);
            assert!((1e-4..1e-1).contains(&v));
        }
    }

    #[test]
    fn state_capture_resumes_exactly() {
        // capture mid-stream, keep drawing, then restore: the restored
        // generator must reproduce the continuation bit-for-bit
        let mut r = Rng::new(1234);
        for _ in 0..17 {
            r.next_u64();
        }
        let st = r.state();
        let cont: Vec<u64> = (0..32).map(|_| r.next_u64()).collect();
        let mut back = Rng::from_state(&st);
        let replay: Vec<u64> = (0..32).map(|_| back.next_u64()).collect();
        assert_eq!(cont, replay);
    }

    #[test]
    fn state_capture_preserves_gaussian_spare() {
        // draw an ODD number of gaussians so a Box-Muller spare is pending,
        // then restore: the spare must survive or the streams diverge
        let mut r = Rng::new(5);
        let _ = r.gaussian(); // leaves a spare cached
        let st = r.state();
        assert!(st.gauss_spare.is_some(), "odd draw count must leave a spare");
        let cont: Vec<f64> = (0..9).map(|_| r.gaussian()).collect();
        let mut back = Rng::from_state(&st);
        let replay: Vec<f64> = (0..9).map(|_| back.gaussian()).collect();
        for (a, b) in cont.iter().zip(&replay) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rng_state_word_encoding_roundtrips() {
        let mut r = Rng::new(42);
        let _ = r.gaussian();
        for st in [r.state(), Rng::new(7).state()] {
            let back = RngState::from_words(&st.to_words()).unwrap();
            assert_eq!(back, st);
        }
        assert!(RngState::from_words(&[1, 2, 3]).is_err());
    }

    #[test]
    fn zipf_prefers_small_indices() {
        let cdf = zipf_cdf(64, 1.2);
        let mut r = Rng::new(9);
        let draws: Vec<usize> = (0..5000).map(|_| r.zipf(64, 1.2, &cdf)).collect();
        let low = draws.iter().filter(|&&i| i < 8).count();
        assert!(low > draws.len() / 3, "low-rank mass {low}");
        assert!(draws.iter().all(|&i| i < 64));
    }
}
