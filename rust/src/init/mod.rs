//! Parameter initialization under a chosen parametrization.
//!
//! Combines the manifest's per-tensor spec (shape, role, init kind) with
//! the μP/SP scaling rules to produce the host-side initial tensors fed to
//! a [`crate::runtime::TrainSession`].  Gaussian init only (App. D.5:
//! non-Gaussian init converges to the infinite-width limit more slowly and
//! can break wider-is-better).

pub mod rng;

use crate::model::{tensor_dims, BaseShape};
use crate::mup::{HyperParams, Parametrization};
use crate::runtime::Variant;
use rng::Rng;

/// Initial tensors for `variant` under `par` with base shape `base`,
/// master init std `hp.sigma`, seeded deterministically.
pub fn init_params(
    variant: &Variant,
    par: &Parametrization,
    hp: &HyperParams,
    base: &BaseShape,
    seed: u64,
) -> Vec<Vec<f32>> {
    let dims = tensor_dims(variant, base);
    let root = Rng::new(seed);
    variant
        .params
        .iter()
        .zip(dims)
        .enumerate()
        .map(|(i, (p, d))| match p.init.as_str() {
            "ones" => vec![1.0; p.numel()],
            "zeros" => vec![0.0; p.numel()],
            _ => {
                let std = hp.sigma * par.scaling(p.role, d).init_std;
                root.fork(i as u64).gaussian_vec(p.numel(), std)
            }
        })
        .collect()
}

/// Per-tensor effective LR vector (before schedule) for `variant`.
pub fn lr_vec(
    variant: &Variant,
    par: &Parametrization,
    hp: &HyperParams,
    base: &BaseShape,
) -> Vec<f32> {
    tensor_dims(variant, base)
        .into_iter()
        .zip(&variant.params)
        .map(|(d, p)| par.effective_lr(hp, p.role, d) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{transformer_specs, TfmConfig};
    use crate::mup::Optimizer;
    use crate::runtime::manifest::Kind;
    use crate::stats;

    fn variant(d_model: usize) -> Variant {
        let c = TfmConfig {
            vocab: 64,
            seq: 32,
            batch: 16,
            d_model,
            n_layer: 1,
            n_head: 4,
            d_head: d_model / 4,
            d_ffn: 2 * d_model,
            pre_ln: true,
        };
        let mut v = Variant {
            name: format!("w{d_model}"),
            arch: crate::runtime::Arch::Transformer,
            kind: Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: transformer_specs(&c),
            golden: None,
        };
        for (k, val) in [
            ("vocab", 64.0),
            ("seq", 32.0),
            ("batch", 16.0),
            ("d_model", d_model as f64),
            ("n_layer", 1.0),
            ("n_head", 4.0),
            ("d_head", (d_model / 4) as f64),
            ("d_ffn", (2 * d_model) as f64),
        ] {
            v.config.fields.insert(k.into(), val);
        }
        v.config_str.insert("ln".into(), "pre".into());
        v
    }

    #[test]
    fn deterministic_and_respects_init_kind() {
        let v = variant(64);
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams::default();
        let a = init_params(&v, &par, &hp, &BaseShape::SameAsTarget, 7);
        let b = init_params(&v, &par, &hp, &BaseShape::SameAsTarget, 7);
        assert_eq!(a, b);
        for (p, t) in v.params.iter().zip(&a) {
            match p.init.as_str() {
                "ones" => assert!(t.iter().all(|&x| x == 1.0), "{}", p.name),
                "zeros" => assert!(t.iter().all(|&x| x == 0.0), "{}", p.name),
                _ => assert!(t.iter().any(|&x| x != 0.0), "{}", p.name),
            }
        }
    }

    #[test]
    fn mup_output_std_pinned_to_base() {
        // make unembed "normal" to measure it
        let mut v = variant(256);
        v.params.last_mut().unwrap().init = "normal".into();
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams::default();
        let base = BaseShape::Tfm {
            d_model: 64,
            n_head: 4,
            d_head: 16,
            d_ffn: 128,
        };
        let params = init_params(&v, &par, &hp, &base, 3);
        let un = params.last().unwrap();
        let measured = stats::rms(un);
        // Table 8: output std = 1/sqrt(base_fan_in) = 1/8
        assert!((measured - 1.0 / 8.0).abs() < 0.01, "measured={measured}");
        // SP at the same width would give 1/16
        let sp = Parametrization::standard(Optimizer::Adam);
        let sp_params = init_params(&v, &sp, &hp, &BaseShape::SameAsTarget, 3);
        let sp_rms = stats::rms(sp_params.last().unwrap());
        assert!((sp_rms - 1.0 / 16.0).abs() < 0.01, "sp={sp_rms}");
    }

    #[test]
    fn lr_vec_shapes_and_hidden_scaling() {
        let v = variant(256);
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams {
            lr: 1e-3,
            ..Default::default()
        };
        let base = BaseShape::Tfm {
            d_model: 64,
            n_head: 4,
            d_head: 16,
            d_ffn: 128,
        };
        let lrs = lr_vec(&v, &par, &hp, &base);
        assert_eq!(lrs.len(), v.params.len());
        // embed (input role): full LR; wk (hidden): LR / 4
        let idx_embed = 0;
        let idx_wk = v.params.iter().position(|p| p.name == "block0.wk").unwrap();
        assert!((lrs[idx_embed] - 1e-3).abs() < 1e-9);
        assert!((lrs[idx_wk] - 0.25e-3).abs() < 1e-9);
    }
}
