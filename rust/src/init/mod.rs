//! Parameter initialization under a chosen parametrization.
//!
//! Combines the manifest's per-tensor spec (shape, role, init kind) with
//! the abc triples from [`Parametrization::abc_for`] to produce the
//! host-side initial tensors, per-tensor LRs and gradient multipliers fed
//! to a [`crate::runtime::TrainSession`].  Gaussian init only (App. D.5:
//! non-Gaussian init converges to the infinite-width limit more slowly and
//! can break wider-is-better).
//!
//! ## Folding `a` into stored tensors
//!
//! A triple's `a` is an effective-weight multiplier: the network computes
//! with `a·Ŵ`.  Our kernels expose two multiplier slots (`output_scale`
//! over the readout, `embed_scale` over token+position embeddings); for
//! every tensor the slot residue `k = (α·a)/slot` is folded into the
//! *stored* tensor `E = k·Ŵ` instead.  Folding is exact when the update
//! stays in Ŵ-coordinates, which requires feeding `k·g_E = g_Ŵ` into the
//! optimizer moments (the per-tensor `gmul` — it cannot be folded into
//! the LR because Adam's ε breaks scale invariance) and storing
//! `lr' = k·c·η`, `std' = k·b·σ`.  Under SP and Table-8 μP every `k` is
//! exactly 1.0 (the slots carry the whole `a`), so the folded path is
//! bit-identical to the historical one; u-μP is where `k ≠ 1` appears
//! (hidden matrices fold `1/√fan_in`, the position table folds its slot
//! mismatch against the shared embedding slot).

pub mod rng;

use crate::model::{self, tensor_dims, BaseShape};
use crate::mup::{HyperParams, Optimizer, ParamAbcSpec, Parametrization, Role, ScaleAxes, Scheme};
use crate::runtime::Variant;
use rng::Rng;

/// Per-tensor fold factors `k` for `variant` under `par` (see module
/// docs).  Identically 1.0 for SP and Table-8 μP.
fn fold_k(
    variant: &Variant,
    par: &Parametrization,
    hp: &HyperParams,
    base: &BaseShape,
    axes: ScaleAxes,
) -> Vec<f64> {
    let dims = tensor_dims(variant, base);
    let d_head = variant.config.get("d_head").unwrap_or(1);
    let d_head0 = model::base_d_head(variant, base);
    let m = par.multipliers(hp, dims[0], *dims.last().unwrap(), d_head, d_head0);
    let sp = par.scheme == Scheme::Sp;
    variant
        .params
        .iter()
        .zip(&dims)
        .map(|(p, d)| {
            let abc = par.abc_for(&ParamAbcSpec {
                role: p.role,
                dims: *d,
                residual: model::residual_out(&p.name),
                axes,
            });
            // Which multiplier slot covers this tensor's `a`?  SP slots
            // ignore the tuned alphas, so its numerators must too.
            let (alpha, slot) = if p.role == Role::Output {
                (if sp { 1.0 } else { hp.alpha_output }, m.output_scale)
            } else if par.optimizer == Optimizer::Adam
                && (p.name == "embed" || p.name == "pos_embed")
            {
                (if sp { 1.0 } else { hp.alpha_embed }, m.embed_scale)
            } else {
                (1.0, 1.0)
            };
            (alpha * abc.a) / slot
        })
        .collect()
}

/// Initial tensors for `variant` under `par` with base shape `base` and
/// axis ratios `axes`, master init std `hp.sigma`, seeded
/// deterministically.  Stored std is `(σ·b)·k`.
pub fn init_params(
    variant: &Variant,
    par: &Parametrization,
    hp: &HyperParams,
    base: &BaseShape,
    axes: ScaleAxes,
    seed: u64,
) -> Vec<Vec<f32>> {
    let dims = tensor_dims(variant, base);
    let ks = fold_k(variant, par, hp, base, axes);
    let root = Rng::new(seed);
    variant
        .params
        .iter()
        .zip(dims)
        .zip(ks)
        .enumerate()
        .map(|(i, ((p, d), k))| match p.init.as_str() {
            "ones" => vec![1.0; p.numel()],
            "zeros" => vec![0.0; p.numel()],
            _ => {
                let abc = par.abc_for(&ParamAbcSpec {
                    role: p.role,
                    dims: d,
                    residual: model::residual_out(&p.name),
                    axes,
                });
                let std = (hp.sigma * abc.b) * k;
                root.fork(i as u64).gaussian_vec(p.numel(), std)
            }
        })
        .collect()
}

/// Per-tensor effective LR vector (before schedule) for `variant`:
/// `((η·c)·group_ratio)·k`.
pub fn lr_vec(
    variant: &Variant,
    par: &Parametrization,
    hp: &HyperParams,
    base: &BaseShape,
    axes: ScaleAxes,
) -> Vec<f32> {
    let ks = fold_k(variant, par, hp, base, axes);
    tensor_dims(variant, base)
        .into_iter()
        .zip(&variant.params)
        .zip(ks)
        .map(|((d, p), k)| {
            let abc = par.abc_for(&ParamAbcSpec {
                role: p.role,
                dims: d,
                residual: model::residual_out(&p.name),
                axes,
            });
            let base_lr = hp.lr * abc.c;
            let grouped = match p.role {
                Role::Input | Role::Vector => base_lr * hp.lr_emb_ratio,
                _ => base_lr,
            };
            (grouped * k) as f32
        })
        .collect()
}

/// Per-tensor gradient multipliers: the fold factor `k` fed into the
/// optimizer's moment accumulation (module docs).  All-ones under SP/μP.
pub fn gmul_vec(
    variant: &Variant,
    par: &Parametrization,
    hp: &HyperParams,
    base: &BaseShape,
    axes: ScaleAxes,
) -> Vec<f32> {
    fold_k(variant, par, hp, base, axes)
        .into_iter()
        .map(|k| k as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{transformer_specs, TfmConfig};
    use crate::mup::Optimizer;
    use crate::runtime::manifest::Kind;
    use crate::stats;

    fn variant(d_model: usize) -> Variant {
        let c = TfmConfig {
            vocab: 64,
            seq: 32,
            batch: 16,
            d_model,
            n_layer: 1,
            n_head: 4,
            d_head: d_model / 4,
            d_ffn: 2 * d_model,
            pre_ln: true,
        };
        let mut v = Variant {
            name: format!("w{d_model}"),
            arch: crate::runtime::Arch::Transformer,
            kind: Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: transformer_specs(&c),
            golden: None,
        };
        for (k, val) in [
            ("vocab", 64.0),
            ("seq", 32.0),
            ("batch", 16.0),
            ("d_model", d_model as f64),
            ("n_layer", 1.0),
            ("n_head", 4.0),
            ("d_head", (d_model / 4) as f64),
            ("d_ffn", (2 * d_model) as f64),
        ] {
            v.config.fields.insert(k.into(), val);
        }
        v.config_str.insert("ln".into(), "pre".into());
        v
    }

    #[test]
    fn deterministic_and_respects_init_kind() {
        let v = variant(64);
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams::default();
        let a = init_params(&v, &par, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT, 7);
        let b = init_params(&v, &par, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT, 7);
        assert_eq!(a, b);
        for (p, t) in v.params.iter().zip(&a) {
            match p.init.as_str() {
                "ones" => assert!(t.iter().all(|&x| x == 1.0), "{}", p.name),
                "zeros" => assert!(t.iter().all(|&x| x == 0.0), "{}", p.name),
                _ => assert!(t.iter().any(|&x| x != 0.0), "{}", p.name),
            }
        }
    }

    #[test]
    fn mup_output_std_pinned_to_base() {
        // make unembed "normal" to measure it
        let mut v = variant(256);
        v.params.last_mut().unwrap().init = "normal".into();
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams::default();
        let base = BaseShape::Tfm {
            d_model: 64,
            n_head: 4,
            d_head: 16,
            d_ffn: 128,
        };
        let params = init_params(&v, &par, &hp, &base, ScaleAxes::UNIT, 3);
        let un = params.last().unwrap();
        let measured = stats::rms(un);
        // Table 8: output std = 1/sqrt(base_fan_in) = 1/8
        assert!((measured - 1.0 / 8.0).abs() < 0.01, "measured={measured}");
        // SP at the same width would give 1/16
        let sp = Parametrization::standard(Optimizer::Adam);
        let sp_params = init_params(&v, &sp, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT, 3);
        let sp_rms = stats::rms(sp_params.last().unwrap());
        assert!((sp_rms - 1.0 / 16.0).abs() < 0.01, "sp={sp_rms}");
    }

    #[test]
    fn lr_vec_shapes_and_hidden_scaling() {
        let v = variant(256);
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams {
            lr: 1e-3,
            ..Default::default()
        };
        let base = BaseShape::Tfm {
            d_model: 64,
            n_head: 4,
            d_head: 16,
            d_ffn: 128,
        };
        let lrs = lr_vec(&v, &par, &hp, &base, ScaleAxes::UNIT);
        assert_eq!(lrs.len(), v.params.len());
        // embed (input role): full LR; wk (hidden): LR / 4
        let idx_embed = 0;
        let idx_wk = v.params.iter().position(|p| p.name == "block0.wk").unwrap();
        assert!((lrs[idx_embed] - 1e-3).abs() < 1e-9);
        assert!((lrs[idx_wk] - 0.25e-3).abs() < 1e-9);
    }

    #[test]
    fn sp_and_mup_folds_are_exactly_one() {
        let v = variant(256);
        let hp = HyperParams {
            alpha_output: 1.7, // alphas must cancel out of the folds
            alpha_embed: 0.9,
            ..Default::default()
        };
        let base = BaseShape::Tfm {
            d_model: 64,
            n_head: 4,
            d_head: 16,
            d_ffn: 128,
        };
        for par in [
            Parametrization::mup(Optimizer::Adam),
            Parametrization::standard(Optimizer::Adam),
        ] {
            let g = gmul_vec(&v, &par, &hp, &base, ScaleAxes::UNIT);
            assert!(g.iter().all(|&k| k == 1.0), "{:?}: {g:?}", par.scheme);
        }
    }

    #[test]
    fn umup_folds_hidden_and_keeps_stored_std_unit_free() {
        let v = variant(256);
        let par = Parametrization::umup(Optimizer::Adam);
        let hp = HyperParams::default();
        let base = BaseShape::Tfm {
            d_model: 64,
            n_head: 4,
            d_head: 16,
            d_ffn: 128,
        };
        let g = gmul_vec(&v, &par, &hp, &base, ScaleAxes::UNIT);
        let idx_wk = v.params.iter().position(|p| p.name == "block0.wk").unwrap();
        // hidden fold = a = 1/sqrt(fan_in) = 1/16 at d_model 256
        assert!((g[idx_wk] - 1.0 / 16.0).abs() < 1e-9);
        // embed is covered by the embed slot: fold exactly 1
        assert_eq!(g[0], 1.0);
        // stored init std for hidden therefore matches μP's 1/sqrt(fan_in)
        let params = init_params(&v, &par, &hp, &base, ScaleAxes::UNIT, 3);
        let wk_rms = stats::rms(&params[idx_wk]);
        assert!((wk_rms - 1.0 / 16.0).abs() < 0.005, "wk={wk_rms}");
        // ... and the stored embed table is unit-variance (u-μP property)
        let emb_rms = stats::rms(&params[0]);
        assert!((emb_rms - 1.0).abs() < 0.05, "embed={emb_rms}");
        // effective hidden Adam LR: c·k = (√fi/r)·(1/√fi) = η/r = η/4
        let lrs = lr_vec(&v, &par, &hp, &base, ScaleAxes::UNIT);
        assert!((lrs[idx_wk] - 0.25e-3).abs() < 1e-9);
    }

    #[test]
    fn depth_axis_scales_residual_lr_and_fold() {
        let mut v = variant(64);
        v.config.fields.insert("n_layer".into(), 4.0); // pretend 4 layers
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams::default();
        let axes = crate::model::scale_axes(&v, Some(1), None);
        assert_eq!(axes.depth_ratio, 4.0);
        let flat = lr_vec(&v, &par, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT);
        let deep = lr_vec(&v, &par, &hp, &BaseShape::SameAsTarget, axes);
        let g = gmul_vec(&v, &par, &hp, &BaseShape::SameAsTarget, axes);
        let idx_wo = v.params.iter().position(|p| p.name == "block0.wo").unwrap();
        let idx_wk = v.params.iter().position(|p| p.name == "block0.wk").unwrap();
        // residual-branch outputs: LR and fold both shrink by √4 = 2
        assert!((deep[idx_wo] / flat[idx_wo] - 0.5).abs() < 1e-6);
        assert!((g[idx_wo] - 0.5).abs() < 1e-6);
        // non-residual hidden: untouched
        assert_eq!(deep[idx_wk], flat[idx_wk]);
        assert_eq!(g[idx_wk], 1.0);
        // SP ignores the axis entirely
        let sp = Parametrization::standard(Optimizer::Adam);
        assert_eq!(
            lr_vec(&v, &sp, &hp, &BaseShape::SameAsTarget, axes),
            lr_vec(&v, &sp, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT)
        );
    }

    #[test]
    fn batch_axis_scales_all_lrs() {
        let v = variant(64);
        let par = Parametrization::mup(Optimizer::Adam);
        let hp = HyperParams::default();
        let axes = crate::model::scale_axes(&v, None, Some(4)); // batch 16, base 4
        assert_eq!(axes.batch_ratio, 4.0);
        let flat = lr_vec(&v, &par, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT);
        let big = lr_vec(&v, &par, &hp, &BaseShape::SameAsTarget, axes);
        for (i, (a, b)) in flat.iter().zip(&big).enumerate() {
            assert!((b / a - 2.0).abs() < 1e-6, "tensor {i}: {a} -> {b}");
        }
        // gradient folds are untouched by the batch axis
        assert!(gmul_vec(&v, &par, &hp, &BaseShape::SameAsTarget, axes)
            .iter()
            .all(|&k| k == 1.0));
        // SP: invariant
        let sp = Parametrization::standard(Optimizer::Adam);
        assert_eq!(
            lr_vec(&v, &sp, &hp, &BaseShape::SameAsTarget, axes),
            lr_vec(&v, &sp, &hp, &BaseShape::SameAsTarget, ScaleAxes::UNIT)
        );
    }
}
