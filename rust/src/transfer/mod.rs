//! μTransfer (Algorithm 1) and reverse-μTransfer (Appendix I).
//!
//! `mu_transfer` is the paper's whole pitch in one function:
//!   1. parametrize the target in μP with the proxy as base shape;
//!   2. tune the proxy (random search over a [`SearchSpace`]);
//!   3. copy the winning HPs to the target, zero-shot.
//!
//! `naive_transfer` is the baseline that must fail (tune a small SP model,
//! copy to a big SP model), and `direct_tuning` is the FLOPs-matched
//! conventional alternative the Tables 4-6 compare against.

use anyhow::Result;

use crate::init::rng::Rng;
use crate::model::BaseShape;
use crate::mup::{HyperParams, Optimizer, Parametrization, Scheme};
use crate::runtime::Runtime;
use crate::sweep::{Job, JobResult, Sweep};
use crate::train::{RunSpec, Schedule};
use crate::tuner::sha::{run_sha, ShaConfig};
use crate::tuner::{select_best, Assignment, SearchSpace, Trial};
use crate::util::json::{jnum, Json};

/// How step 2 of Algorithm 1 ("tune the proxy") searches the space.  All
/// three run through the same [`Sweep`] (worker pool + journal + optional
/// checkpoints); only the schedule differs.
#[derive(Debug, Clone, PartialEq)]
pub enum TunerKind {
    /// the paper's default: `n_samples` independent draws, full budget each
    Random,
    /// exhaustive cartesian grid (requires `Dim::Grid` dimensions;
    /// `n_samples` is ignored)
    Grid,
    /// successive halving over `n_samples` random draws: all trials run to
    /// `rung0` steps, the top `1/eta` resume from their snapshots with
    /// `eta×` more budget, repeating up to `proxy_steps` — strictly fewer
    /// total train steps than [`TunerKind::Random`] at the same final
    /// budget when the sweep has checkpoints enabled
    Sha { eta: usize, rung0: usize },
}

/// Shared knobs for a transfer study.
#[derive(Debug, Clone)]
pub struct TransferSetup {
    pub proxy_variant: String,
    pub target_variant: String,
    /// μP base shape == the proxy's widths
    pub base: BaseShape,
    /// which formulation parametrizes the tuned and transferred runs
    /// (μP/u-μP transfer; SP is the baseline that drifts)
    pub scheme: Scheme,
    /// depth (n_layer / n_block) the proxy tunes at — `None` disables the
    /// depth transfer axis.  Applied to proxy AND target specs; the ratio
    /// against each variant's actual depth drives the residual factors.
    pub base_depth: Option<usize>,
    /// batch size the proxy tunes at — `None` disables the batch axis
    pub base_batch: Option<usize>,
    pub optimizer: Optimizer,
    pub space: SearchSpace,
    pub proxy_steps: usize,
    pub target_steps: usize,
    pub n_samples: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub schedule: Schedule,
    /// proxy-tuning strategy (random / grid / successive halving)
    pub tuner: TunerKind,
}

#[derive(Debug, Clone)]
pub struct TransferOutcome {
    /// all proxy trials (the search record, Fig. 14-style)
    pub proxy_trials: Vec<Trial>,
    /// the winning assignment
    pub best: Option<Assignment>,
    /// target run with transferred HPs
    pub target: Option<JobResult>,
    /// FLOPs spent searching (proxy) and training the target
    pub search_flops: f64,
    pub target_flops: f64,
}

impl TransferOutcome {
    /// Appendix F.4 cost ratio.
    pub fn tuning_cost_ratio(&self) -> f64 {
        if self.target_flops > 0.0 {
            self.search_flops / self.target_flops
        } else {
            f64::NAN
        }
    }

    /// Validation loss of the winning proxy trial (`NaN` when everything
    /// diverged) — what `GET /hp` ranks completed sweeps by.
    pub fn best_val_loss(&self) -> f64 {
        match &self.best {
            Some(b) => self
                .proxy_trials
                .iter()
                .find(|t| &t.assignment == b)
                .map(|t| t.val_loss)
                .unwrap_or(f64::NAN),
            None => f64::NAN,
        }
    }

    /// Canonical JSON form — **deterministic by construction**: every
    /// field is a pure function of the job spec (trials, curves, FLOPs);
    /// wall-clock times are deliberately excluded.  The serve daemon
    /// persists this as a job's `results.json` and the CLI's
    /// `--results-json` writes the identical bytes, which is what lets CI
    /// assert a daemon-run sweep is bit-identical to an offline one.
    pub fn to_json(&self) -> Json {
        let target = match &self.target {
            Some(r) => Json::from_pairs(vec![
                ("trial", r.trial.to_json()),
                (
                    "train_curve",
                    crate::util::json::jnums(&r.train_curve),
                ),
                (
                    "val_curve",
                    Json::Arr(
                        r.val_curve
                            .iter()
                            .map(|&(s, l)| Json::Arr(vec![jnum(s as f64), jnum(l)]))
                            .collect(),
                    ),
                ),
            ]),
            None => Json::Null,
        };
        Json::from_pairs(vec![
            (
                "proxy_trials",
                Json::Arr(self.proxy_trials.iter().map(|t| t.to_json()).collect()),
            ),
            (
                "best",
                self.best.as_ref().map(|b| b.to_json()).unwrap_or(Json::Null),
            ),
            ("best_val_loss", jnum(self.best_val_loss())),
            ("target", target),
            ("search_flops", jnum(self.search_flops)),
            ("target_flops", jnum(self.target_flops)),
            ("tuning_cost_ratio", jnum(self.tuning_cost_ratio())),
        ])
    }
}

#[allow(clippy::too_many_arguments)]
fn spec_for(
    setup: &TransferSetup,
    variant: &str,
    par: Parametrization,
    hp: HyperParams,
    base: BaseShape,
    steps: usize,
    seed: u64,
) -> RunSpec {
    let mut s = RunSpec::new(variant, par, hp, base);
    s.steps = steps;
    s.seed = seed;
    s.eval_every = setup.eval_every.max(1).min(steps);
    s.schedule = setup.schedule;
    // SP specs carry these too but ignore them (`abc_for` applies axis
    // ratios only under μP/u-μP) — which is exactly the baseline story:
    // the naive path gets no depth/batch correction and drifts.
    s.base_depth = setup.base_depth;
    s.base_batch = setup.base_batch;
    s
}

/// Step 2 of Algorithm 1, shared by [`mu_transfer`] and [`tune_only`]:
/// tune the proxy through the sweep and return (all trials, winner).
fn tune_proxy(
    sweep: &mut Sweep,
    setup: &TransferSetup,
    label: &str,
) -> Result<(Vec<Trial>, Option<Assignment>)> {
    let par = Parametrization::new(setup.scheme, setup.optimizer);
    let mut rng = Rng::new(setup.seed ^ 0xA11CE);
    // Grid enumerates the space; Random and SHA draw the same `n_samples`
    // assignments (same RNG stream, so SHA's candidate set is identical
    // to what Random would evaluate).
    let assignments: Vec<Assignment> = match &setup.tuner {
        TunerKind::Grid => setup.space.grid(),
        _ => (0..setup.n_samples)
            .map(|_| setup.space.sample(&mut rng))
            .collect(),
    };
    let jobs: Vec<Job> = assignments
        .into_iter()
        .enumerate()
        .map(|(i, a)| Job {
            key: format!("{label}/proxy/{i}"),
            spec: spec_for(
                setup,
                &setup.proxy_variant,
                par,
                a.apply(HyperParams::default()),
                setup.base.clone(),
                setup.proxy_steps,
                setup.seed + 1000 + i as u64,
            ),
            assignment: a,
            data_seed: setup.seed,
            ckpt_id: None,
        })
        .collect();
    match &setup.tuner {
        TunerKind::Sha { eta, rung0 } => {
            let out = run_sha(
                sweep,
                &jobs,
                &ShaConfig {
                    eta: *eta,
                    rung0: *rung0,
                    max_steps: setup.proxy_steps,
                },
            )?;
            Ok((out.trials, out.best))
        }
        _ => {
            let results = sweep.run(&jobs)?;
            let trials: Vec<Trial> = results.iter().map(|r| r.trial.clone()).collect();
            let best = select_best(&trials).map(|t| t.assignment.clone());
            Ok((trials, best))
        }
    }
}

/// Step 2 of Algorithm 1 on its own: tune the proxy, skip the target run.
/// The serve daemon's `sweep` job kind — tune once, let `GET /hp` answer
/// for any later target scale.
pub fn tune_only(
    rt: &Runtime,
    sweep: &mut Sweep,
    setup: &TransferSetup,
    label: &str,
) -> Result<TransferOutcome> {
    let _ = rt; // execution flows through the sweep's shared runtime
    let (proxy_trials, best) = tune_proxy(sweep, setup, label)?;
    let search_flops: f64 = proxy_trials.iter().map(|t| t.flops).sum();
    Ok(TransferOutcome {
        proxy_trials,
        best,
        target: None,
        search_flops,
        target_flops: 0.0,
    })
}

/// Algorithm 1.  `scheme_base`: μP uses the proxy widths as base for BOTH
/// proxy and target (so the proxy literally *is* an SP model of itself,
/// Eq. (4)).
pub fn mu_transfer(
    rt: &Runtime,
    sweep: &mut Sweep,
    setup: &TransferSetup,
    label: &str,
) -> Result<TransferOutcome> {
    let _ = rt; // execution flows through the sweep's shared runtime
    let par = Parametrization::new(setup.scheme, setup.optimizer);
    // 2. tune the proxy
    let (proxy_trials, best) = tune_proxy(sweep, setup, label)?;
    let search_flops: f64 = proxy_trials.iter().map(|t| t.flops).sum();

    // 3. zero-shot copy to the target
    let (target, target_flops) = if let Some(best_a) = &best {
        let job = Job {
            key: format!("{label}/target"),
            spec: spec_for(
                setup,
                &setup.target_variant,
                par,
                best_a.apply(HyperParams::default()),
                setup.base.clone(),
                setup.target_steps,
                setup.seed + 99,
            ),
            assignment: best_a.clone(),
            data_seed: setup.seed,
            ckpt_id: None,
        };
        let r = sweep.run(&[job])?.remove(0);
        let fl = r.trial.flops;
        (Some(r), fl)
    } else {
        (None, 0.0)
    };

    Ok(TransferOutcome {
        proxy_trials,
        best,
        target,
        search_flops,
        target_flops,
    })
}

/// Naive transfer baseline: tune the proxy in **SP** and copy to the SP
/// target (what practitioners do without μP; Tables 4-6's "diverged"
/// rows).
pub fn naive_transfer(
    rt: &Runtime,
    sweep: &mut Sweep,
    setup: &TransferSetup,
    label: &str,
) -> Result<TransferOutcome> {
    let par = Parametrization::standard(setup.optimizer);
    let mut rng = Rng::new(setup.seed ^ 0xA11CE); // same HP draws as μT
    let jobs: Vec<Job> = (0..setup.n_samples)
        .map(|i| {
            let a = setup.space.sample(&mut rng);
            Job {
                key: format!("{label}/sp-proxy/{i}"),
                spec: spec_for(
                    setup,
                    &setup.proxy_variant,
                    par,
                    a.apply(HyperParams::default()),
                    BaseShape::SameAsTarget,
                    setup.proxy_steps,
                    setup.seed + 1000 + i as u64,
                ),
                assignment: a,
                data_seed: setup.seed,
                ckpt_id: None,
            }
        })
        .collect();
    let results = sweep.run(&jobs)?;
    let proxy_trials: Vec<Trial> = results.iter().map(|r| r.trial.clone()).collect();
    let search_flops: f64 = proxy_trials.iter().map(|t| t.flops).sum();
    let best = select_best(&proxy_trials).map(|t| t.assignment.clone());
    let (target, target_flops) = if let Some(best_a) = &best {
        let job = Job {
            key: format!("{label}/sp-target"),
            spec: spec_for(
                setup,
                &setup.target_variant,
                par,
                best_a.apply(HyperParams::default()),
                BaseShape::SameAsTarget,
                setup.target_steps,
                setup.seed + 99,
            ),
            assignment: best_a.clone(),
            data_seed: setup.seed,
            ckpt_id: None,
        };
        let r = sweep.run(&[job])?.remove(0);
        let fl = r.trial.flops;
        (Some(r), fl)
    } else {
        (None, 0.0)
    };
    let _ = rt;
    Ok(TransferOutcome {
        proxy_trials,
        best,
        target,
        search_flops,
        target_flops,
    })
}

/// Conventional tuning: sample HPs *on the target itself* with a given
/// sample budget (the FLOPs-matched "Tuning on 1x" rows).
pub fn direct_tuning(
    rt: &Runtime,
    sweep: &mut Sweep,
    setup: &TransferSetup,
    n_samples: usize,
    label: &str,
) -> Result<TransferOutcome> {
    let par = Parametrization::standard(setup.optimizer);
    let mut rng = Rng::new(setup.seed ^ 0xD12EC7);
    let jobs: Vec<Job> = (0..n_samples)
        .map(|i| {
            let a = setup.space.sample(&mut rng);
            Job {
                key: format!("{label}/direct/{i}"),
                spec: spec_for(
                    setup,
                    &setup.target_variant,
                    par,
                    a.apply(HyperParams::default()),
                    BaseShape::SameAsTarget,
                    setup.target_steps,
                    setup.seed + 2000 + i as u64,
                ),
                assignment: a,
                data_seed: setup.seed,
                ckpt_id: None,
            }
        })
        .collect();
    let results = sweep.run(&jobs)?;
    let trials: Vec<Trial> = results.iter().map(|r| r.trial.clone()).collect();
    let search_flops: f64 = trials.iter().map(|t| t.flops).sum();
    let best_idx = select_best(&trials).map(|b| {
        trials
            .iter()
            .position(|t| std::ptr::eq(t, b))
            .unwrap_or(0)
    });
    let target = best_idx.map(|i| results[i].clone());
    let best = select_best(&trials).map(|t| t.assignment.clone());
    let _ = rt;
    Ok(TransferOutcome {
        proxy_trials: trials,
        best,
        target,
        search_flops,
        target_flops: 0.0,
    })
}

/// Reverse-μTransfer (Appendix I): take HPs that destabilize a *wide* SP
/// model and map them onto a narrow μP model with base width =
/// `simulated_width`, replicating the instability cheaply.  Returns the
/// RunSpec to execute on the narrow model.
pub fn reverse_spec(
    narrow_variant: &str,
    simulated: BaseShape,
    optimizer: Optimizer,
    hp: HyperParams,
    steps: usize,
    seed: u64,
) -> RunSpec {
    // μP with base = the *wide* shape: at narrow width the rules invert,
    // scaling LR/init *up* exactly as much as width went down — i.e. the
    // narrow model behaves like the wide SP model.
    let par = Parametrization::mup(optimizer);
    let mut s = RunSpec::new(narrow_variant, par, hp, simulated);
    s.steps = steps;
    s.seed = seed;
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_cost_ratio() {
        let o = TransferOutcome {
            proxy_trials: vec![],
            best: None,
            target: None,
            search_flops: 7.0,
            target_flops: 100.0,
        };
        assert!((o.tuning_cost_ratio() - 0.07).abs() < 1e-12);
    }

    #[test]
    fn reverse_spec_uses_mup_with_wide_base() {
        let spec = reverse_spec(
            "tfm_post_w64_d2",
            BaseShape::Tfm {
                d_model: 512,
                n_head: 4,
                d_head: 128,
                d_ffn: 2048,
            },
            Optimizer::Adam,
            HyperParams::default(),
            10,
            1,
        );
        assert_eq!(spec.par, Parametrization::mup(Optimizer::Adam));
        match spec.base {
            BaseShape::Tfm { d_model, .. } => assert_eq!(d_model, 512),
            _ => panic!(),
        }
    }
}
