//! Learning-rate schedules — one of the four HPs whose transferability
//! Fig. 4 validates (column 4: (a) linear decay, (b)/(c) StepLR,
//! (d) cosine annealing, (e) constant, (f) inverse square-root).
//!
//! Schedules are pure host-side multipliers on the per-tensor LR vector,
//! so a single compiled artifact serves every schedule.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant,
    /// linear decay toward 0, floored at `1/total`: the final step trains
    /// at `lr/total` instead of an exact 0, which would make it a no-op
    /// (the paper's linear-decay panel likewise never multiplies by 0).
    /// The floor is intentional; `linear_decays_monotonically` pins it.
    Linear,
    /// cosine annealing to 0
    Cosine,
    /// multiply by `factor` at each fraction-of-training milestone
    Step2 {
        at: [f64; 2],
        factor: f64,
    },
    /// 1/sqrt(1 + step/warm)
    InvSqrt {
        warm: f64,
    },
}

impl Schedule {
    /// Whether the multiplier at a given step is independent of the total
    /// step budget.  Budget-agnostic schedules (constant, inverse
    /// square-root) let a checkpointed trial legally *extend* its budget
    /// mid-trajectory — SHA's rung promotions rely on this.  The others
    /// (linear, cosine, step milestones) bake `total` into every step's
    /// LR, so the checkpoint trajectory fingerprint includes the budget
    /// and a resume under a different budget restarts from step 0 rather
    /// than splicing two decay ladders together.
    pub fn budget_agnostic(&self) -> bool {
        matches!(self, Schedule::Constant | Schedule::InvSqrt { .. })
    }

    /// Multiplier at `step` of `total` (step is 0-based).
    pub fn factor(&self, step: usize, total: usize) -> f64 {
        let t = if total <= 1 {
            0.0
        } else {
            step as f64 / (total - 1) as f64
        };
        match self {
            Schedule::Constant => 1.0,
            Schedule::Linear => (1.0 - t).max(1.0 / total.max(1) as f64),
            Schedule::Cosine => 0.5 * (1.0 + (std::f64::consts::PI * t).cos()),
            Schedule::Step2 { at, factor } => {
                let mut f = 1.0;
                if t >= at[0] {
                    f *= factor;
                }
                if t >= at[1] {
                    f *= factor;
                }
                f
            }
            Schedule::InvSqrt { warm } => 1.0 / (1.0 + step as f64 / warm).sqrt(),
        }
    }

    /// The Fig. 4 schedule panel, by label.
    pub fn named(name: &str) -> Option<Schedule> {
        Some(match name {
            "constant" => Schedule::Constant,
            "linear" => Schedule::Linear,
            "cosine" => Schedule::Cosine,
            "step_0.1" => Schedule::Step2 {
                at: [0.5, 0.8],
                factor: 0.1,
            },
            "step_0.3" => Schedule::Step2 {
                at: [0.4, 0.7],
                factor: 0.3,
            },
            "invsqrt" => Schedule::InvSqrt { warm: 32.0 },
            _ => return None,
        })
    }

    pub fn all_named() -> &'static [&'static str] {
        &["constant", "linear", "cosine", "step_0.1", "step_0.3", "invsqrt"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        for s in [0, 10, 99] {
            assert_eq!(Schedule::Constant.factor(s, 100), 1.0);
        }
    }

    #[test]
    fn linear_decays_monotonically() {
        let sch = Schedule::Linear;
        let mut prev = f64::INFINITY;
        for s in 0..100 {
            let f = sch.factor(s, 100);
            assert!(f <= prev && f > 0.0);
            prev = f;
        }
        assert!((sch.factor(0, 100) - 1.0).abs() < 1e-12);
        // the documented floor: final step trains at exactly 1/total, not 0
        assert_eq!(sch.factor(99, 100), 0.01);
        assert_eq!(sch.factor(1, 2), 0.5);
    }

    #[test]
    fn cosine_endpoints() {
        let sch = Schedule::Cosine;
        assert!((sch.factor(0, 100) - 1.0).abs() < 1e-12);
        assert!(sch.factor(99, 100).abs() < 1e-12);
        assert!((sch.factor(49, 99) - 0.5).abs() < 0.02);
    }

    #[test]
    fn step_schedule_drops_twice() {
        let sch = Schedule::Step2 {
            at: [0.5, 0.8],
            factor: 0.1,
        };
        assert_eq!(sch.factor(0, 100), 1.0);
        assert!((sch.factor(60, 100) - 0.1).abs() < 1e-12);
        assert!((sch.factor(90, 100) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn invsqrt_halves_at_3warm() {
        let sch = Schedule::InvSqrt { warm: 32.0 };
        assert!((sch.factor(96, 1000) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn named_roundtrip() {
        for name in Schedule::all_named() {
            assert!(Schedule::named(name).is_some(), "{name}");
        }
        assert!(Schedule::named("bogus").is_none());
    }
}
