//! Single-run training driver: one (variant, parametrization, HP
//! assignment, seed) → a loss curve.  Everything above this (tuner, sweep,
//! experiments) composes runs; everything below (runtime) executes steps.

pub mod schedule;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::ckpt::{RunProgress, Snapshot};
use crate::data::{DataSource, Split};
use crate::init;
use crate::model::BaseShape;
use crate::mup::{HyperParams, Optimizer, Parametrization, ScaleAxes};
use crate::obs::{coords, metrics, trace};
use crate::runtime::session::{validate_init, StepInputs};
use crate::runtime::{BackendSession, Runtime, SessionCore, Variant};
use crate::serve::events::{Event, EventSink, StderrSink};
pub use schedule::Schedule;

/// Loss above which (relative to the initial loss) a run is declared
/// diverged — matching the paper's "training diverged" table entries.
pub const DIVERGE_FACTOR: f64 = 3.0;
pub const DIVERGE_ABS: f64 = 1e4;

#[derive(Debug, Clone)]
pub struct RunSpec {
    /// train (or coord) variant name from the manifest
    pub variant: String,
    pub par: Parametrization,
    pub hp: HyperParams,
    pub base: BaseShape,
    /// depth (n_layer / n_block) of the base model the HPs were tuned at —
    /// `None` = same as target (no depth-axis scaling).  Drives the
    /// residual-branch 1/√(L/L₀) factors under μP/u-μP.
    pub base_depth: Option<usize>,
    /// batch size of the base model — `None` = same as target.  Drives the
    /// global LR batch-scaling factor (√(B/B₀) Adam, B/B₀ SGD).
    pub base_batch: Option<usize>,
    pub steps: usize,
    pub seed: u64,
    pub schedule: Schedule,
    /// evaluate on the val stream every k steps (0 = never)
    pub eval_every: usize,
    /// number of val batches averaged per evaluation
    pub eval_batches: usize,
}

impl RunSpec {
    pub fn new(variant: &str, par: Parametrization, hp: HyperParams, base: BaseShape) -> RunSpec {
        RunSpec {
            variant: variant.to_string(),
            par,
            hp,
            base,
            base_depth: None,
            base_batch: None,
            steps: 100,
            seed: 0,
            schedule: Schedule::Constant,
            eval_every: 0,
            eval_batches: 4,
        }
    }

    pub fn optimizer(&self) -> Optimizer {
        self.par.optimizer
    }

    /// Depth/batch transfer ratios for this spec against `variant`'s
    /// actual shape (unit when the base dims are unset or match).
    pub fn axes(&self, variant: &Variant) -> ScaleAxes {
        crate::model::scale_axes(variant, self.base_depth, self.base_batch)
    }

    /// Identity of the *trajectory* this spec defines: variant,
    /// parametrization, HPs, base shape, seed, and schedule — everything
    /// that changes the step-by-step math, but **not** the eval cadence,
    /// and not the step budget *when the schedule is budget-agnostic*
    /// (SHA rungs legitimately extend a constant-LR trial's budget; a
    /// linear/cosine trial's per-step LR depends on the total, so its
    /// budget is part of the identity and resume under a different budget
    /// restarts fresh).  Checkpoints record this; resume refuses a
    /// snapshot written under a different fingerprint, so edited HPs can
    /// never silently continue foreign state.
    pub fn trajectory_fingerprint(&self) -> u64 {
        let budget_tag = if self.schedule.budget_agnostic() {
            0
        } else {
            self.steps as u64
        };
        // Debug formatting is deterministic (f64 prints shortest
        // round-trip), which is all a same-binary identity check needs.
        let desc = format!(
            "{}|{:?}|{:?}|{:?}|{:?}|{:?}|{:?}|{}|{budget_tag}",
            self.variant,
            self.par,
            self.hp,
            self.base,
            self.base_depth,
            self.base_batch,
            self.schedule,
            self.seed
        );
        crate::init::rng::fold64(0xC0DE_5EED_0000_0001, desc.as_bytes())
    }
}

#[derive(Debug, Clone)]
pub struct RunResult {
    pub train_losses: Vec<f64>,
    /// (step, val_loss) pairs
    pub val_losses: Vec<(usize, f64)>,
    pub diverged: bool,
    pub steps_done: usize,
    pub flops: f64,
    pub wall_secs: f64,
}

impl RunResult {
    /// Mean training loss over the last 10% of steps (smooths batch noise;
    /// what the LR-sweep figures plot).
    pub fn final_train_loss(&self) -> f64 {
        if self.diverged || self.train_losses.is_empty() {
            return f64::NAN;
        }
        let k = (self.train_losses.len() / 10).max(1);
        let tail = &self.train_losses[self.train_losses.len() - k..];
        tail.iter().sum::<f64>() / k as f64
    }

    /// Best (lowest) validation loss seen — the paper's §7 selection
    /// metric ("we pick the HP combination that achieves the lowest
    /// validation loss").
    pub fn best_val_loss(&self) -> f64 {
        if self.diverged {
            return f64::NAN;
        }
        self.val_losses
            .iter()
            .map(|&(_, l)| l)
            .fold(f64::NAN, |a, b| if a.is_nan() || b < a { b } else { a })
    }
}

/// Build the per-step hp_vec for a variant/parametrization pair.
pub fn hp_vec(spec: &RunSpec, rt: &Runtime) -> Result<[f32; 8]> {
    let variant = rt.manifest().get(&spec.variant)?;
    let dims = crate::model::tensor_dims(variant, &spec.base);
    let out_dims = *dims.last().unwrap(); // unembed / w_out is last by layout
    let hp = &spec.hp;
    Ok(match spec.par.optimizer {
        Optimizer::Adam => {
            let d_head = variant.config.get("d_head").unwrap_or(1);
            let d_head0 = crate::model::base_d_head(variant, &spec.base);
            let m = spec.par.multipliers(hp, dims[0], out_dims, d_head, d_head0);
            [
                m.attn_scale as f32,
                m.output_scale as f32,
                m.embed_scale as f32,
                hp.beta1 as f32,
                hp.beta2 as f32,
                hp.eps as f32,
                hp.weight_decay as f32,
                1.0, // step counter; session overwrites per step
            ]
        }
        Optimizer::Sgd => {
            let m = spec.par.multipliers(hp, dims[0], out_dims, 1, 1);
            [
                m.output_scale as f32,
                hp.momentum as f32,
                hp.weight_decay as f32,
                0.0,
                0.0,
                0.0,
                0.0,
                0.0,
            ]
        }
    })
}

/// Periodic-checkpoint policy for one run (DESIGN.md §7).  The drive loop
/// writes a [`Snapshot`] to `path` every `every` steps (and always one at
/// the end of the run, marked complete), and — if `path` already holds a
/// usable snapshot when the run starts — restores it and continues from
/// its step counter instead of from 0.  An interrupted-then-resumed run
/// is bitwise identical to an uninterrupted one
/// (`rust/tests/ckpt_resume.rs`).  Backends without state capture (PJRT)
/// make both directions a silent no-op.
#[derive(Debug, Clone)]
pub struct CkptConfig {
    /// write a mid-run snapshot every `every` steps (0 = only at the end)
    pub every: usize,
    /// snapshot file; written tmp-then-rename, read back on resume
    pub path: PathBuf,
}

/// Everything a run needs once the `Runtime` has been consulted: resolved
/// variant, expanded init (already inside the session), per-tensor base
/// LRs and the hp_vec.  Because the session handle is `Send`-bounded
/// (obtained via [`crate::runtime::Backend::session_send`]), a
/// `PreparedRun` can be shipped to a sweep worker thread and executed
/// there without touching the `Runtime` again.
pub struct PreparedRun {
    spec: RunSpec,
    core: SessionCore<dyn BackendSession + Send>,
    base_lr: Vec<f32>,
    gmul: Vec<f32>,
    hp_v: [f32; 8],
    ckpt: Option<CkptConfig>,
    sink: Option<Arc<dyn EventSink>>,
    key: Option<String>,
}

impl PreparedRun {
    pub fn variant(&self) -> &Variant {
        &self.core.variant
    }

    /// Attach a checkpoint policy: the drive loop snapshots periodically
    /// and resumes from `cfg.path` when it already holds usable state.
    pub fn with_checkpoint(mut self, cfg: CkptConfig) -> PreparedRun {
        self.ckpt = Some(cfg);
        self
    }

    /// Route this run's progress/warning events (labelled `key`) into
    /// `sink` instead of the default warnings-only stderr sink.
    pub fn with_emitter(mut self, sink: Arc<dyn EventSink>, key: &str) -> PreparedRun {
        self.sink = Some(sink);
        self.key = Some(key.to_string());
        self
    }

    /// Run the step loop to completion.  Consumes the prepared session —
    /// restartability lives in the checkpoint file, not the value.
    pub fn execute(mut self, data: &dyn DataSource) -> Result<RunResult> {
        let sink: Arc<dyn EventSink> =
            self.sink.take().unwrap_or_else(|| Arc::new(StderrSink::quiet()));
        let key = self.key.take().unwrap_or_else(|| self.spec.variant.clone());
        drive(
            &mut self.core,
            &self.spec,
            &self.base_lr,
            &self.gmul,
            &self.hp_v,
            data,
            self.ckpt.as_ref(),
            sink.as_ref(),
            &key,
        )
    }
}

/// Spec resolution shared by the sequential and parallel paths: resolve
/// the variant, expand init + per-tensor LRs + hp_vec, and validate.  One
/// function so the two schedulers can never desynchronize on seeding or
/// validation order — the bit-exact-across-worker-counts contract depends
/// on it.
#[allow(clippy::type_complexity)]
fn resolve(
    rt: &Runtime,
    spec: &RunSpec,
) -> Result<(Variant, Vec<Vec<f32>>, Vec<f32>, Vec<f32>, [f32; 8])> {
    let variant = rt.manifest().get(&spec.variant)?.clone();
    let axes = spec.axes(&variant);
    let params = init::init_params(&variant, &spec.par, &spec.hp, &spec.base, axes, spec.seed);
    let base_lr = init::lr_vec(&variant, &spec.par, &spec.hp, &spec.base, axes);
    // all-ones collapses to the empty vector: backends skip the multiply
    // entirely (bitwise-identical trajectories for SP/μP) and PJRT — which
    // cannot apply a real fold — stays usable for them.
    let mut gmul = init::gmul_vec(&variant, &spec.par, &spec.hp, &spec.base, axes);
    if gmul.iter().all(|&k| k == 1.0) {
        gmul = Vec::new();
    }
    let hp_v = hp_vec(spec, rt)?;
    validate_init(&variant, &spec.variant, &params)?;
    Ok((variant, params, base_lr, gmul, hp_v))
}

/// Resolve a spec into a [`PreparedRun`] on the coordinator thread.
/// Returns `Ok(None)` when the backend declines `Send` sessions (PJRT) —
/// the caller must then execute sequentially via [`run`].
pub fn prepare(rt: &Runtime, spec: &RunSpec) -> Result<Option<PreparedRun>> {
    let (variant, params, base_lr, gmul, hp_v) = resolve(rt, spec)?;
    let inner = match rt
        .backend()
        .session_send(rt.manifest(), &variant, params)
        .with_context(|| {
            format!(
                "creating {} Send session for {}",
                rt.backend().name(),
                spec.variant
            )
        })? {
        Some(s) => s,
        None => return Ok(None),
    };
    Ok(Some(PreparedRun {
        spec: spec.clone(),
        core: SessionCore::new(variant, inner),
        base_lr,
        gmul,
        hp_v,
        ckpt: None,
        sink: None,
        key: None,
    }))
}

/// Execute a full training run (single-threaded path).
pub fn run(rt: &Runtime, spec: &RunSpec, data: &dyn DataSource) -> Result<RunResult> {
    run_ckpt(rt, spec, data, None)
}

/// [`run`] with a checkpoint policy: resumes from `ckpt.path` when it
/// holds usable state, snapshots every `ckpt.every` steps plus once at the
/// end.  `None` behaves exactly like [`run`].
pub fn run_ckpt(
    rt: &Runtime,
    spec: &RunSpec,
    data: &dyn DataSource,
    ckpt: Option<&CkptConfig>,
) -> Result<RunResult> {
    run_ckpt_with(rt, spec, data, ckpt, &StderrSink::quiet(), &spec.variant)
}

/// [`run_ckpt`] with an explicit event sink: progress, checkpoint and
/// warning events are emitted under the trial label `key` — how the sweep
/// scheduler and the serve daemon observe individual runs.
pub fn run_ckpt_with(
    rt: &Runtime,
    spec: &RunSpec,
    data: &dyn DataSource,
    ckpt: Option<&CkptConfig>,
    sink: &dyn EventSink,
    key: &str,
) -> Result<RunResult> {
    let (variant, params, base_lr, gmul, hp_v) = resolve(rt, spec)?;
    let inner = rt
        .backend()
        .session(rt.manifest(), &variant, params)
        .with_context(|| {
            format!("creating {} session for {}", rt.backend().name(), spec.variant)
        })?;
    let mut core = SessionCore::new(variant, inner);
    drive(&mut core, spec, &base_lr, &gmul, &hp_v, data, ckpt, sink, key)
}

/// Rebuild the outcome of a finished run straight from its end-of-run
/// snapshot (a crash landed between the final snapshot and the caller's
/// bookkeeping).  Wall time is the only field that cannot be restored.
fn result_from_snapshot(snap: &Snapshot) -> RunResult {
    RunResult {
        train_losses: snap.progress.train_losses.clone(),
        val_losses: snap.progress.val_losses.clone(),
        diverged: snap.progress.diverged,
        steps_done: snap.progress.steps_done,
        flops: snap.progress.flops,
        wall_secs: 0.0,
    }
}

/// Snapshot the session + run progress to `path` (tmp-then-rename).
/// Backends that decline state capture make this a no-op; returns whether
/// a snapshot was actually published (so callers can emit
/// [`Event::CheckpointWritten`] honestly).
fn write_snapshot<S: BackendSession + ?Sized>(
    core: &SessionCore<S>,
    spec: &RunSpec,
    result: &RunResult,
    complete: bool,
    path: &Path,
) -> Result<bool> {
    let state = match core.state()? {
        Some(s) => s,
        None => return Ok(false),
    };
    let progress = RunProgress {
        steps_done: result.steps_done,
        complete,
        diverged: result.diverged,
        flops: result.flops,
        train_losses: result.train_losses.clone(),
        val_losses: result.val_losses.clone(),
    };
    Snapshot::from_state(
        &core.variant,
        state,
        progress,
        spec.trajectory_fingerprint(),
        None,
    )?
    .save(path)?;
    Ok(true)
}

/// The step loop, generic over the session bound so the same code drives
/// both the sequential path (`dyn BackendSession`) and sweep worker
/// threads (`dyn BackendSession + Send`).  Identical specs produce
/// bitwise-identical results on either path — the parallel scheduler's
/// bit-exact-resume contract rests on this being the single loop.
///
/// With a [`CkptConfig`], the loop first tries to resume from the
/// snapshot file (restoring tensors, step counter, recorded loss curves
/// and FLOPs), then snapshots every `every` steps and once at the end.
/// Because the restore is bit-exact and the data substrates are pure in
/// (seed, split, step), the resumed trajectory is bitwise identical to an
/// uninterrupted run.  An unreadable or mismatched snapshot is *ignored*
/// with a warning (the run restarts from 0) — a crashed write can never
/// produce one thanks to tmp-then-rename, so this only fires on genuine
/// external corruption, where restarting is the honest fallback.
///
/// Progress flows through `sink` (DESIGN.md §9): warnings, one
/// [`Event::StepEval`] per recorded validation point, and one
/// [`Event::CheckpointWritten`] per published snapshot, all labelled
/// `key`.  The default sink ([`StderrSink::quiet`]) prints exactly the
/// warnings the loop used to `eprintln!`.
#[allow(clippy::too_many_arguments)]
fn drive<S: BackendSession + ?Sized>(
    core: &mut SessionCore<S>,
    spec: &RunSpec,
    base_lr: &[f32],
    gmul: &[f32],
    hp_v: &[f32; 8],
    data: &dyn DataSource,
    ckpt: Option<&CkptConfig>,
    sink: &dyn EventSink,
    key: &str,
) -> Result<RunResult> {
    let t0 = std::time::Instant::now();
    let flops_per_step = core.variant.flops_per_step();
    let mut result = RunResult {
        train_losses: Vec::with_capacity(spec.steps),
        val_losses: Vec::new(),
        diverged: false,
        steps_done: 0,
        flops: 0.0,
        wall_secs: 0.0,
    };
    let mut initial_loss = f64::NAN;
    let mut start = 0usize;
    if let Some(c) = ckpt {
        if c.path.exists() {
            match Snapshot::load(&c.path) {
                Ok(snap) => {
                    if let Err(e) = snap.validate_for(&core.variant) {
                        sink.emit(&Event::warning(
                            key,
                            format!("ignoring checkpoint {}: {e:#}", c.path.display()),
                        ));
                    } else if snap.spec_fp != spec.trajectory_fingerprint() {
                        sink.emit(&Event::warning(
                            key,
                            format!(
                                "checkpoint {} was written under a different run \
                                 configuration (hp/seed/schedule); restarting from step 0",
                                c.path.display()
                            ),
                        ));
                    } else if snap.progress.complete
                        && (snap.progress.diverged || snap.progress.steps_done == spec.steps)
                    {
                        let mut r = result_from_snapshot(&snap);
                        r.wall_secs = t0.elapsed().as_secs_f64();
                        return Ok(r);
                    } else if snap.progress.steps_done > spec.steps {
                        sink.emit(&Event::warning(
                            key,
                            format!(
                                "checkpoint {} is at step {} but only {} steps were requested; restarting fresh",
                                c.path.display(),
                                snap.progress.steps_done,
                                spec.steps
                            ),
                        ));
                    } else {
                        // take the progress out (loss curves are small),
                        // then move the tensors into the restore without a
                        // second full-model copy
                        let progress = snap.progress.clone();
                        if core.restore(&snap.into_model_state(), progress.steps_done)? {
                            start = progress.steps_done;
                            result.train_losses = progress.train_losses;
                            result.val_losses = progress.val_losses;
                            result.flops = progress.flops;
                            result.steps_done = start;
                            initial_loss =
                                result.train_losses.first().copied().unwrap_or(f64::NAN);
                        }
                        // restore declined (backend without the
                        // capability): fall through and run from step 0
                    }
                }
                Err(e) => sink.emit(&Event::warning(
                    key,
                    format!(
                        "ignoring unreadable checkpoint {}: {e:#}",
                        c.path.display()
                    ),
                )),
            }
        }
    }
    for step in start..spec.steps {
        let decay = spec.schedule.factor(step, spec.steps);
        let lr_vec: Vec<f32> = base_lr.iter().map(|&l| l * decay as f32).collect();
        let inputs = StepInputs {
            lr_vec,
            gmul_vec: gmul.to_vec(),
            hp_vec: *hp_v,
        };
        let batch = data.batch(Split::Train, step);
        // μ-coordinate telemetry (opt-in, see obs::coords): read-only
        // param snapshots around the step — the trajectory stays bitwise
        // identical with sampling on or off
        let coord_before = if coords::sample_step(step) {
            Some(snapshot_params(core))
        } else {
            None
        };
        let t_step = std::time::Instant::now();
        let loss = {
            let _sp = trace::span("train_step");
            core.step(&batch, &inputs)? as f64
        };
        metrics::STEP_LATENCY.observe_since(t_step);
        metrics::TRAIN_STEPS.inc();
        if let Some(before) = coord_before {
            let after = snapshot_params(core);
            let groups = coords::group_stats(&core.variant.params, &before, &after);
            metrics::COORD_SAMPLES.inc();
            sink.emit(&Event::CoordStats {
                key: key.to_string(),
                step,
                groups: groups
                    .iter()
                    .map(|g| (g.name.clone(), g.w_rms, g.upd_rms))
                    .collect(),
            });
        }
        result.flops += flops_per_step;
        result.train_losses.push(loss);
        result.steps_done = step + 1;
        if initial_loss.is_nan() {
            initial_loss = loss;
        }
        if !loss.is_finite() || loss > DIVERGE_ABS || loss > initial_loss * DIVERGE_FACTOR + 5.0 {
            result.diverged = true;
            break;
        }
        if spec.eval_every > 0 && (step + 1) % spec.eval_every == 0 {
            let v = eval(core, spec, data, hp_v)?;
            if !v.is_finite() {
                result.diverged = true;
                break;
            }
            result.val_losses.push((step + 1, v));
            sink.emit(&Event::StepEval {
                key: key.to_string(),
                step: step + 1,
                val_loss: v,
            });
        }
        if let Some(c) = ckpt {
            // mid-run snapshot, written after the step's eval so the
            // recorded curves are consistent with the tensors; the final
            // step is covered by the complete snapshot below
            if c.every > 0 && (step + 1) % c.every == 0 && step + 1 < spec.steps
                && write_snapshot(core, spec, &result, false, &c.path)?
            {
                sink.emit(&Event::CheckpointWritten {
                    key: key.to_string(),
                    step: step + 1,
                    path: c.path.to_string_lossy().into_owned(),
                });
            }
        }
    }
    // Always record a final val point for selection if eval was requested.
    if spec.eval_every > 0 && !result.diverged {
        let v = eval(core, spec, data, hp_v)?;
        if v.is_finite() {
            result.val_losses.push((result.steps_done, v));
            sink.emit(&Event::StepEval {
                key: key.to_string(),
                step: result.steps_done,
                val_loss: v,
            });
        } else {
            result.diverged = true;
        }
    }
    if let Some(c) = ckpt {
        if write_snapshot(core, spec, &result, true, &c.path)? {
            sink.emit(&Event::CheckpointWritten {
                key: key.to_string(),
                step: result.steps_done,
                path: c.path.to_string_lossy().into_owned(),
            });
        }
    }
    result.wall_secs = t0.elapsed().as_secs_f64();
    Ok(result)
}

/// Host-side copy of every parameter tensor (coord telemetry).  A tensor
/// the backend declines comes back empty, and `coords::group_stats` drops
/// it rather than failing the step.
fn snapshot_params<S: BackendSession + ?Sized>(core: &SessionCore<S>) -> Vec<Vec<f32>> {
    (0..core.variant.params.len())
        .map(|i| core.param(i).unwrap_or_default())
        .collect()
}

fn eval<S: BackendSession + ?Sized>(
    core: &SessionCore<S>,
    spec: &RunSpec,
    data: &dyn DataSource,
    hp_v: &[f32; 8],
) -> Result<f64> {
    let _sp = trace::span("eval");
    let mut acc = 0.0;
    for b in 0..spec.eval_batches {
        let batch = data.batch(Split::Val, b);
        let inputs = StepInputs {
            lr_vec: vec![],
            gmul_vec: vec![],
            hp_vec: *hp_v,
        };
        acc += core.eval(&batch, &inputs)? as f64;
    }
    Ok(acc / spec.eval_batches as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_train_loss_tail_mean() {
        let r = RunResult {
            train_losses: (0..20).map(|i| 20.0 - i as f64).collect(),
            val_losses: vec![],
            diverged: false,
            steps_done: 20,
            flops: 0.0,
            wall_secs: 0.0,
        };
        // last 2 losses: 2, 1 -> mean 1.5
        assert!((r.final_train_loss() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn diverged_run_is_nan() {
        let r = RunResult {
            train_losses: vec![1.0],
            val_losses: vec![(1, 0.5)],
            diverged: true,
            steps_done: 1,
            flops: 0.0,
            wall_secs: 0.0,
        };
        assert!(r.final_train_loss().is_nan());
        assert!(r.best_val_loss().is_nan());
    }

    #[test]
    fn best_val_picks_minimum() {
        let r = RunResult {
            train_losses: vec![1.0; 10],
            val_losses: vec![(5, 3.0), (10, 2.0), (15, 2.5)],
            diverged: false,
            steps_done: 15,
            flops: 0.0,
            wall_secs: 0.0,
        };
        assert_eq!(r.best_val_loss(), 2.0);
    }
}
