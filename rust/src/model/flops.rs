//! FLOPs accounting — the currency of every tuning-budget comparison in
//! the paper (§7.1 "controlling the total tuning budget in FLOPs",
//! Appendix F.4's 7% tuning-cost ratio).
//!
//! Uses the standard 6·N·D estimate (fwd 2ND + bwd 4ND) for token models;
//! the optimizer update adds O(N) per step, negligible at our D.
//!
//! Two tiers share this module (DESIGN.md §13):
//!
//! * the 6·N·D *budget estimate* (`training_flops`, `speedups`) — the
//!   paper's tuning-cost currency;
//! * the *exact GEMM inventory* ([`gemm_shapes`] / [`step_gemm_flops`] /
//!   [`flops_for_shape`]) — the profiler's single accounting source.
//!   The inventory enumerates precisely the kernel invocations that
//!   carry a `gemm` trace span (attention's fused softmax·V context is a
//!   fused kernel, not a GEMM span, and is deliberately absent), so the
//!   span-summed FLOPs of a profiled step must agree with
//!   `step_gemm_flops` exactly — `rust/tests/profile.rs` pins ≤ 1%.

use crate::model::{MlpConfig, ResMlpConfig, TfmConfig};
use crate::runtime::manifest::Arch;
use crate::runtime::Variant;

/// FLOPs for `steps` optimizer steps on a variant.
pub fn training_flops(v: &Variant, steps: usize) -> f64 {
    v.flops_per_step() * steps as f64
}

/// FLOPs of one `c(m,n) += a(m,k)·b(k,n)`-shaped contraction — 2·m·k·n
/// (one multiply + one add per inner element).  `(m, k, n)` are the
/// *effective* output-rows / contraction / output-cols extents, the same
/// normalization `trace::span_mnk` records for every kernel transpose
/// layout; this helper is the one place FLOPs-per-shape is defined.
#[inline]
pub fn flops_for_shape(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// One GEMM shape a train step issues, with its invocation count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub count: usize,
}

impl GemmShape {
    pub fn flops(&self) -> f64 {
        self.count as f64 * flops_for_shape(self.m, self.k, self.n)
    }
}

fn push(out: &mut Vec<GemmShape>, m: usize, k: usize, n: usize, count: usize) {
    if count == 0 {
        return;
    }
    if let Some(g) = out.iter_mut().find(|g| g.m == m && g.k == k && g.n == n) {
        g.count += count;
    } else {
        out.push(GemmShape { m, k, n, count });
    }
}

/// The exact GEMM inventory of ONE optimizer step (forward + backward),
/// mirroring the kernel call sites in `runtime/native/{transformer,
/// mlp}.rs` one for one.  Shapes are deduplicated with counts; order is
/// descending FLOPs is NOT guaranteed — sort at the presentation layer.
pub fn gemm_shapes(v: &Variant) -> Vec<GemmShape> {
    let mut out = Vec::new();
    match v.arch {
        Arch::Transformer => {
            let c = TfmConfig::from_variant(v);
            let (d, da, f, vo, s, dh) = (
                c.d_model,
                c.d_attn(),
                c.d_ffn,
                c.vocab,
                c.seq,
                c.d_head,
            );
            let rows = c.batch * s;
            let nbh = c.batch * c.n_head;
            let l = c.n_layer;
            // attention forward: q/k/v projections, per-head score
            // panels (softmax·V context is fused, not a GEMM), output
            // projection
            push(&mut out, rows, d, da, 3 * l);
            push(&mut out, s, dh, s, nbh * l);
            push(&mut out, rows, da, d, l);
            // FFN forward
            push(&mut out, rows, d, f, l);
            push(&mut out, rows, f, d, l);
            // unembed forward + backward
            push(&mut out, rows, d, vo, 1);
            push(&mut out, d, rows, vo, 1);
            push(&mut out, rows, vo, d, 1);
            // attention backward: WO grad + dmerged, per-head panels
            // (dprob, dV-grad, dQ, dK), then q/k/v weight + input grads
            push(&mut out, da, rows, d, l);
            push(&mut out, rows, d, da, l);
            push(&mut out, s, dh, s, nbh * l);
            push(&mut out, s, s, dh, 3 * nbh * l);
            push(&mut out, d, rows, da, 3 * l);
            push(&mut out, rows, da, d, 3 * l);
            // FFN backward: W2 grad, du, W1 grad, dh
            push(&mut out, f, rows, d, l);
            push(&mut out, rows, d, f, l);
            push(&mut out, d, rows, f, l);
            push(&mut out, rows, f, d, l);
        }
        Arch::Mlp => {
            let c = MlpConfig::from_variant(v);
            let (b, din, n, co) = (c.batch, c.d_in, c.width, c.d_out);
            // forward
            push(&mut out, b, din, n, 1);
            push(&mut out, b, n, n, 1);
            push(&mut out, b, n, co, 1);
            // backward
            push(&mut out, n, b, co, 1); // w3 grad
            push(&mut out, b, co, n, 1); // du2
            push(&mut out, n, b, n, 1); // w2 grad
            push(&mut out, b, n, n, 1); // du1
            push(&mut out, din, b, n, 1); // w1 grad
        }
        Arch::ResMlp => {
            let c = ResMlpConfig::from_variant(v);
            let (b, din, n, co, nb) = (c.batch, c.d_in, c.width, c.d_out, c.n_block);
            // forward: w_in, per-block w1/w2, w_out
            push(&mut out, b, din, n, 1);
            push(&mut out, b, n, n, 2 * nb);
            push(&mut out, b, n, co, 1);
            // backward: w_out grad, dhf, per-block (w2 grad, du, w1
            // grad, dz), w_in grad
            push(&mut out, n, b, co, 1);
            push(&mut out, b, co, n, 1);
            push(&mut out, n, b, n, 2 * nb);
            push(&mut out, b, n, n, 2 * nb);
            push(&mut out, din, b, n, 1);
        }
    }
    out
}

/// Exact GEMM FLOPs of one optimizer step — Σ over [`gemm_shapes`].
pub fn step_gemm_flops(v: &Variant) -> f64 {
    gemm_shapes(v).iter().map(|g| g.flops()).sum()
}

/// The Appendix F.4 cost ratio:
/// (proxy params · Σ_i tokens_i·trials_i) / (target params · target tokens).
/// Expressed here directly in FLOPs of the actual runs.
pub fn tuning_cost_ratio(search_flops: f64, target_training_flops: f64) -> f64 {
    search_flops / target_training_flops
}

/// Model/total speedup factors reported in Table 6:
/// *model speedup* = target step FLOPs / proxy step FLOPs,
/// *total speedup* additionally counts the step-count saving.
pub fn speedups(
    proxy: &Variant,
    target: &Variant,
    proxy_steps: usize,
    target_steps: usize,
) -> (f64, f64) {
    let model = target.flops_per_step() / proxy.flops_per_step();
    let total = model * target_steps as f64 / proxy_steps.max(1) as f64;
    (model, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{transformer_specs, TfmConfig};
    use crate::runtime::manifest::Kind;

    fn variant(d_model: usize) -> Variant {
        let c = TfmConfig {
            vocab: 64,
            seq: 32,
            batch: 16,
            d_model,
            n_layer: 2,
            n_head: 4,
            d_head: d_model / 4,
            d_ffn: 4 * d_model,
            pre_ln: true,
        };
        let mut v = Variant {
            name: format!("w{d_model}"),
            arch: crate::runtime::Arch::Transformer,
            kind: Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: transformer_specs(&c),
            golden: None,
        };
        v.config.fields.insert("batch".into(), 16.0);
        v.config.fields.insert("seq".into(), 32.0);
        v
    }

    #[test]
    fn flops_scale_with_width_squared_ish() {
        let small = variant(64);
        let big = variant(256);
        let ratio = big.flops_per_step() / small.flops_per_step();
        // hidden params dominate -> ~16x at 4x width (embeddings dilute it)
        assert!(ratio > 8.0 && ratio < 16.5, "ratio={ratio}");
    }

    #[test]
    fn cost_ratio_and_speedups() {
        let proxy = variant(64);
        let target = variant(256);
        let (model, total) = speedups(&proxy, &target, 100, 1000);
        assert!(model > 8.0);
        assert!((total / model - 10.0).abs() < 1e-9);
        let search = training_flops(&proxy, 100) * 64.0; // 64 samples
        let train = training_flops(&target, 1000);
        let r = tuning_cost_ratio(search, train);
        assert!(r > 0.0 && r < 1.5, "r={r}");
    }

    #[test]
    fn shape_flops_is_2mkn() {
        assert_eq!(flops_for_shape(3, 5, 7), 2.0 * 3.0 * 5.0 * 7.0);
        let g = GemmShape { m: 4, k: 2, n: 8, count: 3 };
        assert_eq!(g.flops(), 3.0 * flops_for_shape(4, 2, 8));
    }

    #[test]
    fn gemm_inventory_tracks_the_6nd_estimate() {
        // The exact inventory and the 6·N·D budget estimate measure
        // different things (6ND counts embedding params that never hit a
        // GEMM; the inventory adds attention panels that aren't
        // param-proportional) but must stay the same order of magnitude
        // and scale together with width.
        for d in [64usize, 256] {
            let v = variant(d);
            let exact = step_gemm_flops(&v);
            let est = v.flops_per_step();
            assert!(exact > 0.0);
            let ratio = exact / est;
            assert!(
                (0.2..5.0).contains(&ratio),
                "d={d}: exact {exact:.3e} vs 6ND {est:.3e} (ratio {ratio:.2})"
            );
        }
        let r = step_gemm_flops(&variant(256)) / step_gemm_flops(&variant(64));
        assert!(r > 8.0, "GEMM FLOPs must grow ~quadratically in width, got {r:.1}x");
    }

    #[test]
    fn gemm_inventory_dedupes_with_counts() {
        let v = variant(64);
        let shapes = gemm_shapes(&v);
        let mut seen = std::collections::BTreeSet::new();
        for g in &shapes {
            assert!(g.count > 0);
            assert!(seen.insert((g.m, g.k, g.n)), "duplicate shape {g:?}");
        }
        // qkv fwd (rows, d, da) appears for both layers under one entry
        let rows = 16 * 32;
        let qkv = shapes
            .iter()
            .find(|g| g.m == rows && g.k == 64 && g.n == 64)
            .expect("qkv projection shape present");
        assert!(qkv.count >= 6, "3 proj x 2 layers folded: {qkv:?}");
    }
}
