//! FLOPs accounting — the currency of every tuning-budget comparison in
//! the paper (§7.1 "controlling the total tuning budget in FLOPs",
//! Appendix F.4's 7% tuning-cost ratio).
//!
//! Uses the standard 6·N·D estimate (fwd 2ND + bwd 4ND) for token models;
//! the optimizer update adds O(N) per step, negligible at our D.

use crate::runtime::Variant;

/// FLOPs for `steps` optimizer steps on a variant.
pub fn training_flops(v: &Variant, steps: usize) -> f64 {
    v.flops_per_step() * steps as f64
}

/// The Appendix F.4 cost ratio:
/// (proxy params · Σ_i tokens_i·trials_i) / (target params · target tokens).
/// Expressed here directly in FLOPs of the actual runs.
pub fn tuning_cost_ratio(search_flops: f64, target_training_flops: f64) -> f64 {
    search_flops / target_training_flops
}

/// Model/total speedup factors reported in Table 6:
/// *model speedup* = target step FLOPs / proxy step FLOPs,
/// *total speedup* additionally counts the step-count saving.
pub fn speedups(
    proxy: &Variant,
    target: &Variant,
    proxy_steps: usize,
    target_steps: usize,
) -> (f64, f64) {
    let model = target.flops_per_step() / proxy.flops_per_step();
    let total = model * target_steps as f64 / proxy_steps.max(1) as f64;
    (model, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{transformer_specs, TfmConfig};
    use crate::runtime::manifest::Kind;

    fn variant(d_model: usize) -> Variant {
        let c = TfmConfig {
            vocab: 64,
            seq: 32,
            batch: 16,
            d_model,
            n_layer: 2,
            n_head: 4,
            d_head: d_model / 4,
            d_ffn: 4 * d_model,
            pre_ln: true,
        };
        let mut v = Variant {
            name: format!("w{d_model}"),
            arch: crate::runtime::Arch::Transformer,
            kind: Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: transformer_specs(&c),
            golden: None,
        };
        v.config.fields.insert("batch".into(), 16.0);
        v.config.fields.insert("seq".into(), 32.0);
        v
    }

    #[test]
    fn flops_scale_with_width_squared_ish() {
        let small = variant(64);
        let big = variant(256);
        let ratio = big.flops_per_step() / small.flops_per_step();
        // hidden params dominate -> ~16x at 4x width (embeddings dilute it)
        assert!(ratio > 8.0 && ratio < 16.5, "ratio={ratio}");
    }

    #[test]
    fn cost_ratio_and_speedups() {
        let proxy = variant(64);
        let target = variant(256);
        let (model, total) = speedups(&proxy, &target, 100, 1000);
        assert!(model > 8.0);
        assert!((total / model - 10.0).abs() < 1e-9);
        let search = training_flops(&proxy, 100) * 64.0; // 64 samples
        let train = training_flops(&target, 1000);
        let r = tuning_cost_ratio(search, train);
        assert!(r > 0.0 && r < 1.5, "r={r}");
    }
}
