//! Architecture specs on the Rust side.
//!
//! Mirrors `python/compile/model.py`'s parameter layouts exactly (same
//! names, shapes, roles, order) — integration tests assert the mirror
//! against every manifest entry.  Having the layout natively lets the
//! coordinator construct the *base shape* of any model analytically (the
//! μP base can be a shape we never lowered, e.g. the proxy width at the
//! target depth, per Appendix H's "recreate the base model shape at new
//! depths").

pub mod flops;

use crate::mup::{Role, TensorDims};
use crate::runtime::manifest::{ModelConfig, ParamInfo, Variant};

use std::collections::BTreeMap;

/// Transformer shape (decoder-only LM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TfmConfig {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    /// true = pre-LN
    pub pre_ln: bool,
}

impl TfmConfig {
    pub fn d_attn(&self) -> usize {
        self.n_head * self.d_head
    }

    pub fn from_variant(v: &Variant) -> TfmConfig {
        let c: &ModelConfig = &v.config;
        TfmConfig {
            vocab: c.req("vocab"),
            seq: c.req("seq"),
            batch: c.req("batch"),
            d_model: c.req("d_model"),
            n_layer: c.req("n_layer"),
            n_head: c.req("n_head"),
            d_head: c.req("d_head"),
            d_ffn: c.req("d_ffn"),
            pre_ln: v.config_str.get("ln").map(|s| s == "pre").unwrap_or(true),
        }
    }

    /// The μP base: shrink width-like dims to the proxy's, keep
    /// everything scale-like (depth, seq, batch, vocab) at the target's.
    pub fn with_widths(&self, d_model: usize, n_head: usize, d_head: usize, d_ffn: usize) -> TfmConfig {
        TfmConfig {
            d_model,
            n_head,
            d_head,
            d_ffn,
            ..*self
        }
    }
}

fn p(name: &str, shape: &[usize], role: Role, fan_in: usize, fan_out: usize, init: &str) -> ParamInfo {
    ParamInfo {
        name: name.to_string(),
        shape: shape.to_vec(),
        role,
        fan_in,
        fan_out,
        init: init.to_string(),
    }
}

/// Exact mirror of `compile.model.transformer_param_specs`.
pub fn transformer_specs(c: &TfmConfig) -> Vec<ParamInfo> {
    let (d, da, f, v, s) = (c.d_model, c.d_attn(), c.d_ffn, c.vocab, c.seq);
    let mut out = vec![
        p("embed", &[v, d], Role::Input, v, d, "normal"),
        p("pos_embed", &[s, d], Role::Input, s, d, "normal"),
    ];
    for i in 0..c.n_layer {
        let pre = format!("block{i}.");
        out.push(p(&format!("{pre}ln1_g"), &[d], Role::Vector, 1, d, "ones"));
        out.push(p(&format!("{pre}ln1_b"), &[d], Role::Vector, 1, d, "zeros"));
        out.push(p(&format!("{pre}wq"), &[d, da], Role::Hidden, d, da, "zeros"));
        out.push(p(&format!("{pre}wk"), &[d, da], Role::Hidden, d, da, "normal"));
        out.push(p(&format!("{pre}wv"), &[d, da], Role::Hidden, d, da, "normal"));
        out.push(p(&format!("{pre}wo"), &[da, d], Role::Hidden, da, d, "normal"));
        out.push(p(&format!("{pre}ln2_g"), &[d], Role::Vector, 1, d, "ones"));
        out.push(p(&format!("{pre}ln2_b"), &[d], Role::Vector, 1, d, "zeros"));
        out.push(p(&format!("{pre}w1"), &[d, f], Role::Hidden, d, f, "normal"));
        out.push(p(&format!("{pre}w2"), &[f, d], Role::Hidden, f, d, "normal"));
    }
    if c.pre_ln {
        out.push(p("lnf_g", &[d], Role::Vector, 1, d, "ones"));
        out.push(p("lnf_b", &[d], Role::Vector, 1, d, "zeros"));
    }
    out.push(p("unembed", &[d, v], Role::Output, d, v, "zeros"));
    out
}

/// MLP (Section 3 / Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlpConfig {
    pub d_in: usize,
    pub width: usize,
    pub d_out: usize,
    pub batch: usize,
}

impl MlpConfig {
    pub fn from_variant(v: &Variant) -> MlpConfig {
        MlpConfig {
            d_in: v.config.req("d_in"),
            width: v.config.req("width"),
            d_out: v.config.req("d_out"),
            batch: v.config.req("batch"),
        }
    }

    pub fn with_width(&self, width: usize) -> MlpConfig {
        MlpConfig { width, ..*self }
    }
}

pub fn mlp_specs(c: &MlpConfig) -> Vec<ParamInfo> {
    let n = c.width;
    vec![
        p("w1", &[c.d_in, n], Role::Input, c.d_in, n, "normal"),
        p("b1", &[n], Role::Vector, 1, n, "zeros"),
        p("w2", &[n, n], Role::Hidden, n, n, "normal"),
        p("b2", &[n], Role::Vector, 1, n, "zeros"),
        p("w3", &[n, c.d_out], Role::Output, n, c.d_out, "zeros"),
    ]
}

/// Residual MLP (ResNet stand-in, Tab. 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResMlpConfig {
    pub d_in: usize,
    pub width: usize,
    pub n_block: usize,
    pub d_out: usize,
    pub batch: usize,
}

impl ResMlpConfig {
    pub fn from_variant(v: &Variant) -> ResMlpConfig {
        ResMlpConfig {
            d_in: v.config.req("d_in"),
            width: v.config.req("width"),
            n_block: v.config.req("n_block"),
            d_out: v.config.req("d_out"),
            batch: v.config.req("batch"),
        }
    }

    pub fn with_width(&self, width: usize) -> ResMlpConfig {
        ResMlpConfig { width, ..*self }
    }
}

pub fn resmlp_specs(c: &ResMlpConfig) -> Vec<ParamInfo> {
    let n = c.width;
    let mut out = vec![p("w_in", &[c.d_in, n], Role::Input, c.d_in, n, "normal")];
    for i in 0..c.n_block {
        let pre = format!("block{i}.");
        out.push(p(&format!("{pre}ln_g"), &[n], Role::Vector, 1, n, "ones"));
        out.push(p(&format!("{pre}ln_b"), &[n], Role::Vector, 1, n, "zeros"));
        out.push(p(&format!("{pre}w1"), &[n, n], Role::Hidden, n, n, "normal"));
        out.push(p(&format!("{pre}w2"), &[n, n], Role::Hidden, n, n, "normal"));
    }
    out.push(p("ln_f_g", &[n], Role::Vector, 1, n, "ones"));
    out.push(p("ln_f_b", &[n], Role::Vector, 1, n, "zeros"));
    out.push(p("w_out", &[n, c.d_out], Role::Output, n, c.d_out, "zeros"));
    out
}

/// Rebuild the param layout for any manifest variant from its config —
/// must equal `variant.params` exactly (tested in rust/tests/).
pub fn specs_for_variant(v: &Variant) -> Vec<ParamInfo> {
    match v.arch {
        crate::runtime::Arch::Transformer => transformer_specs(&TfmConfig::from_variant(v)),
        crate::runtime::Arch::Mlp => mlp_specs(&MlpConfig::from_variant(v)),
        crate::runtime::Arch::ResMlp => resmlp_specs(&ResMlpConfig::from_variant(v)),
    }
}

/// The μP base shape for a target variant: a (possibly never-lowered)
/// spec list at proxy widths but target depth/seq/batch.
#[derive(Debug, Clone)]
pub enum BaseShape {
    /// base == target (makes μP degenerate to SP-at-this-width; used for
    /// SP baselines and the identity checks)
    SameAsTarget,
    /// transformer base widths
    Tfm {
        d_model: usize,
        n_head: usize,
        d_head: usize,
        d_ffn: usize,
    },
    /// mlp/resmlp base hidden width
    Width(usize),
}

/// Per-tensor dims (current + base fan in/out) for a variant under a base
/// shape; panics if the layouts diverge (they cannot, by construction).
pub fn tensor_dims(v: &Variant, base: &BaseShape) -> Vec<TensorDims> {
    let base_specs: Vec<ParamInfo> = match (v.arch, base) {
        (_, BaseShape::SameAsTarget) => v.params.clone(),
        (crate::runtime::Arch::Transformer, BaseShape::Tfm { d_model, n_head, d_head, d_ffn }) => {
            let c = TfmConfig::from_variant(v).with_widths(*d_model, *n_head, *d_head, *d_ffn);
            transformer_specs(&c)
        }
        (crate::runtime::Arch::Mlp, BaseShape::Width(n)) => {
            mlp_specs(&MlpConfig::from_variant(v).with_width(*n))
        }
        (crate::runtime::Arch::ResMlp, BaseShape::Width(n)) => {
            resmlp_specs(&ResMlpConfig::from_variant(v).with_width(*n))
        }
        (a, b) => panic!("base shape {b:?} does not apply to arch {a:?}"),
    };
    let by_name: BTreeMap<&str, &ParamInfo> =
        base_specs.iter().map(|s| (s.name.as_str(), s)).collect();
    v.params
        .iter()
        .map(|t| {
            let b = by_name
                .get(t.name.as_str())
                .unwrap_or_else(|| panic!("base shape missing tensor {}", t.name));
            TensorDims {
                fan_in: t.fan_in,
                fan_out: t.fan_out,
                base_fan_in: b.fan_in,
                base_fan_out: b.fan_out,
            }
        })
        .collect()
}

/// Does this tensor write the output of a residual branch?  The depth
/// transfer axis scales exactly these (branch-output multiplier 1/√r_L):
/// the attention projection `block*.wo` and the FFN/ResMLP second matmul
/// `block*.w2`.  Detected by name so the manifest layout (and its JSON
/// mirror test) stays untouched.
pub fn residual_out(name: &str) -> bool {
    name.contains("block") && (name.ends_with(".wo") || name.ends_with(".w2"))
}

/// Depth/batch axis ratios for a variant, given the run's base dims
/// (`None` = base equals target on that axis → ratio exactly 1.0).
/// Depth counts residual blocks: `n_layer` (transformer) or `n_block`
/// (ResMLP); the plain MLP has no residual depth and always reports 1.0.
pub fn scale_axes(
    v: &Variant,
    base_depth: Option<usize>,
    base_batch: Option<usize>,
) -> crate::mup::ScaleAxes {
    let depth = v.config.get("n_layer").or_else(|| v.config.get("n_block"));
    let depth_ratio = match (depth, base_depth) {
        (Some(l), Some(l0)) if l0 > 0 => l as f64 / l0 as f64,
        _ => 1.0,
    };
    let batch_ratio = match (v.config.get("batch"), base_batch) {
        (Some(b), Some(b0)) if b0 > 0 => b as f64 / b0 as f64,
        _ => 1.0,
    };
    crate::mup::ScaleAxes {
        depth_ratio,
        batch_ratio,
    }
}

/// d_head of the base shape (for the attention-scale multiplier).
pub fn base_d_head(v: &Variant, base: &BaseShape) -> usize {
    match base {
        BaseShape::SameAsTarget => v.config.get("d_head").unwrap_or(1),
        BaseShape::Tfm { d_head, .. } => *d_head,
        BaseShape::Width(_) => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TfmConfig {
        TfmConfig {
            vocab: 64,
            seq: 32,
            batch: 16,
            d_model: 128,
            n_layer: 2,
            n_head: 4,
            d_head: 32,
            d_ffn: 512,
            pre_ln: true,
        }
    }

    #[test]
    fn transformer_layout_counts() {
        let specs = transformer_specs(&cfg());
        // 2 emb + 2 layers * 10 + 2 final LN + unembed
        assert_eq!(specs.len(), 2 + 20 + 2 + 1);
        assert_eq!(specs[0].name, "embed");
        assert_eq!(specs.last().unwrap().name, "unembed");
        assert_eq!(specs.last().unwrap().role, Role::Output);
        // post-LN drops the final LN pair
        let mut c = cfg();
        c.pre_ln = false;
        assert_eq!(transformer_specs(&c).len(), 2 + 20 + 1);
    }

    #[test]
    fn wq_and_unembed_zero_init() {
        let specs = transformer_specs(&cfg());
        for s in &specs {
            if s.name.ends_with("wq") || s.name == "unembed" {
                assert_eq!(s.init, "zeros", "{}", s.name);
            }
        }
    }

    #[test]
    fn mlp_layout() {
        let c = MlpConfig {
            d_in: 256,
            width: 128,
            d_out: 10,
            batch: 64,
        };
        let specs = mlp_specs(&c);
        assert_eq!(specs.len(), 5);
        assert_eq!(specs[0].fan_in, 256);
        assert_eq!(specs[2].role, Role::Hidden);
        assert_eq!(specs[4].role, Role::Output);
    }

    #[test]
    fn base_dims_width_ratio() {
        // emulate a manifest variant at 4x width with a base at 1x
        let c4 = cfg();
        let mut v = Variant {
            name: "t".into(),
            arch: crate::runtime::Arch::Transformer,
            kind: crate::runtime::manifest::Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: transformer_specs(&c4),
            golden: None,
        };
        v.config.fields.insert("vocab".into(), 64.0);
        v.config.fields.insert("seq".into(), 32.0);
        v.config.fields.insert("batch".into(), 16.0);
        v.config.fields.insert("d_model".into(), 128.0);
        v.config.fields.insert("n_layer".into(), 2.0);
        v.config.fields.insert("n_head".into(), 4.0);
        v.config.fields.insert("d_head".into(), 32.0);
        v.config.fields.insert("d_ffn".into(), 512.0);
        v.config_str.insert("ln".into(), "pre".into());
        let base = BaseShape::Tfm {
            d_model: 32,
            n_head: 4,
            d_head: 8,
            d_ffn: 128,
        };
        let dims = tensor_dims(&v, &base);
        // embed: fan_in vocab (finite), fan_out width (ratio 4)
        assert_eq!(dims[0].fan_in, 64);
        assert_eq!(dims[0].base_fan_in, 64);
        assert!((dims[0].r_out() - 4.0).abs() < 1e-12);
        // hidden wk: both ratios 4
        let wk = &dims[4];
        assert!((wk.r_in() - 4.0).abs() < 1e-12);
        // unembed: fan_in ratio 4, fan_out vocab
        let un = dims.last().unwrap();
        assert!((un.r_in() - 4.0).abs() < 1e-12);
        assert_eq!(un.fan_out, 64);
        assert_eq!(base_d_head(&v, &base), 8);
    }

    #[test]
    fn residual_out_names() {
        assert!(residual_out("block0.wo"));
        assert!(residual_out("block11.w2"));
        assert!(!residual_out("block0.wq"));
        assert!(!residual_out("block0.w1"));
        assert!(!residual_out("w2")); // plain MLP: not a residual branch
        assert!(!residual_out("unembed"));
        assert!(!residual_out("w_out"));
        // every transformer/resmlp spec classifies exactly 2/1 per block
        let tfm = transformer_specs(&cfg());
        assert_eq!(tfm.iter().filter(|s| residual_out(&s.name)).count(), 4);
        let rm = resmlp_specs(&ResMlpConfig {
            d_in: 256,
            width: 64,
            n_block: 3,
            d_out: 10,
            batch: 64,
        });
        assert_eq!(rm.iter().filter(|s| residual_out(&s.name)).count(), 3);
    }

    #[test]
    fn scale_axes_ratios() {
        let c4 = cfg();
        let mut v = Variant {
            name: "t".into(),
            arch: crate::runtime::Arch::Transformer,
            kind: crate::runtime::manifest::Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: transformer_specs(&c4),
            golden: None,
        };
        v.config.fields.insert("n_layer".into(), 8.0);
        v.config.fields.insert("batch".into(), 32.0);
        let a = scale_axes(&v, Some(2), Some(8));
        assert_eq!(a.depth_ratio, 4.0);
        assert_eq!(a.batch_ratio, 4.0);
        // None (or matching) base dims are exactly 1.0
        let u = scale_axes(&v, None, None);
        assert_eq!(u, crate::mup::ScaleAxes::UNIT);
        let m = scale_axes(&v, Some(8), Some(32));
        assert_eq!(m, crate::mup::ScaleAxes::UNIT);
    }

    #[test]
    fn same_as_target_is_identity() {
        let specs = transformer_specs(&cfg());
        let v = Variant {
            name: "t".into(),
            arch: crate::runtime::Arch::Transformer,
            kind: crate::runtime::manifest::Kind::Train,
            opt: "adam".into(),
            hlo_path: "/dev/null".into(),
            config: Default::default(),
            config_str: Default::default(),
            data_inputs: vec![],
            n_state: 2,
            probes: vec![],
            params: specs,
            golden: None,
        };
        for d in tensor_dims(&v, &BaseShape::SameAsTarget) {
            assert!((d.r_in() - 1.0).abs() < 1e-12);
            assert!((d.r_out() - 1.0).abs() < 1e-12);
        }
    }
}
