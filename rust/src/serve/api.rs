//! REST/SSE routes over the job registry (DESIGN.md §9).
//!
//! | method | path                  | status            | body                       |
//! |--------|-----------------------|-------------------|----------------------------|
//! | POST   | /jobs                 | 201 / 400         | `{"id","name"}`            |
//! | GET    | /jobs                 | 200               | `{"jobs":[view…]}`         |
//! | GET    | /jobs/:id             | 200 / 404         | job view                   |
//! | GET    | /jobs/:id/results     | 200 / 404 / 409   | canonical results JSON     |
//! | GET    | /jobs/:id/journal     | 200 / 404         | last trial records, NDJSON |
//! | DELETE | /jobs/:id             | 200 / 404 / 409   | `{"id","state"}`           |
//! | GET    | /jobs/:id/events      | 200 / 404 (SSE)   | `id:`/`data:` event frames |
//! | GET    | /jobs/:id/metrics     | 200 / 400 / 404   | μ-coordinate samples       |
//! | GET    | /hp?width=&depth=&batch= | 200 / 400 / 404 | best transferred HPs     |
//! | GET    | /healthz              | 200 / 503         | uptime, job counts, slots  |
//! | GET    | /metrics              | 200               | Prometheus text exposition |
//! | GET    | /debug/metrics        | 200               | same registry, as JSON     |
//! | GET    | /debug/profile        | 200               | perf attribution since boot|
//!
//! `GET /jobs/:id/metrics` without query params answers the live ring
//! (last 256 samples) or the final `coords.json`; `?after=N` pages the
//! full persisted NDJSON history from step `N` inclusive — a full page
//! carries `next_after`, the cursor for the next call (how
//! `mutransfer watch --coords` replays history past the ring cap).
//!
//! `GET /hp` query params are each optional and echoed back (μP transfer
//! makes the answer shape-independent); an *unparseable* value
//! (`?width=abc`, `?depth=2.5`) is a 400, never silently ignored — a
//! client that mistyped a dimension must not mistake the global best for
//! a shape-specific answer.
//!
//! `GET /jobs/:id/results` query params: `path=a.b.0` answers with just
//! that value's raw slice (lazy scan, no tree build; unknown path → 404),
//! `nocache=1` bypasses the results byte cache.  `GET /jobs/:id/journal`
//! takes `tail=N` (default 10, cap 1000) and filters checkpoint records
//! out of the trial stream.
//!
//! Client-supplied job names are echoed back **verbatim** (full JSON
//! string escaping, surrogate pairs included — `util::json` round-trip
//! tests pin it).  Unknown paths are 404, known paths with the wrong
//! method 405.
//!
//! Every dispatch records a per-route request count and latency
//! histogram into [`crate::obs::metrics`]; `GET /healthz` answers 503
//! when an executor thread has died (the registry would accept jobs it
//! can never run).

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use super::daemon::{CancelOutcome, JobSpec, Registry};
use super::http::{self, error_json, Request};
use crate::obs::metrics;
use crate::util::json::{self, jstr, Json};

/// Classify a request onto one of the static route labels in
/// [`metrics::ROUTES`].  Unknown shapes map to `other` — never a
/// dynamically built label, so the metric cardinality stays fixed (the
/// `metric-names` lint enforces the same rule at record sites).
fn route_idx(method: &str, segs: &[&str]) -> usize {
    match (method, segs) {
        (_, ["healthz"]) => metrics::ROUTE_HEALTHZ,
        (_, ["metrics"]) => metrics::ROUTE_METRICS,
        (_, ["debug", "metrics"]) => metrics::ROUTE_DEBUG_METRICS,
        (_, ["debug", "profile"]) => metrics::ROUTE_DEBUG_PROFILE,
        ("POST", ["jobs"]) => metrics::ROUTE_JOBS_CREATE,
        (_, ["jobs"]) => metrics::ROUTE_JOBS_LIST,
        ("DELETE", ["jobs", _]) => metrics::ROUTE_JOB_DELETE,
        (_, ["jobs", _]) => metrics::ROUTE_JOB_GET,
        (_, ["jobs", _, "results"]) => metrics::ROUTE_JOB_RESULTS,
        (_, ["jobs", _, "journal"]) => metrics::ROUTE_JOB_JOURNAL,
        (_, ["jobs", _, "events"]) => metrics::ROUTE_JOB_EVENTS,
        (_, ["jobs", _, "metrics"]) => metrics::ROUTE_JOB_METRICS,
        (_, ["hp"]) => metrics::ROUTE_HP,
        _ => metrics::ROUTE_OTHER,
    }
}

/// The scalar-FMA roofline, measured once per process — the microbench
/// burns a few milliseconds, fine at boot-or-first-poll, not per poll.
fn peak_cached() -> f64 {
    static PEAK: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *PEAK.get_or_init(crate::obs::profile::measured_peak_flops)
}

/// Dispatch one request; returns whether the connection may be reused
/// (SSE streams and malformed exchanges always close).  `stop` is the
/// daemon's shutdown flag: long-lived SSE streams poll it so a shutdown
/// join never waits on a subscriber whose job is still running.
pub fn handle(
    reg: &std::sync::Arc<Registry>,
    req: &Request,
    w: &mut TcpStream,
    stop: &AtomicBool,
) -> bool {
    let keep = req.keep_alive();
    let t0 = Instant::now();
    let _sp = crate::obs::trace::span("http_handle");
    let segs: Vec<&str> = req
        .path
        .trim_matches('/')
        .split('/')
        .filter(|s| !s.is_empty())
        .collect();
    let idx = route_idx(req.method.as_str(), segs.as_slice());
    let ok = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => {
            let (body, healthy) = reg.health();
            http::respond_json(w, if healthy { 200 } else { 503 }, &body, keep)
        }
        ("GET", ["metrics"]) => http::respond(
            w,
            200,
            "text/plain; version=0.0.4",
            metrics::render_prometheus().as_bytes(),
            keep,
        ),
        ("GET", ["debug", "metrics"]) => {
            http::respond_json(w, 200, &metrics::render_json(), keep)
        }
        ("GET", ["debug", "profile"]) => {
            // perf attribution aggregated since boot (profile::enable()
            // at daemon start), with per-executor-slot thread labels
            let snap = crate::obs::profile::snapshot();
            let ctx = crate::report::perf::ProfileCtx {
                variant: None,
                steps: None,
                peak_flops: peak_cached(),
            };
            let rep = crate::report::perf::profile_report(&snap, &ctx);
            http::respond_json(w, 200, &rep.json, keep)
        }
        ("POST", ["jobs"]) => match json::parse(&req.body)
            .map_err(|e| e.to_string())
            .and_then(|j| JobSpec::from_json(&j).map_err(|e| format!("{e:#}")))
        {
            Ok(spec) => match reg.submit(spec.clone()) {
                Ok(id) => http::respond_json(
                    w,
                    201,
                    &Json::from_pairs(vec![("id", jstr(&id)), ("name", jstr(&spec.name))]),
                    keep,
                ),
                Err(e) => http::respond_json(w, 500, &error_json(500, &format!("{e:#}")), keep),
            },
            Err(msg) => http::respond_json(w, 400, &error_json(400, &msg), keep),
        },
        ("GET", ["jobs"]) => http::respond_json(w, 200, &reg.list(), keep),
        ("GET", ["jobs", id]) => match reg.view(id) {
            Some(v) => http::respond_json(w, 200, &v, keep),
            None => http::respond_json(w, 404, &error_json(404, "no such job"), keep),
        },
        ("GET", ["jobs", id, "results"]) => match reg.state(id) {
            None => http::respond_json(w, 404, &error_json(404, "no such job"), keep),
            Some(st) if st != super::daemon::JobState::Done => http::respond_json(
                w,
                409,
                &error_json(409, &format!("job is {}, results exist only for done jobs", st.as_str())),
                keep,
            ),
            Some(_) => {
                let nocache = req.query.contains_key("nocache");
                match reg.results_bytes(id, !nocache) {
                    None => http::respond_json(
                        w,
                        500,
                        &error_json(500, "results.json unreadable"),
                        keep,
                    ),
                    Some(bytes) => match req.query.get("path") {
                        // raw passthrough: the stored bytes ARE the
                        // canonical form; re-serializing could only risk
                        // drift
                        None => http::respond(w, 200, "application/json", &bytes, keep),
                        Some(path) if path.split('.').any(|s| s.is_empty()) => {
                            http::respond_json(w, 400, &error_json(400, "bad path"), keep)
                        }
                        Some(path) => {
                            // partial read: scan to the path, answer with
                            // just that value's raw slice
                            let doc = std::str::from_utf8(&bytes).ok();
                            match doc.map(|d| json::lazy::extract(d, path)) {
                                Some(Ok(Some(slice))) => {
                                    http::respond(w, 200, "application/json", slice.as_bytes(), keep)
                                }
                                Some(Ok(None)) => http::respond_json(
                                    w,
                                    404,
                                    &error_json(404, "no such path in results"),
                                    keep,
                                ),
                                _ => http::respond_json(
                                    w,
                                    500,
                                    &error_json(500, "results.json corrupt"),
                                    keep,
                                ),
                            }
                        }
                    },
                }
            }
        },
        ("GET", ["jobs", id, "journal"]) => {
            if reg.state(id).is_none() {
                http::respond_json(w, 404, &error_json(404, "no such job"), keep)
            } else {
                let tail: usize = req
                    .query
                    .get("tail")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(10)
                    .clamp(1, 1000);
                let text = std::fs::read_to_string(reg.job_dir(id).join("journal"))
                    .unwrap_or_default();
                // trial records only: checkpoint markers and torn tails
                // are bookkeeping, not progress — the lazy scan keeps
                // this O(bytes) with zero tree builds per poll
                let lines: Vec<&str> = text
                    .lines()
                    .filter(|l| !l.trim().is_empty())
                    .filter(|l| json::lazy::validate(l).is_ok())
                    .filter(|l| !matches!(json::lazy::extract(l, "ckpt"), Ok(Some(_))))
                    .collect();
                let start = lines.len().saturating_sub(tail);
                let mut body = lines.get(start..).unwrap_or(&[]).join("\n");
                if !body.is_empty() {
                    body.push('\n');
                }
                http::respond(w, 200, "application/x-ndjson", body.as_bytes(), keep)
            }
        }
        ("DELETE", ["jobs", id]) => match reg.cancel(id) {
            Ok(CancelOutcome::Cancelled) => http::respond_json(
                w,
                200,
                &Json::from_pairs(vec![("id", jstr(id)), ("state", jstr("cancelled"))]),
                keep,
            ),
            Ok(CancelOutcome::Deleted) => http::respond_json(
                w,
                200,
                &Json::from_pairs(vec![("id", jstr(id)), ("state", jstr("deleted"))]),
                keep,
            ),
            Ok(CancelOutcome::Running) => http::respond_json(
                w,
                409,
                &error_json(409, "job is running; running jobs cannot be cancelled"),
                keep,
            ),
            Ok(CancelOutcome::NotFound) => {
                http::respond_json(w, 404, &error_json(404, "no such job"), keep)
            }
            Err(e) => http::respond_json(w, 500, &error_json(500, &format!("{e:#}")), keep),
        },
        ("GET", ["jobs", id, "events"]) => {
            let r = stream_events(reg, req, id, w, stop);
            // SSE latency is the stream's lifetime — recorded under its
            // own route label so it cannot skew the request histograms.
            metrics::route(idx).record(t0);
            return r;
        }
        ("GET", ["jobs", id, "metrics"]) => match req.query.get("after") {
            // ?after=N pages the full persisted history (coords.ndjson)
            // from step N inclusive; without it, the live ring / final
            // coords.json snapshot answers as before.  Same strictness
            // rule as /hp: a malformed cursor is a 400, not the default.
            Some(v) => match v.parse::<u64>() {
                Ok(after) => match reg.coord_page(id, after) {
                    Some(page) => http::respond_json(w, 200, &page, keep),
                    None => http::respond_json(w, 404, &error_json(404, "no such job"), keep),
                },
                Err(_) => http::respond_json(
                    w,
                    400,
                    &error_json(
                        400,
                        &format!("query param after must be a non-negative integer, got {v:?}"),
                    ),
                    keep,
                ),
            },
            None => match reg.coord_metrics(id) {
                Some(samples) => http::respond_json(
                    w,
                    200,
                    &Json::from_pairs(vec![("id", jstr(id)), ("samples", samples)]),
                    keep,
                ),
                None => http::respond_json(w, 404, &error_json(404, "no such job"), keep),
            },
        },
        ("GET", ["hp"]) => {
            // strict parse: a present-but-malformed dimension is a 400.
            // The old `.and_then(|v| v.parse().ok())` silently collapsed
            // `?width=abc` to "no width" and answered the global best —
            // precisely the wrong response to a typo.
            let dim = |k: &str| -> Result<Option<usize>, String> {
                match req.query.get(k) {
                    None => Ok(None),
                    Some(v) => v
                        .parse::<usize>()
                        .map(Some)
                        .map_err(|_| format!("query param {k} must be a non-negative integer, got {v:?}")),
                }
            };
            match (dim("width"), dim("depth"), dim("batch")) {
                (Ok(width), Ok(depth), Ok(batch)) => match reg.best_hp(width, depth, batch) {
                    Some(ans) => http::respond_json(w, 200, &ans, keep),
                    None => http::respond_json(
                        w,
                        404,
                        &error_json(404, "no completed sweep has a non-diverged winner yet"),
                        keep,
                    ),
                },
                (Err(m), _, _) | (_, Err(m), _) | (_, _, Err(m)) => {
                    http::respond_json(w, 400, &error_json(400, &m), keep)
                }
            }
        }
        // known resources, wrong method
        (_, ["jobs"]) | (_, ["jobs", _]) | (_, ["jobs", _, "results"])
        | (_, ["jobs", _, "journal"]) | (_, ["jobs", _, "events"])
        | (_, ["jobs", _, "metrics"]) | (_, ["hp"]) | (_, ["healthz"])
        | (_, ["metrics"]) | (_, ["debug", "metrics"]) | (_, ["debug", "profile"]) => {
            http::respond_json(w, 405, &error_json(405, "method not allowed"), keep)
        }
        _ => http::respond_json(w, 404, &error_json(404, "no such route"), keep),
    };
    metrics::route(idx).record(t0);
    ok.is_ok() && keep
}

/// `GET /jobs/:id/events`: replay retained history from `?after=SEQ` (or
/// the standard `Last-Event-ID` header), then stream live events.  The
/// stream ends when the job's bus closes (terminal state), the client
/// disconnects, or the daemon begins shutting down; idle gaps carry
/// `: ping` comments so dead peers are noticed.  Always closes the
/// connection (SSE has no length framing).
fn stream_events(
    reg: &std::sync::Arc<Registry>,
    req: &Request,
    id: &str,
    w: &mut TcpStream,
    stop: &AtomicBool,
) -> bool {
    let Some(bus) = reg.bus(id) else {
        let _ = http::respond_json(w, 404, &error_json(404, "no such job"), false);
        return false;
    };
    let after: u64 = req
        .query
        .get("after")
        .map(|s| s.as_str())
        .or_else(|| req.header("last-event-id"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let rx = bus.subscribe(after);
    if http::sse_headers(w).is_err() {
        return false;
    }
    let _sub = metrics::SSE_SUBSCRIBERS.guard();
    loop {
        match rx.recv_timeout(Duration::from_millis(500)) {
            Ok((seq, ev)) => {
                if http::sse_event(w, seq, &ev.to_json()).is_err() {
                    break; // client went away
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                // stream pinning a pool worker must not block shutdown
                if stop.load(Ordering::SeqCst) || http::sse_ping(w).is_err() {
                    break;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break, // job over
        }
    }
    let _ = w.shutdown(std::net::Shutdown::Both);
    false
}
