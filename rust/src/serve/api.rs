//! REST/SSE routes over the job registry (DESIGN.md §9).
//!
//! | method | path                  | status            | body                       |
//! |--------|-----------------------|-------------------|----------------------------|
//! | POST   | /jobs                 | 201 / 400         | `{"id","name"}`            |
//! | GET    | /jobs                 | 200               | `{"jobs":[view…]}`         |
//! | GET    | /jobs/:id             | 200 / 404         | job view                   |
//! | GET    | /jobs/:id/results     | 200 / 404 / 409   | canonical results JSON     |
//! | DELETE | /jobs/:id             | 200 / 404 / 409   | `{"id","state"}`           |
//! | GET    | /jobs/:id/events      | 200 / 404 (SSE)   | `id:`/`data:` event frames |
//! | GET    | /hp?width=N           | 200 / 404         | best transferred HPs       |
//! | GET    | /healthz              | 200               | `{"ok":true}`              |
//!
//! Client-supplied job names are echoed back **verbatim** (full JSON
//! string escaping, surrogate pairs included — `util::json` round-trip
//! tests pin it).  Unknown paths are 404, known paths with the wrong
//! method 405.

use std::net::TcpStream;
use std::time::Duration;

use super::daemon::{CancelOutcome, JobSpec, Registry};
use super::http::{self, error_json, Request};
use crate::util::json::{self, jstr, Json};

/// Dispatch one request; returns whether the connection may be reused
/// (SSE streams and malformed exchanges always close).
pub fn handle(reg: &std::sync::Arc<Registry>, req: &Request, w: &mut TcpStream) -> bool {
    let keep = req.keep_alive();
    let segs: Vec<&str> = req
        .path
        .trim_matches('/')
        .split('/')
        .filter(|s| !s.is_empty())
        .collect();
    let ok = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => http::respond_json(
            w,
            200,
            &Json::from_pairs(vec![("ok", Json::Bool(true))]),
            keep,
        ),
        ("POST", ["jobs"]) => match json::parse(&req.body)
            .map_err(|e| e.to_string())
            .and_then(|j| JobSpec::from_json(&j).map_err(|e| format!("{e:#}")))
        {
            Ok(spec) => match reg.submit(spec.clone()) {
                Ok(id) => http::respond_json(
                    w,
                    201,
                    &Json::from_pairs(vec![("id", jstr(&id)), ("name", jstr(&spec.name))]),
                    keep,
                ),
                Err(e) => http::respond_json(w, 500, &error_json(500, &format!("{e:#}")), keep),
            },
            Err(msg) => http::respond_json(w, 400, &error_json(400, &msg), keep),
        },
        ("GET", ["jobs"]) => http::respond_json(w, 200, &reg.list(), keep),
        ("GET", ["jobs", id]) => match reg.view(id) {
            Some(v) => http::respond_json(w, 200, &v, keep),
            None => http::respond_json(w, 404, &error_json(404, "no such job"), keep),
        },
        ("GET", ["jobs", id, "results"]) => match reg.state(id) {
            None => http::respond_json(w, 404, &error_json(404, "no such job"), keep),
            Some(st) if st != super::daemon::JobState::Done => http::respond_json(
                w,
                409,
                &error_json(409, &format!("job is {}, results exist only for done jobs", st.as_str())),
                keep,
            ),
            Some(_) => match reg.results_raw(id) {
                // raw passthrough: the stored bytes ARE the canonical
                // form; re-serializing could only risk drift
                Some(raw) => http::respond(w, 200, "application/json", raw.as_bytes(), keep),
                None => http::respond_json(w, 500, &error_json(500, "results.json unreadable"), keep),
            },
        },
        ("DELETE", ["jobs", id]) => match reg.cancel(id) {
            Ok(CancelOutcome::Cancelled) => http::respond_json(
                w,
                200,
                &Json::from_pairs(vec![("id", jstr(id)), ("state", jstr("cancelled"))]),
                keep,
            ),
            Ok(CancelOutcome::Deleted) => http::respond_json(
                w,
                200,
                &Json::from_pairs(vec![("id", jstr(id)), ("state", jstr("deleted"))]),
                keep,
            ),
            Ok(CancelOutcome::Running) => http::respond_json(
                w,
                409,
                &error_json(409, "job is running; running jobs cannot be cancelled"),
                keep,
            ),
            Ok(CancelOutcome::NotFound) => {
                http::respond_json(w, 404, &error_json(404, "no such job"), keep)
            }
            Err(e) => http::respond_json(w, 500, &error_json(500, &format!("{e:#}")), keep),
        },
        ("GET", ["jobs", id, "events"]) => return stream_events(reg, req, id, w),
        ("GET", ["hp"]) => {
            let width = req.query.get("width").and_then(|v| v.parse().ok());
            match reg.best_hp(width) {
                Some(ans) => http::respond_json(w, 200, &ans, keep),
                None => http::respond_json(
                    w,
                    404,
                    &error_json(404, "no completed sweep has a non-diverged winner yet"),
                    keep,
                ),
            }
        }
        // known resources, wrong method
        (_, ["jobs"]) | (_, ["jobs", _]) | (_, ["jobs", _, "results"])
        | (_, ["jobs", _, "events"]) | (_, ["hp"]) | (_, ["healthz"]) => {
            http::respond_json(w, 405, &error_json(405, "method not allowed"), keep)
        }
        _ => http::respond_json(w, 404, &error_json(404, "no such route"), keep),
    };
    ok.is_ok() && keep
}

/// `GET /jobs/:id/events`: replay retained history from `?after=SEQ` (or
/// the standard `Last-Event-ID` header), then stream live events.  The
/// stream ends when the job's bus closes (terminal state) or the client
/// disconnects; idle gaps carry `: ping` comments so dead peers are
/// noticed.  Always closes the connection (SSE has no length framing).
fn stream_events(
    reg: &std::sync::Arc<Registry>,
    req: &Request,
    id: &str,
    w: &mut TcpStream,
) -> bool {
    let Some(bus) = reg.bus(id) else {
        let _ = http::respond_json(w, 404, &error_json(404, "no such job"), false);
        return false;
    };
    let after: u64 = req
        .query
        .get("after")
        .map(|s| s.as_str())
        .or_else(|| req.header("last-event-id"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let rx = bus.subscribe(after);
    if http::sse_headers(w).is_err() {
        return false;
    }
    loop {
        match rx.recv_timeout(Duration::from_millis(500)) {
            Ok((seq, ev)) => {
                if http::sse_event(w, seq, &ev.to_json()).is_err() {
                    break; // client went away
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if http::sse_ping(w).is_err() {
                    break;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break, // job over
        }
    }
    let _ = w.shutdown(std::net::Shutdown::Both);
    false
}
