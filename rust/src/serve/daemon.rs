//! The tuning-service daemon: a durable job registry + work queue feeding
//! N executor slots (trial work divided fairly across running jobs by a
//! shared [`crate::util::pool::FairBudget`]), fronted by the REST/SSE API
//! in [`super::api`] over a bounded connection worker pool (DESIGN.md §9).
//!
//! Durability model — everything the daemon must not lose lives on disk
//! under `--state-dir`, published with the same crash-consistency rules
//! the rest of the repo already enforces:
//!
//! ```text
//! state_dir/jobs/<id>/spec.json      job spec      (tmp-then-rename at submit)
//! state_dir/jobs/<id>/journal        sweep journal (append + fdatasync per trial)
//! state_dir/jobs/<id>/ckpt/          trial snapshots (tmp-then-rename)
//! state_dir/jobs/<id>/results.json   canonical outcome (tmp-then-rename)
//! state_dir/jobs/<id>/state.json     terminal state only (tmp-then-rename)
//! ```
//!
//! `Running` is deliberately **not** persisted: a SIGKILLed daemon
//! restarted with the same `--state-dir` re-scans `jobs/`, re-queues every
//! job without a terminal `state.json` in id order, and the PR-4 journal +
//! checkpoint machinery makes the re-run skip completed trials and resume
//! interrupted ones mid-flight — so a killed daemon finishes its queue
//! with bit-identical results and no recomputation (pinned by the CI
//! daemon end-to-end step and `rust/tests/serve_e2e.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::model::BaseShape;
use crate::mup::{Optimizer, Scheme};
use crate::obs::coords::{self, CoordRing};
use crate::obs::metrics;
use crate::runtime::Runtime;
use crate::serve::events::{Event, EventBus, EventSink, StderrSink};
use crate::sweep::Sweep;
use crate::train::Schedule;
use crate::transfer::{mu_transfer, tune_only, TransferSetup, TunerKind};
use crate::tuner::SearchSpace;
use crate::util::fsio::write_atomic;
use crate::util::json::{self, jnum, jstr, Json};
use crate::util::pool;

/// The journal/result key label every daemon job runs under.  Pinned to
/// the offline CLI's label so a daemon-run sweep and `mutransfer transfer`
/// produce byte-comparable journals and identical `results.json` bytes —
/// the CI end-to-end step diffs exactly that.
pub const JOB_LABEL: &str = "cli";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// tune the proxy only (serve the winner through `GET /hp`)
    Sweep,
    /// full Algorithm 1: tune the proxy, run the target zero-shot
    Transfer,
}

impl JobKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobKind::Sweep => "sweep",
            JobKind::Transfer => "transfer",
        }
    }

    pub fn parse(s: &str) -> Result<JobKind> {
        match s {
            "sweep" => Ok(JobKind::Sweep),
            "transfer" => Ok(JobKind::Transfer),
            other => bail!("job kind must be sweep|transfer, got {other}"),
        }
    }
}

/// A submitted tuning job — the JSON body of `POST /jobs`, persisted
/// verbatim as `spec.json`.  [`JobSpec::setup`] is the **single** place a
/// spec becomes a [`TransferSetup`]; the offline `mutransfer transfer`
/// CLI routes through it too, which is what makes a daemon job
/// bit-identical to the same sweep run offline.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// client-supplied display name, echoed back verbatim by the API
    pub name: String,
    pub kind: JobKind,
    pub proxy: String,
    pub target: String,
    pub base_width: usize,
    pub samples: usize,
    pub steps: usize,
    pub target_steps: usize,
    pub seed: u64,
    /// sweep worker threads; 0 = auto (`MUTRANSFER_WORKERS` or 1)
    pub workers: usize,
    pub tuner: TunerKind,
    /// mid-trial snapshot cadence; 0 with a non-SHA tuner = no checkpoints
    pub ckpt_every: usize,
    /// which parametrization the tuned/transferred runs use (`sp` is the
    /// no-transfer baseline; `mup`/`umup` transfer)
    pub param: Scheme,
    /// base depth (layers/blocks) for the depth transfer axis; 0 = same
    /// as the target, i.e. no depth scaling
    pub base_depth: usize,
    /// base batch size for the batch transfer axis; 0 = same as target
    pub base_batch: usize,
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec {
            name: String::new(),
            kind: JobKind::Transfer,
            proxy: "tfm_post_w64_d2".into(),
            target: "tfm_post_w256_d2".into(),
            base_width: 64,
            samples: 12,
            steps: 40,
            target_steps: 120,
            seed: 0,
            workers: 0,
            tuner: TunerKind::Random,
            ckpt_every: 0,
            param: Scheme::Mup,
            base_depth: 0,
            base_batch: 0,
        }
    }
}

impl JobSpec {
    /// SHA defaults shared by the JSON decoder and the CLI flag parser —
    /// one source, so `--tuner sha` without `--eta/--rung0` and a JSON
    /// body without those fields always mean the same job.
    pub fn default_eta() -> usize {
        2
    }

    pub fn default_rung0(steps: usize) -> usize {
        (steps / 4).max(1)
    }

    /// Validate a directly-constructed spec by round-tripping it through
    /// the canonical JSON codec: the CLI routes here so `transfer` and
    /// `submit` accept exactly the specs `POST /jobs` accepts — same
    /// checks, same errors, no drift.
    pub fn validated(self) -> Result<JobSpec> {
        JobSpec::from_json(&self.to_json())
    }

    pub fn to_json(&self) -> Json {
        let (tuner, eta, rung0) = match &self.tuner {
            TunerKind::Random => ("random", 0, 0),
            TunerKind::Grid => ("grid", 0, 0),
            TunerKind::Sha { eta, rung0 } => ("sha", *eta, *rung0),
        };
        Json::from_pairs(vec![
            ("name", jstr(&self.name)),
            ("kind", jstr(self.kind.as_str())),
            ("proxy", jstr(&self.proxy)),
            ("target", jstr(&self.target)),
            ("base_width", jnum(self.base_width as f64)),
            ("samples", jnum(self.samples as f64)),
            ("steps", jnum(self.steps as f64)),
            ("target_steps", jnum(self.target_steps as f64)),
            // string, not number: our JSON numbers are f64, which cannot
            // round-trip u64 seeds above 2^53 exactly
            ("seed", jstr(&self.seed.to_string())),
            ("workers", jnum(self.workers as f64)),
            ("tuner", jstr(tuner)),
            ("eta", jnum(eta as f64)),
            ("rung0", jnum(rung0 as f64)),
            ("ckpt_every", jnum(self.ckpt_every as f64)),
            ("param", jstr(self.param.name())),
            ("base_depth", jnum(self.base_depth as f64)),
            ("base_batch", jnum(self.base_batch as f64)),
        ])
    }

    /// Parse and validate a client-submitted spec.  Missing fields take
    /// the defaults; out-of-range values are a hard error (the API turns
    /// it into a 400).
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let d = JobSpec::default();
        let s = |k: &str, dv: &str| -> String {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .unwrap_or_else(|| dv.to_string())
        };
        let u = |k: &str, dv: usize| -> Result<usize> {
            match j.get(k) {
                None | Some(Json::Null) => Ok(dv),
                Some(v) => v
                    .as_f64()
                    // whole numbers only: 24.9 must be a 400, not a
                    // silently-executed steps=24
                    .filter(|f| f.is_finite() && *f >= 0.0 && f.fract() == 0.0)
                    .map(|f| f as usize)
                    .with_context(|| format!("field {k} must be a non-negative integer")),
            }
        };
        let name = s("name", &d.name);
        if name.chars().count() > 256 {
            bail!("name exceeds 256 characters");
        }
        let steps = u("steps", d.steps)?;
        let rung0 = u("rung0", JobSpec::default_rung0(steps))?;
        let eta = u("eta", JobSpec::default_eta())?;
        // same validation run_sha applies offline: a spec the CLI would
        // reject must be a 400 here, never a silently-rewritten job
        let tuner = match s("tuner", "random").as_str() {
            "random" => TunerKind::Random,
            "grid" => TunerKind::Grid,
            "sha" => {
                if eta < 2 {
                    bail!("sha needs eta >= 2, got {eta}");
                }
                if rung0 == 0 || rung0 > steps {
                    bail!("sha needs 1 <= rung0 <= steps, got rung0={rung0} steps={steps}");
                }
                TunerKind::Sha { eta, rung0 }
            }
            other => bail!("tuner must be random|grid|sha, got {other}"),
        };
        // seed accepts a string (exact u64) or a number (exact below 2^53)
        let seed = match j.get("seed") {
            None | Some(Json::Null) => d.seed,
            Some(Json::Str(text)) => text
                .parse::<u64>()
                .ok()
                .with_context(|| format!("field seed must be a u64, got {text:?}"))?,
            Some(v) => v
                .as_f64()
                .filter(|f| f.is_finite() && *f >= 0.0 && f.fract() == 0.0 && *f <= 9e15)
                .map(|f| f as u64)
                .context("field seed must be a non-negative integer (send as string beyond 2^53)")?,
        };
        let param = {
            let text = s("param", d.param.name());
            Scheme::parse(&text)
                .with_context(|| format!("param must be sp|mup|umup, got {text:?}"))?
        };
        let spec = JobSpec {
            name,
            kind: JobKind::parse(&s("kind", d.kind.as_str()))?,
            proxy: s("proxy", &d.proxy),
            target: s("target", &d.target),
            base_width: u("base_width", d.base_width)?,
            samples: u("samples", d.samples)?,
            steps,
            target_steps: u("target_steps", d.target_steps)?,
            seed,
            workers: u("workers", d.workers)?,
            tuner,
            ckpt_every: u("ckpt_every", d.ckpt_every)?,
            param,
            base_depth: u("base_depth", d.base_depth)?,
            base_batch: u("base_batch", d.base_batch)?,
        };
        if spec.steps == 0 || spec.samples == 0 {
            bail!("steps and samples must be >= 1");
        }
        if spec.base_width == 0 || spec.base_width % 4 != 0 {
            bail!("base_width must be a positive multiple of 4 (n_head = 4)");
        }
        if spec.kind == JobKind::Transfer && spec.target_steps == 0 {
            bail!("transfer jobs need target_steps >= 1");
        }
        Ok(spec)
    }

    /// The one spec→setup mapping (mirrored exactly by nothing else: the
    /// CLI `transfer` subcommand builds a `JobSpec` and calls this too).
    pub fn setup(&self) -> TransferSetup {
        TransferSetup {
            proxy_variant: self.proxy.clone(),
            target_variant: self.target.clone(),
            base: BaseShape::Tfm {
                d_model: self.base_width,
                n_head: 4,
                d_head: self.base_width / 4,
                d_ffn: 4 * self.base_width,
            },
            optimizer: Optimizer::Adam,
            scheme: self.param,
            base_depth: (self.base_depth > 0).then_some(self.base_depth),
            base_batch: (self.base_batch > 0).then_some(self.base_batch),
            space: SearchSpace::iwslt_like(),
            proxy_steps: self.steps,
            target_steps: self.target_steps,
            n_samples: self.samples,
            seed: self.seed,
            eval_every: (self.steps / 2).max(2),
            schedule: Schedule::Constant,
            tuner: self.tuner.clone(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

struct JobEntry {
    spec: JobSpec,
    state: JobState,
    error: Option<String>,
    bus: Arc<EventBus>,
    /// `(winning val loss, assignment)` of a done job, cached so `GET /hp`
    /// never re-reads results documents off disk per request
    best: Option<(f64, Json)>,
}

/// Pull the `/hp`-relevant facts out of a results document.
fn extract_best(results: &Json) -> Option<(f64, Json)> {
    let assignment = results.get("best").filter(|b| !b.is_null())?;
    let loss = results
        .get("best_val_loss")
        .and_then(|v| v.as_f64())
        .filter(|l| l.is_finite())?;
    Some((loss, assignment.clone()))
}

/// [`extract_best`] from raw document *text*, building a tree only for
/// the (small) winning assignment — the startup scan reads every done
/// job's results.json, and those documents are dominated by loss curves
/// the `/hp` answer never touches.
fn lazy_best(text: &str) -> Option<(f64, Json)> {
    let assignment = json::lazy::extract(text, "best").ok()??;
    if assignment == "null" {
        return None;
    }
    let loss: f64 = json::lazy::extract(text, "best_val_loss")
        .ok()??
        .parse()
        .ok()
        .filter(|l: &f64| l.is_finite())?;
    Some((loss, json::parse(assignment).ok()?))
}

struct RegInner {
    jobs: BTreeMap<String, JobEntry>,
    queue: VecDeque<String>,
    next_id: u64,
}

/// What `DELETE /jobs/:id` did.
#[derive(Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// queued job → terminal `cancelled` (persisted)
    Cancelled,
    /// finished job → its record and artifacts were removed
    Deleted,
    /// running jobs cannot be interrupted (409)
    Running,
    NotFound,
}

/// Sizing knobs for [`Daemon::start_cfg`].  [`Daemon::start`] uses the
/// defaults; `mutransfer serve` exposes each field as a CLI flag.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// HTTP worker threads servicing the pooled connections
    pub http_workers: usize,
    /// executor slots — jobs running concurrently
    pub exec_slots: usize,
    /// total trial-worker budget shared max-min fairly across running
    /// jobs; 0 = auto (the machine's available parallelism)
    pub worker_budget: usize,
    /// open-connection cap; beyond it the acceptor answers `503`
    pub max_conns: usize,
    /// LRU byte budget for the in-memory results cache
    pub cache_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            http_workers: 8,
            exec_slots: 2,
            worker_budget: 0,
            max_conns: 1024,
            cache_bytes: 32 * 1024 * 1024,
        }
    }
}

/// In-memory LRU byte cache of terminal results documents, keyed by job
/// id.  Serialization + disk I/O happen once per completed job; every
/// later `GET /jobs/:id/results` is a map lookup and an `Arc` clone.
/// Entries are evicted least-recently-touched-first once the byte budget
/// is exceeded; a document larger than the whole budget is simply never
/// cached (served from disk each time rather than thrashing the cache).
struct ResultCache {
    budget: usize,
    inner: Mutex<CacheInner>,
}

#[derive(Default)]
struct CacheInner {
    entries: BTreeMap<String, CacheEntry>,
    total: usize,
    clock: u64,
}

struct CacheEntry {
    bytes: Arc<Vec<u8>>,
    tick: u64,
}

impl ResultCache {
    fn new(budget: usize) -> ResultCache {
        ResultCache {
            budget,
            inner: Mutex::new(CacheInner::default()),
        }
    }

    fn get(&self, id: &str) -> Option<Arc<Vec<u8>>> {
        let mut c = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        c.clock += 1;
        let now = c.clock;
        match c.entries.get_mut(id) {
            Some(e) => {
                e.tick = now;
                metrics::CACHE_HITS.inc();
                Some(e.bytes.clone())
            }
            None => {
                metrics::CACHE_MISSES.inc();
                None
            }
        }
    }

    fn put(&self, id: &str, bytes: Arc<Vec<u8>>) {
        if bytes.len() > self.budget {
            return;
        }
        let mut c = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        c.clock += 1;
        let tick = c.clock;
        let len = bytes.len();
        if let Some(old) = c.entries.insert(id.to_string(), CacheEntry { bytes, tick }) {
            c.total -= old.bytes.len();
        }
        c.total += len;
        while c.total > self.budget {
            let victim = c
                .entries
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            if let Some(e) = c.entries.remove(&k) {
                c.total -= e.bytes.len();
                metrics::CACHE_EVICTIONS.inc();
            }
        }
        metrics::CACHE_BYTES.set(c.total as i64);
    }

    fn invalidate(&self, id: &str) {
        let mut c = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = c.entries.remove(id) {
            c.total -= e.bytes.len();
        }
        metrics::CACHE_BYTES.set(c.total as i64);
    }
}

/// Durable job registry: the single source of truth the HTTP handlers and
/// the executors share.  All mutation happens under one mutex; filesystem
/// writes are tmp-then-rename so a crash at any instant leaves either the
/// old or the new contents, never a torn file.
pub struct Registry {
    state_dir: PathBuf,
    inner: Mutex<RegInner>,
    work: Condvar,
    cache: ResultCache,
    /// daemon start time — `GET /healthz` uptime
    started: Instant,
    /// executor slots the daemon spawned / still alive: `healthz` answers
    /// 503 when `live < expected` (the registry would accept jobs it can
    /// never run).  Bare registries (tests, CLI) leave both at 0.
    exec_expected: AtomicUsize,
    exec_live: AtomicUsize,
    /// per-live-job ring of μ-coordinate samples ([`coords::RING_CAP`]);
    /// drained to `coords.json` at `finish` so `GET /jobs/:id/metrics`
    /// answers for terminal jobs too
    coords: Mutex<BTreeMap<String, CoordRing>>,
}

impl Registry {
    pub fn open(state_dir: &Path) -> Result<Arc<Registry>> {
        Self::open_cfg(state_dir, ServeConfig::default().cache_bytes)
    }

    pub fn open_cfg(state_dir: &Path, cache_bytes: usize) -> Result<Arc<Registry>> {
        Self::open_logged(state_dir, cache_bytes, &StderrSink::quiet())
    }

    /// [`Registry::open_cfg`] with an explicit sink for operational log
    /// events (unloadable-job skips).  The daemon routes these through the
    /// event bus (DESIGN.md §11.4); the `StderrSink` default preserves the
    /// old stderr lines for direct callers.
    pub fn open_logged(
        state_dir: &Path,
        cache_bytes: usize,
        log: &dyn EventSink,
    ) -> Result<Arc<Registry>> {
        let jobs_dir = state_dir.join("jobs");
        std::fs::create_dir_all(&jobs_dir)
            .with_context(|| format!("creating state dir {}", jobs_dir.display()))?;
        let mut jobs = BTreeMap::new();
        let mut queue = VecDeque::new();
        let mut next_id = 1u64;
        let mut ids: Vec<String> = std::fs::read_dir(&jobs_dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("spec.json").exists())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        ids.sort(); // zero-padded ids sort in submission order
        for id in ids {
            // the id range is burned even for unloadable jobs, so a later
            // submit can never reuse a directory that still holds an old
            // job's journal/checkpoints
            if let Some(n) = id.strip_prefix('j').and_then(|s| s.parse::<u64>().ok()) {
                next_id = next_id.max(n + 1);
            }
            // one corrupt job directory must not brick the whole daemon:
            // skip it (leaving it on disk for forensics) and keep loading
            match Self::load_job(&jobs_dir.join(&id)) {
                Ok((spec, state, error)) => {
                    let bus = Arc::new(EventBus::new());
                    let mut best = None;
                    if state.terminal() {
                        bus.emit(&Event::JobUpdate { state: state.as_str().to_string() });
                        bus.close();
                        if state == JobState::Done {
                            // one read at startup, then /hp answers from
                            // memory for the daemon's lifetime; the lazy
                            // scan pulls just the two `/hp` leaves out of
                            // documents dominated by loss curves, instead
                            // of building every job's full tree
                            best = std::fs::read_to_string(
                                jobs_dir.join(&id).join("results.json"),
                            )
                            .ok()
                            .as_deref()
                            .and_then(lazy_best);
                        }
                    } else {
                        // no terminal state recorded: the daemon died while
                        // this job was queued or running — re-queue it.  Its
                        // journal and checkpoints make the re-run skip
                        // finished trials.
                        queue.push_back(id.clone());
                    }
                    jobs.insert(id, JobEntry { spec, state, error, bus, best });
                }
                Err(e) => log.emit(&Event::server_log(format!(
                    "[serve] skipping unloadable job {id}: {e:#} (directory left on disk)"
                ))),
            }
        }
        // ids are never reused, even across delete + restart: the
        // high-water mark survives in its own file
        if let Ok(text) = std::fs::read_to_string(state_dir.join("last_id")) {
            if let Ok(n) = text.trim().parse::<u64>() {
                next_id = next_id.max(n + 1);
            }
        }
        Ok(Arc::new(Registry {
            state_dir: state_dir.to_path_buf(),
            inner: Mutex::new(RegInner { jobs, queue, next_id }),
            work: Condvar::new(),
            cache: ResultCache::new(cache_bytes),
            started: Instant::now(),
            exec_expected: AtomicUsize::new(0),
            exec_live: AtomicUsize::new(0),
            coords: Mutex::new(BTreeMap::new()),
        }))
    }

    /// Load one job directory: spec + terminal state (if any).
    fn load_job(dir: &Path) -> Result<(JobSpec, JobState, Option<String>)> {
        let spec_text = std::fs::read_to_string(dir.join("spec.json"))?;
        let spec = JobSpec::from_json(
            &json::parse(&spec_text).map_err(|e| anyhow::anyhow!("corrupt spec.json: {e}"))?,
        )?;
        let (state, error) = match std::fs::read_to_string(dir.join("state.json")) {
            Ok(text) => {
                let j = json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("corrupt state.json: {e}"))?;
                let st = match j.get("state").and_then(|v| v.as_str()) {
                    Some("done") => JobState::Done,
                    Some("failed") => JobState::Failed,
                    Some("cancelled") => JobState::Cancelled,
                    other => bail!("unknown terminal state {other:?}"),
                };
                let err = j.get("error").and_then(|v| v.as_str()).map(str::to_string);
                (st, err)
            }
            Err(_) => (JobState::Queued, None),
        };
        Ok((spec, state, error))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RegInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn job_dir(&self, id: &str) -> PathBuf {
        self.state_dir.join("jobs").join(id)
    }

    /// Persist and enqueue a job; returns its id.  The spec hits disk
    /// before the id is announced, so a submit the client saw succeed is
    /// never lost to a crash.
    ///
    /// The registry lock is held only for the in-memory transitions (id
    /// allocation + the final publish); the job-dir filesystem work runs
    /// unlocked so a slow fsync never stalls the whole control plane.
    /// The tiny `last_id` write stays under the lock: it is what makes
    /// ids never-reused across delete + restart, so it must be ordered
    /// with the allocation it records.
    pub fn submit(&self, spec: JobSpec) -> Result<String> {
        let id = {
            let mut inner = self.lock();
            let n = inner.next_id;
            inner.next_id += 1;
            write_atomic(&self.state_dir.join("last_id"), n.to_string().as_bytes())?;
            format!("j{n:06}")
        };
        let dir = self.job_dir(&id);
        std::fs::create_dir_all(&dir)?;
        write_atomic(&dir.join("spec.json"), spec.to_json().to_string().as_bytes())?;
        let bus = Arc::new(EventBus::new());
        bus.emit(&Event::JobUpdate { state: "queued".into() });
        {
            let mut inner = self.lock();
            inner.jobs.insert(
                id.clone(),
                JobEntry { spec, state: JobState::Queued, error: None, bus, best: None },
            );
            inner.queue.push_back(id.clone());
        }
        metrics::JOBS_SUBMITTED.inc();
        self.work.notify_all();
        Ok(id)
    }

    /// Executor side: block until a job is available (or `stop` is set).
    /// The popped job transitions to `Running` in memory only — see the
    /// module docs for why `Running` is never persisted.
    pub fn next_job(&self, stop: &AtomicBool) -> Option<(String, JobSpec)> {
        let mut inner = self.lock();
        loop {
            if stop.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(id) = inner.queue.pop_front() {
                // a queued job can have been cancelled since enqueue
                let Some(entry) = inner.jobs.get_mut(&id) else { continue };
                if entry.state != JobState::Queued {
                    continue;
                }
                entry.state = JobState::Running;
                entry
                    .bus
                    .emit(&Event::JobUpdate { state: "running".into() });
                return Some((id.clone(), entry.spec.clone()));
            }
            let (guard, _) = self
                .work
                .wait_timeout(inner, Duration::from_millis(100))
                .unwrap_or_else(|e| e.into_inner());
            inner = guard;
        }
    }

    /// Record a job's terminal state: `results.json` first (when it
    /// succeeded), then `state.json` — both atomic, in that order, so a
    /// `done` marker always implies readable results.
    pub fn finish(&self, id: &str, outcome: Result<Json>) -> Result<()> {
        let dir = self.job_dir(id);
        let (state, error, best) = match &outcome {
            Ok(results) => {
                // serialize exactly once: the same bytes go to disk and
                // into the results cache, so a cached read can never
                // diverge from what a fresh disk read would return
                let text = results.to_string();
                write_atomic(&dir.join("results.json"), text.as_bytes())?;
                self.cache.put(id, Arc::new(text.into_bytes()));
                (JobState::Done, None, extract_best(results))
            }
            Err(e) => (JobState::Failed, Some(format!("{e:#}")), None),
        };
        let mut st = Json::from_pairs(vec![("state", jstr(state.as_str()))]);
        if let Some(e) = &error {
            st.set("error", jstr(e));
        }
        write_atomic(&dir.join("state.json"), st.to_string().as_bytes())?;
        // drain the live coord ring to disk: telemetry is best-effort, so
        // a failed write must not fail the job's terminal transition
        let ring = {
            let mut m = self.coords.lock().unwrap_or_else(|e| e.into_inner());
            m.remove(id)
        };
        if let Some(r) = ring {
            let _ = write_atomic(&dir.join("coords.json"), r.to_json().to_string().as_bytes());
        }
        let mut inner = self.lock();
        if let Some(entry) = inner.jobs.get_mut(id) {
            entry.state = state;
            entry.error = error;
            entry.best = best;
            entry
                .bus
                .emit(&Event::JobUpdate { state: state.as_str().to_string() });
            entry.bus.close();
        }
        Ok(())
    }

    /// `DELETE /jobs/:id` semantics (documented in DESIGN.md §9).
    pub fn cancel(&self, id: &str) -> Result<CancelOutcome> {
        let mut inner = self.lock();
        let Some(entry) = inner.jobs.get_mut(id) else {
            return Ok(CancelOutcome::NotFound);
        };
        match entry.state {
            JobState::Running => Ok(CancelOutcome::Running),
            JobState::Queued => {
                // the small state.json write stays under the lock: the
                // cancelled marker must be ordered with the queue removal
                // or a concurrent executor pop could start a job whose
                // terminal state is already on disk
                let st = Json::from_pairs(vec![("state", jstr("cancelled"))]);
                write_atomic(&self.job_dir(id).join("state.json"), st.to_string().as_bytes())?;
                entry.state = JobState::Cancelled;
                entry
                    .bus
                    .emit(&Event::JobUpdate { state: "cancelled".into() });
                entry.bus.close();
                inner.queue.retain(|q| q != id);
                Ok(CancelOutcome::Cancelled)
            }
            _ => {
                // terminal jobs never transition again, so the (possibly
                // large — checkpoints) directory removal can run unlocked
                entry.bus.close();
                inner.jobs.remove(id);
                drop(inner);
                // drop cached bytes before the files: even if the removal
                // errors, the cache must not keep serving a job the
                // registry no longer knows
                self.cache.invalidate(id);
                self.coords
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .remove(id);
                std::fs::remove_dir_all(self.job_dir(id))
                    .with_context(|| format!("removing job dir for {id}"))?;
                Ok(CancelOutcome::Deleted)
            }
        }
    }

    fn view_locked(id: &str, e: &JobEntry) -> Json {
        let mut j = Json::from_pairs(vec![
            ("id", jstr(id)),
            ("name", jstr(&e.spec.name)),
            ("kind", jstr(e.spec.kind.as_str())),
            ("state", jstr(e.state.as_str())),
            ("spec", e.spec.to_json()),
        ]);
        if let Some(err) = &e.error {
            j.set("error", jstr(err));
        }
        j
    }

    pub fn view(&self, id: &str) -> Option<Json> {
        let inner = self.lock();
        inner.jobs.get(id).map(|e| Self::view_locked(id, e))
    }

    pub fn list(&self) -> Json {
        let inner = self.lock();
        Json::from_pairs(vec![(
            "jobs",
            Json::Arr(
                inner
                    .jobs
                    .iter()
                    .map(|(id, e)| Self::view_locked(id, e))
                    .collect(),
            ),
        )])
    }

    pub fn state(&self, id: &str) -> Option<JobState> {
        self.lock().jobs.get(id).map(|e| e.state)
    }

    /// Jobs still owed work (queued or running) — what a restarted daemon
    /// reports as "resumed".
    pub fn pending(&self) -> usize {
        self.lock()
            .jobs
            .values()
            .filter(|e| !e.state.terminal())
            .count()
    }

    pub fn bus(&self, id: &str) -> Option<Arc<EventBus>> {
        self.lock().jobs.get(id).map(|e| e.bus.clone())
    }

    /// `GET /healthz` body + verdict.  Unhealthy (503) iff executor
    /// threads have died: `live < expected` means queued jobs may wait
    /// forever, which a load balancer must see.  Job counts come from the
    /// same lock every other view takes; gauges are read lock-free.
    pub fn health(&self) -> (Json, bool) {
        let (queued, running, terminal) = {
            let inner = self.lock();
            let mut q = 0usize;
            let mut r = 0usize;
            let mut t = 0usize;
            for e in inner.jobs.values() {
                match e.state {
                    JobState::Queued => q += 1,
                    JobState::Running => r += 1,
                    _ => t += 1,
                }
            }
            (q, r, t)
        };
        let expected = self.exec_expected.load(Ordering::SeqCst);
        let live = self.exec_live.load(Ordering::SeqCst);
        let healthy = live >= expected;
        let body = Json::from_pairs(vec![
            ("ok", Json::Bool(healthy)),
            ("version", jstr(env!("CARGO_PKG_VERSION"))),
            ("uptime_secs", jnum(self.started.elapsed().as_secs() as f64)),
            (
                "jobs",
                Json::from_pairs(vec![
                    ("queued", jnum(queued as f64)),
                    ("running", jnum(running as f64)),
                    ("terminal", jnum(terminal as f64)),
                ]),
            ),
            (
                "exec",
                Json::from_pairs(vec![
                    ("expected", jnum(expected as f64)),
                    ("live", jnum(live as f64)),
                    ("busy", jnum(metrics::EXEC_SLOTS_BUSY.get() as f64)),
                ]),
            ),
        ]);
        (body, healthy)
    }

    /// Ring-buffer one μ-coordinate sample for a live job (called by the
    /// executor's [`CoordCapture`] sink).
    pub fn record_coords(&self, id: &str, sample: Json) {
        // Full-history append: the in-memory ring caps at
        // [`coords::RING_CAP`], so `GET /jobs/:id/metrics?after=` pages
        // over this NDJSON file instead.  Line-framed append-only
        // telemetry is best-effort by design — the paging reader skips a
        // torn tail, and rewriting the whole file per sample would turn
        // O(1) appends into O(n²) churn.
        // mutlint: allow(atomic-write, "append-only NDJSON telemetry log; paging readers skip torn tails, durable artifacts all stay on write_atomic")
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.job_dir(id).join("coords.ndjson"))
        {
            use std::io::Write as _;
            let _ = writeln!(f, "{}", sample.to_string());
        }
        let mut m = self.coords.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(id.to_string()).or_default().push(sample);
    }

    /// `GET /jobs/:id/metrics`: the live ring when the job is running,
    /// else the `coords.json` persisted at finish.  `None` = unknown job;
    /// a known job with no telemetry answers an empty array, not a 404 —
    /// "no samples yet" and "no such job" are different facts.
    pub fn coord_metrics(&self, id: &str) -> Option<Json> {
        {
            let m = self.coords.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(r) = m.get(id) {
                if !r.is_empty() {
                    return Some(r.to_json());
                }
            }
        }
        self.state(id)?;
        let text = std::fs::read_to_string(self.job_dir(id).join("coords.json"))
            .unwrap_or_default();
        Some(json::parse(&text).unwrap_or(Json::Arr(Vec::new())))
    }

    /// `GET /jobs/:id/metrics?after=N`: one page of the *full* persisted
    /// coordinate history (`coords.ndjson`), starting at step `after`
    /// inclusive — the ring above forgets anything older than
    /// [`coords::RING_CAP`] samples, this file does not.  At most
    /// `RING_CAP` samples per page; a full page carries `next_after`
    /// (the cursor for the next call), a short one is the end of history
    /// so far.  Torn tail lines (a crash mid-append) are skipped, never
    /// an error.  `None` = unknown job.
    pub fn coord_page(&self, id: &str, after: u64) -> Option<Json> {
        self.state(id)?;
        let text = std::fs::read_to_string(self.job_dir(id).join("coords.ndjson"))
            .unwrap_or_default();
        let mut samples = Vec::new();
        let mut last_step = 0u64;
        let mut full = false;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let Ok(j) = json::parse(line) else { continue };
            let Some(step) = j.get("step").and_then(|s| s.as_f64()) else { continue };
            let step = step as u64;
            if step < after {
                continue;
            }
            if samples.len() >= coords::RING_CAP {
                full = true;
                break;
            }
            last_step = last_step.max(step);
            samples.push(j);
        }
        let mut out =
            Json::from_pairs(vec![("id", jstr(id)), ("samples", Json::Arr(samples))]);
        if full {
            out.set("next_after", jnum((last_step + 1) as f64));
        }
        Some(out)
    }

    /// Raw `results.json` bytes for a `done` job (`None` = not done yet
    /// or unknown; the API distinguishes unknown ids separately).  With
    /// `use_cache` the bytes come from the LRU cache when present (misses
    /// repopulate it); without, every call is a fresh disk read — the
    /// `?nocache=1` escape hatch and the bench's uncached baseline.
    pub fn results_bytes(&self, id: &str, use_cache: bool) -> Option<Arc<Vec<u8>>> {
        if self.state(id) != Some(JobState::Done) {
            return None;
        }
        if use_cache {
            if let Some(b) = self.cache.get(id) {
                return Some(b);
            }
        }
        let bytes = Arc::new(std::fs::read(self.job_dir(id).join("results.json")).ok()?);
        if use_cache {
            self.cache.put(id, bytes.clone());
        }
        Some(bytes)
    }

    /// [`Registry::results_bytes`] as a `String` (CLI/test convenience).
    pub fn results_raw(&self, id: &str) -> Option<String> {
        self.results_bytes(id, true)
            .map(|b| String::from_utf8_lossy(&b).into_owned())
    }

    /// The μTransfer question, answered from the registry: the best HPs
    /// recorded by any completed proxy sweep, ranked by winning-trial
    /// validation loss.  μP makes the answer width-independent — that is
    /// the paper's whole point — so the requested target `width` (and,
    /// with the depth/batch transfer axes, `depth`/`batch`) is echoed,
    /// not matched.  Served entirely from the in-memory cache (populated
    /// at `finish` / startup), so polling `/hp` never touches disk.
    pub fn best_hp(
        &self,
        width: Option<usize>,
        depth: Option<usize>,
        batch: Option<usize>,
    ) -> Option<Json> {
        let inner = self.lock();
        let (id, entry, loss, assignment) = inner
            .jobs
            .iter()
            .filter(|(_, e)| e.state == JobState::Done)
            .filter_map(|(id, e)| {
                e.best.as_ref().map(|(l, a)| (id, e, *l, a))
            })
            .min_by(|a, b| a.2.total_cmp(&b.2))?;
        let mut j = Json::from_pairs(vec![
            ("job", jstr(id)),
            ("name", jstr(&entry.spec.name)),
            ("proxy", jstr(&entry.spec.proxy)),
            ("base_width", jnum(entry.spec.base_width as f64)),
            ("proxy_steps", jnum(entry.spec.steps as f64)),
            ("param", jstr(entry.spec.param.name())),
            ("assignment", assignment.clone()),
            ("proxy_val_loss", jnum(loss)),
            (
                "note",
                jstr("muP: these HPs transfer zero-shot across width/depth/batch with the same base shape"),
            ),
        ]);
        if let Some(w) = width {
            j.set("width", jnum(w as f64));
        }
        if let Some(d) = depth {
            j.set("depth", jnum(d as f64));
        }
        if let Some(b) = batch {
            j.set("batch", jnum(b as f64));
        }
        Some(j)
    }
}

/// A SIGKILL landing inside the very *first* journal append leaves a file
/// holding one newline-less JSON prefix and nothing else.
/// `Sweep::with_journal` deliberately refuses to truncate files in which
/// it recognized no records (it must never destroy a foreign file handed
/// to `--resume-from`) — but this journal is daemon-owned, so the
/// torn-first-append signature is safe to repair here: truncate to empty
/// and let the sweep start from scratch.  A complete-but-newline-less
/// record is left alone (`with_journal` completes the newline itself).
fn repair_torn_first_append(path: &Path) {
    let Ok(text) = std::fs::read_to_string(path) else { return };
    if text.is_empty() || text.ends_with('\n') || text.trim().is_empty() {
        return;
    }
    if !text.contains('\n') && json::parse(text.trim()).is_err() {
        // mutlint: allow(atomic-write, "in-place truncate of a daemon-owned torn journal; there is no content to make durable and rename would race the sweep's own append path")
        if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
            let _ = f.set_len(0);
            let _ = f.sync_all();
        }
    }
}

/// Executor-side sink wrapper: forwards every event to the job's bus and
/// additionally ring-buffers `CoordStats` samples in the registry, so
/// `GET /jobs/:id/metrics` answers from memory while the job is live.
/// Warning counting happens in the wrapped sink (`count_event`); this
/// wrapper must never count, or forwarded warnings would double.
struct CoordCapture {
    id: String,
    reg: Arc<Registry>,
    inner: Arc<dyn EventSink>,
}

impl EventSink for CoordCapture {
    fn emit(&self, ev: &Event) {
        if let Event::CoordStats { step, groups, .. } = ev {
            let gs: Vec<coords::GroupStat> = groups
                .iter()
                .map(|(name, w_rms, upd_rms)| coords::GroupStat {
                    name: name.clone(),
                    w_rms: *w_rms,
                    upd_rms: *upd_rms,
                })
                .collect();
            self.reg.record_coords(&self.id, coords::sample_json(*step, &gs));
        }
        self.inner.emit(ev);
    }
}

/// Decrements the registry's live-executor count when an executor thread
/// exits — normally *or* by unwind, so a panicked slot flips `healthz`
/// to 503 instead of leaving a zombie-healthy daemon.
struct ExecLive(Arc<Registry>);

impl Drop for ExecLive {
    fn drop(&mut self) {
        self.0.exec_live.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Execute one job through the existing sweep/transfer machinery, with
/// the job's event bus as the sink.  Pure function of (spec, job dir):
/// results are the canonical [`crate::transfer::TransferOutcome::to_json`]
/// — the fair-share `budget` lease throttles *when* trials execute, never
/// what they compute, so results stay bit-identical at any slot count.
pub fn run_job(
    rt: &Runtime,
    dir: &Path,
    spec: &JobSpec,
    bus: Arc<dyn EventSink>,
    budget: Option<Arc<pool::BudgetLease>>,
) -> Result<Json> {
    let journal = dir.join("journal");
    repair_torn_first_append(&journal);
    let mut sweep = Sweep::new(rt).with_journal(&journal)?;
    if spec.workers > 0 {
        sweep = sweep.with_workers(spec.workers);
    }
    if let Some(lease) = budget {
        sweep = sweep.with_budget(lease);
    }
    if spec.ckpt_every > 0 || matches!(spec.tuner, TunerKind::Sha { .. }) {
        sweep = sweep.with_checkpoints(&dir.join("ckpt"), spec.ckpt_every)?;
    }
    let mut sweep = sweep.with_sink(bus);
    let setup = spec.setup();
    let out = match spec.kind {
        JobKind::Transfer => mu_transfer(rt, &mut sweep, &setup, JOB_LABEL)?,
        JobKind::Sweep => tune_only(rt, &mut sweep, &setup, JOB_LABEL)?,
    };
    Ok(out.to_json())
}

// ---------------------------------------------------------------------------
// connection pool
// ---------------------------------------------------------------------------

/// One pooled keep-alive connection (reader/writer halves of a socket).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    idle_since: Instant,
}

/// Bounded connection pool: the acceptor pushes sockets, a fixed set of
/// HTTP workers pops them, serves a bounded burst, and requeues the
/// connection if it goes quiet — so 256 keep-alive clients multiplex over
/// `http_workers` threads instead of pinning 256.  `active` counts every
/// admitted socket (queued *or* being served); the acceptor answers `503`
/// past `max_conns`, never spawning an unbounded thread.
struct ConnPool {
    queue: Mutex<VecDeque<Conn>>,
    ready: Condvar,
    active: AtomicUsize,
    max_conns: usize,
}

impl ConnPool {
    fn new(max_conns: usize) -> ConnPool {
        ConnPool {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            active: AtomicUsize::new(0),
            max_conns: max_conns.max(1),
        }
    }

    fn push(&self, conn: Conn) {
        self.queue
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push_back(conn);
        self.ready.notify_one();
    }

    fn pop(&self, stop: &AtomicBool) -> Option<Conn> {
        let mut q = self.queue.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if stop.load(Ordering::SeqCst) {
                return None; // shutdown drops queued conns unanswered
            }
            if let Some(c) = q.pop_front() {
                return Some(c);
            }
            q = self
                .ready
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner())
                .0;
        }
    }

    fn release(&self, conn: Conn) {
        drop(conn);
        self.active.fetch_sub(1, Ordering::SeqCst);
        metrics::HTTP_OPEN_CONNS.dec();
    }
}

/// How long a pooled connection may sit idle before it is closed.
const IDLE_CLOSE: Duration = Duration::from_secs(60);
/// Probe window per scheduling slice — also the worker's sleep, so an
/// idle pool rotates through its connections without spinning hot.
const PROBE: Duration = Duration::from_millis(2);
/// Mid-request / mid-body read timeout once bytes have started arriving.
const REQUEST_READ: Duration = Duration::from_secs(10);
/// Requests served per connection per scheduling slice before it must
/// requeue behind its siblings (keeps one pipelining client from pinning
/// a worker).
const BURST: usize = 32;

fn conn_worker(pool: &ConnPool, reg: &Arc<Registry>, stop: &AtomicBool) {
    while let Some(conn) = pool.pop(stop) {
        serve_conn(pool, reg, stop, conn);
    }
}

enum Probe {
    Data,
    Eof,
    Quiet,
    Dead,
}

/// Serve one pooled connection for one scheduling slice.
fn serve_conn(pool: &ConnPool, reg: &Arc<Registry>, stop: &AtomicBool, mut conn: Conn) {
    if conn.reader.buffer().is_empty() {
        // nothing pre-buffered: probe briefly for new bytes (try_clone'd
        // halves share the socket, so one timeout call covers both)
        conn.writer.set_read_timeout(Some(PROBE)).ok();
        let probe = match conn.reader.fill_buf() {
            Ok([]) => Probe::Eof,
            Ok(_) => Probe::Data,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                Probe::Quiet
            }
            Err(_) => Probe::Dead,
        };
        match probe {
            Probe::Data => {}
            Probe::Eof | Probe::Dead => {
                pool.release(conn);
                return;
            }
            Probe::Quiet => {
                if conn.idle_since.elapsed() > IDLE_CLOSE {
                    pool.release(conn); // silent idle close
                } else {
                    pool.push(conn); // round-robin back into the pool
                }
                return;
            }
        }
    }
    // bytes are waiting: parse + answer a bounded burst of requests
    conn.writer.set_read_timeout(Some(REQUEST_READ)).ok();
    for _ in 0..BURST {
        match crate::serve::http::read_request(&mut conn.reader) {
            Ok(Some(req)) => {
                if !crate::serve::api::handle(reg, &req, &mut conn.writer, stop) {
                    pool.release(conn);
                    return;
                }
                conn.idle_since = Instant::now();
                if conn.reader.buffer().is_empty() {
                    pool.push(conn);
                    return;
                }
            }
            Ok(None) => {
                pool.release(conn); // clean keep-alive close
                return;
            }
            Err(e) => {
                // mid-request stall: hang up silently — an unsolicited
                // 400 would be read by a keep-alive client as the (wrong)
                // response to its NEXT request
                let timed_out = e.chain().any(|c| {
                    c.downcast_ref::<std::io::Error>()
                        .map(|io| {
                            matches!(
                                io.kind(),
                                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                            )
                        })
                        .unwrap_or(false)
                });
                if !timed_out {
                    // genuinely malformed request: best-effort 400
                    let _ = crate::serve::http::respond_json(
                        &mut conn.writer,
                        400,
                        &crate::serve::http::error_json(400, "malformed request"),
                        false,
                    );
                }
                pool.release(conn);
                return;
            }
        }
    }
    // burst exhausted with more pipelined bytes buffered: requeue so
    // sibling connections get a turn
    pool.push(conn);
}

/// A running daemon: registry + executor slots + HTTP acceptor feeding a
/// bounded connection worker pool.
pub struct Daemon {
    pub registry: Arc<Registry>,
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    executors: Vec<std::thread::JoinHandle<()>>,
}

impl Daemon {
    /// Bind `addr` (port 0 = ephemeral; the bound address is in
    /// [`Daemon::addr`]), open the registry under `state_dir`, re-queue
    /// unfinished jobs, and start serving with default sizing.
    pub fn start(addr: &str, state_dir: &Path, artifacts: Option<PathBuf>) -> Result<Daemon> {
        Self::start_cfg(addr, state_dir, artifacts, ServeConfig::default())
    }

    /// [`Daemon::start`] with explicit pool/executor/cache sizing.
    pub fn start_cfg(
        addr: &str,
        state_dir: &Path,
        artifacts: Option<PathBuf>,
        cfg: ServeConfig,
    ) -> Result<Daemon> {
        Self::start_logged(addr, state_dir, artifacts, cfg, Arc::new(StderrSink::quiet()))
    }

    /// [`Daemon::start_cfg`] with an explicit sink for the daemon's
    /// operational log events (`[serve] …` lifecycle lines).  The default
    /// `StderrSink` keeps stderr byte-identical to the pre-bus daemon;
    /// tests pass a `CollectSink`, embedders can forward to their own bus.
    pub fn start_logged(
        addr: &str,
        state_dir: &Path,
        artifacts: Option<PathBuf>,
        cfg: ServeConfig,
        log: Arc<dyn EventSink>,
    ) -> Result<Daemon> {
        let registry = Registry::open_logged(state_dir, cfg.cache_bytes, log.as_ref())?;
        // fail fast on an unloadable artifacts path: degrading to the
        // native backend must be a startup error, not a silent mid-queue
        // substitution the operator never sees
        if let Some(p) = &artifacts {
            Runtime::new(p)
                .with_context(|| format!("loading artifacts from {}", p.display()))?;
        }
        // SO_REUSEADDR bind: a restarted daemon must reclaim its address
        // while its previous life's connections sit in TIME_WAIT
        let listener = crate::serve::http::bind_reuse(addr)
            .with_context(|| format!("binding {addr}"))?;
        let bound = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));

        // executor slots share one fair-share trial-worker budget: a big
        // sweep and a small one run concurrently, each throttled to its
        // max-min fair share of the machine
        let budget_total = if cfg.worker_budget == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            cfg.worker_budget
        };
        let budget = pool::FairBudget::new(budget_total);
        // live μ-coordinate telemetry is on for every daemon-run job
        // (offline CLI runs stay opt-in, keeping their output byte-stable)
        coords::set_enabled(true);
        // perf attribution aggregates for the daemon's whole lifetime —
        // streaming fold, bounded state — served at GET /debug/profile
        crate::obs::profile::enable();
        let slots = cfg.exec_slots.max(1);
        registry.exec_expected.store(slots, Ordering::SeqCst);
        metrics::EXEC_SLOTS_TOTAL.set(slots as i64);
        let mut executors = Vec::new();
        for slot in 0..slots {
            let reg = registry.clone();
            let stop = stop.clone();
            let artifacts = artifacts.clone();
            let budget = budget.clone();
            let log = log.clone();
            reg.exec_live.fetch_add(1, Ordering::SeqCst);
            executors.push(std::thread::spawn(move || {
                // counted live before spawn (not inside the thread) so a
                // healthz probe racing startup never sees live < expected
                let _live = ExecLive(reg.clone());
                // per-slot attribution in GET /debug/profile
                crate::obs::profile::label_current_thread(&format!("exec-{slot}"));
                // each slot owns its Runtime: backends need not be Sync.
                // Daemon::start already validated the artifacts path; if
                // it became unloadable since, say so instead of degrading
                // mutely.
                let rt = match &artifacts {
                    Some(p) => Runtime::new(p).unwrap_or_else(|e| {
                        log.emit(&Event::server_log(format!(
                            "[serve] warning: artifacts became unavailable ({e:#}); using the native backend"
                        )));
                        Runtime::native()
                    }),
                    None => Runtime::native(),
                };
                while let Some((id, spec)) = reg.next_job(&stop) {
                    log.emit(&Event::server_log(format!(
                        "[serve] job {id} ({}) started on slot {slot}",
                        spec.name
                    )));
                    let dir = reg.job_dir(&id);
                    let bus: Arc<dyn EventSink> = match reg.bus(&id) {
                        Some(b) => b,
                        None => Arc::new(crate::serve::events::NullSink),
                    };
                    let bus: Arc<dyn EventSink> = Arc::new(CoordCapture {
                        id: id.clone(),
                        reg: reg.clone(),
                        inner: bus,
                    });
                    let lease = Arc::new(budget.lease());
                    let busy = metrics::EXEC_SLOTS_BUSY.guard();
                    let outcome = run_job(&rt, &dir, &spec, bus, Some(lease));
                    drop(busy);
                    match &outcome {
                        Ok(_) => log.emit(&Event::server_log(format!("[serve] job {id} done"))),
                        Err(e) => log.emit(&Event::server_log(format!(
                            "[serve] job {id} FAILED: {e:#}"
                        ))),
                    }
                    if let Err(e) = reg.finish(&id, outcome) {
                        log.emit(&Event::server_log(format!(
                            "[serve] persisting terminal state for {id} failed: {e:#}"
                        )));
                    }
                }
            }));
        }

        let conn_pool = Arc::new(ConnPool::new(cfg.max_conns));
        let mut workers = Vec::new();
        for _ in 0..cfg.http_workers.max(1) {
            let pool = conn_pool.clone();
            let reg = registry.clone();
            let stop = stop.clone();
            workers.push(std::thread::spawn(move || conn_worker(&pool, &reg, &stop)));
        }

        let acc_pool = conn_pool;
        let acc_stop = stop.clone();
        let acceptor = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if acc_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if acc_pool.active.load(Ordering::SeqCst) >= acc_pool.max_conns {
                    // full house: a one-line 503 + close, never a new
                    // thread and never a silent drop
                    metrics::HTTP_SHEDS.inc();
                    let mut s = stream;
                    let _ = crate::serve::http::respond_overload(&mut s);
                    continue;
                }
                stream.set_nodelay(true).ok();
                let Ok(read_half) = stream.try_clone() else { continue };
                acc_pool.active.fetch_add(1, Ordering::SeqCst);
                metrics::HTTP_OPEN_CONNS.inc();
                acc_pool.push(Conn {
                    reader: BufReader::new(read_half),
                    writer: stream,
                    idle_since: Instant::now(),
                });
            }
        });

        Ok(Daemon {
            registry,
            addr: bound,
            stop,
            acceptor: Some(acceptor),
            workers,
            executors,
        })
    }

    /// Block on the serving threads — `mutransfer serve` foreground mode.
    pub fn join(mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful stop for tests/benches: stops accepting, wakes workers
    /// and executors, joins every thread — a *bounded* join, since HTTP
    /// workers observe `stop` within one pop/SSE timeout tick and
    /// executors between jobs.  Call once the queue is drained — a
    /// mid-job executor finishes its current job first (jobs themselves
    /// are never interrupted; that is what kill -9 + restart is for).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the blocking accept() so the acceptor observes `stop`
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mutransfer_daemon_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn open_logged_reports_unloadable_job_on_the_sink() {
        let dir = tmpdir("openlog");
        // corrupt job dir: spec.json present but unparseable
        let jdir = dir.join("jobs").join("j0000000007");
        std::fs::create_dir_all(&jdir).unwrap();
        std::fs::write(jdir.join("spec.json"), "{not json").unwrap();
        let sink = crate::serve::events::CollectSink::default();
        let reg = Registry::open_logged(&dir, 0, &sink).unwrap();
        let evs = sink.take();
        assert!(
            evs.iter().any(|e| matches!(
                e,
                Event::ServerLog { msg }
                    if msg.contains("skipping unloadable job j0000000007")
            )),
            "skip must surface as a typed ServerLog event, got {evs:?}"
        );
        let inner = reg.inner.lock().unwrap();
        assert!(inner.jobs.is_empty(), "corrupt job must not load");
        assert!(inner.next_id > 7, "unloadable job still burns its id range");
        // the directory stays on disk for forensics
        assert!(jdir.join("spec.json").exists());
    }

    #[test]
    fn jobspec_json_roundtrip() {
        let spec = JobSpec {
            name: "quo\"te \u{1F600}\nnl".into(),
            kind: JobKind::Sweep,
            proxy: "tfm_post_w32_d2".into(),
            target: "tfm_post_w64_d2".into(),
            base_width: 32,
            samples: 5,
            steps: 16,
            target_steps: 8,
            seed: 3,
            workers: 2,
            tuner: TunerKind::Sha { eta: 3, rung0: 4 },
            ckpt_every: 2,
            param: Scheme::Umup,
            base_depth: 2,
            base_batch: 16,
        };
        let text = spec.to_json().to_string();
        let back = JobSpec::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec, "names with quotes/newlines/emoji must survive");
        // param + base dims survive the roundtrip and drive setup()
        assert_eq!(back.param, Scheme::Umup);
        let setup = back.setup();
        assert_eq!(setup.scheme, Scheme::Umup);
        assert_eq!(setup.base_depth, Some(2));
        assert_eq!(setup.base_batch, Some(16));
    }

    #[test]
    fn jobspec_validation() {
        let bad = |s: &str| JobSpec::from_json(&json::parse(s).unwrap()).is_err();
        assert!(bad(r#"{"kind":"evil"}"#));
        assert!(bad(r#"{"tuner":"lbfgs"}"#));
        assert!(bad(r#"{"param":"ntk"}"#));
        assert!(bad(r#"{"steps":0}"#));
        assert!(bad(r#"{"base_width":33}"#));
        assert!(bad(r#"{"samples":-2}"#));
        // sha params the offline path would reject are a 400, not a
        // silently rewritten job
        assert!(bad(r#"{"tuner":"sha","eta":1}"#));
        assert!(bad(r#"{"tuner":"sha","steps":8,"rung0":9}"#));
        // seeds: fractional numbers rejected, strings exact to u64::MAX
        assert!(bad(r#"{"seed":1.5}"#));
        assert!(bad(r#"{"seed":"zzz"}"#));
        let big = JobSpec::from_json(
            &json::parse(r#"{"seed":"18446744073709551615"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(big.seed, u64::MAX);
        assert_eq!(
            JobSpec::from_json(&big.to_json()).unwrap().seed,
            u64::MAX,
            "seed must round-trip exactly above 2^53"
        );
        // defaults fill everything else in
        let ok = JobSpec::from_json(&json::parse("{}").unwrap()).unwrap();
        assert_eq!(ok.kind, JobKind::Transfer);
        assert_eq!(ok.tuner, TunerKind::Random);
        assert_eq!(ok.param, Scheme::Mup);
        assert_eq!(ok.base_depth, 0);
        let setup = ok.setup();
        assert_eq!(setup.base_depth, None, "0 means same-as-target");
        assert_eq!(setup.base_batch, None);
    }

    #[test]
    fn registry_queue_survives_reopen() {
        let dir = tmpdir("reopen");
        let spec = JobSpec { samples: 1, steps: 2, ..JobSpec::default() };
        {
            let reg = Registry::open(&dir).unwrap();
            let a = reg.submit(spec.clone()).unwrap();
            let b = reg.submit(spec.clone()).unwrap();
            assert_eq!(a, "j000001");
            assert_eq!(b, "j000002");
            // j000001 reaches a terminal state; j000002 stays queued
            reg.finish(&a, Ok(Json::from_pairs(vec![("x", jnum(1.0))]))).unwrap();
        }
        // "restart": only the unfinished job is re-queued, ids continue
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.state("j000001"), Some(JobState::Done));
        assert_eq!(reg.state("j000002"), Some(JobState::Queued));
        let stop = AtomicBool::new(false);
        let (id, _) = reg.next_job(&stop).unwrap();
        assert_eq!(id, "j000002");
        let c = reg.submit(spec).unwrap();
        assert_eq!(c, "j000003");
    }

    #[test]
    fn cancel_semantics() {
        let dir = tmpdir("cancel");
        let reg = Registry::open(&dir).unwrap();
        let spec = JobSpec::default();
        let q = reg.submit(spec.clone()).unwrap();
        assert_eq!(reg.cancel(&q).unwrap(), CancelOutcome::Cancelled);
        assert_eq!(reg.state(&q), Some(JobState::Cancelled));
        // cancelled queue entries are skipped by the executor
        let q2 = reg.submit(spec.clone()).unwrap();
        let stop = AtomicBool::new(false);
        let (id, _) = reg.next_job(&stop).unwrap();
        assert_eq!(id, q2);
        assert_eq!(reg.cancel(&q2).unwrap(), CancelOutcome::Running);
        reg.finish(&q2, Err(anyhow::anyhow!("boom"))).unwrap();
        assert_eq!(reg.state(&q2), Some(JobState::Failed));
        // terminal → delete removes the record and the directory
        assert_eq!(reg.cancel(&q2).unwrap(), CancelOutcome::Deleted);
        assert_eq!(reg.cancel(&q2).unwrap(), CancelOutcome::NotFound);
        assert!(!reg.job_dir(&q2).exists());
    }

    #[test]
    fn ids_never_reused_after_delete_and_restart() {
        let dir = tmpdir("idreuse");
        let spec = JobSpec::default();
        {
            let reg = Registry::open(&dir).unwrap();
            let a = reg.submit(spec.clone()).unwrap(); // j000001
            reg.finish(&a, Ok(Json::obj())).unwrap();
            assert_eq!(reg.cancel(&a).unwrap(), CancelOutcome::Deleted);
        }
        // restart: the deleted id's directory is gone, but its id is
        // burned — a stale client reference can never alias a new job
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.submit(spec).unwrap(), "j000002");
    }

    #[test]
    fn corrupt_job_dir_is_skipped_not_fatal() {
        let dir = tmpdir("corruptjob");
        {
            let reg = Registry::open(&dir).unwrap();
            reg.submit(JobSpec::default()).unwrap(); // j000001
        }
        let bad = dir.join("jobs").join("j000900");
        std::fs::create_dir_all(&bad).unwrap();
        std::fs::write(bad.join("spec.json"), "{not json").unwrap();
        // restart still succeeds: the healthy job loads, the corrupt one
        // is skipped, and its id range is burned
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.state("j000001"), Some(JobState::Queued));
        assert!(reg.state("j000900").is_none());
        assert_eq!(reg.submit(JobSpec::default()).unwrap(), "j000901");
    }

    #[test]
    fn torn_first_journal_append_is_repaired() {
        let dir = tmpdir("torn1");
        let p = dir.join("journal");
        // kill mid-first-append: one newline-less JSON prefix
        std::fs::write(&p, "{\"key\":\"cli/proxy/0\",\"trial\":{\"assi").unwrap();
        repair_torn_first_append(&p);
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "");
        // a complete single record without its newline is NOT wiped
        // (with_journal completes the newline itself)
        std::fs::write(&p, "{\"x\":1}").unwrap();
        repair_torn_first_append(&p);
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"x\":1}");
        // multi-line files are with_journal's territory, untouched here
        std::fs::write(&p, "{\"x\":1}\n{\"y\":2").unwrap();
        repair_torn_first_append(&p);
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"x\":1}\n{\"y\":2");
    }

    #[test]
    fn best_hp_served_from_cache_and_survives_restart() {
        let dir = tmpdir("besthp");
        let id = {
            let reg = Registry::open(&dir).unwrap();
            let id = reg.submit(JobSpec::default()).unwrap();
            let results =
                json::parse(r#"{"best":{"lr":0.01},"best_val_loss":2.5}"#).unwrap();
            reg.finish(&id, Ok(results)).unwrap();
            let ans = reg.best_hp(Some(256), Some(8), Some(512)).unwrap();
            assert_eq!(ans.req("job").as_str().unwrap(), id);
            assert_eq!(ans.req("assignment").req("lr").as_f64().unwrap(), 0.01);
            assert_eq!(ans.req("width").as_usize().unwrap(), 256);
            assert_eq!(ans.req("depth").as_usize().unwrap(), 8);
            assert_eq!(ans.req("batch").as_usize().unwrap(), 512);
            assert_eq!(ans.req("param").as_str().unwrap(), "mup");
            id
        };
        // restart: the cache repopulates from results.json at open
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(
            reg.best_hp(None, None, None).unwrap().req("job").as_str().unwrap(),
            id
        );
        // a later sweep with a lower winning loss takes over
        let id2 = reg.submit(JobSpec::default()).unwrap();
        reg.finish(
            &id2,
            Ok(json::parse(r#"{"best":{"lr":0.02},"best_val_loss":1.5}"#).unwrap()),
        )
        .unwrap();
        assert_eq!(reg.best_hp(None, None, None).unwrap().req("job").as_str().unwrap(), id2);
        // an all-diverged sweep (best null) never wins
        let id3 = reg.submit(JobSpec::default()).unwrap();
        reg.finish(
            &id3,
            Ok(json::parse(r#"{"best":null,"best_val_loss":null}"#).unwrap()),
        )
        .unwrap();
        assert_eq!(reg.best_hp(None, None, None).unwrap().req("job").as_str().unwrap(), id2);
    }

    #[test]
    fn results_cache_serves_finish_bytes_and_invalidates_on_delete() {
        let dir = tmpdir("rescache");
        let reg = Registry::open(&dir).unwrap();
        let id = reg.submit(JobSpec::default()).unwrap();
        let results = json::parse(r#"{"best":{"lr":0.01},"best_val_loss":2.5}"#).unwrap();
        reg.finish(&id, Ok(results.clone())).unwrap();
        let cached = reg.results_bytes(&id, true).unwrap();
        let fresh = reg.results_bytes(&id, false).unwrap();
        assert_eq!(*cached, *fresh, "cached bytes must equal a disk read");
        assert_eq!(String::from_utf8(fresh.to_vec()).unwrap(), results.to_string());
        // the cached read is served from memory: delete the file behind
        // the cache's back and the cached path still answers
        std::fs::remove_file(reg.job_dir(&id).join("results.json")).unwrap();
        assert!(reg.results_bytes(&id, true).is_some());
        assert!(reg.results_bytes(&id, false).is_none());
        // restore + delete the job: the cache entry must die with it
        std::fs::write(reg.job_dir(&id).join("results.json"), "{}").unwrap();
        assert_eq!(reg.cancel(&id).unwrap(), CancelOutcome::Deleted);
        assert!(reg.results_bytes(&id, true).is_none());
    }

    #[test]
    fn results_cache_evicts_by_lru_byte_budget() {
        let big = "x".repeat(400);
        let doc = |tag: &str| {
            Ok(Json::from_pairs(vec![("tag", jstr(tag)), ("pad", jstr(&big))]))
        };
        let dir = tmpdir("lru");
        // budget fits roughly two padded documents, not three
        let reg = Registry::open_cfg(&dir, 1024).unwrap();
        let a = reg.submit(JobSpec::default()).unwrap();
        let b = reg.submit(JobSpec::default()).unwrap();
        let c = reg.submit(JobSpec::default()).unwrap();
        reg.finish(&a, doc("a")).unwrap();
        reg.finish(&b, doc("b")).unwrap();
        // touch a so b is the least-recently-used entry
        assert!(reg.results_bytes(&a, true).is_some());
        reg.finish(&c, doc("c")).unwrap();
        let inner = reg.cache.inner.lock().unwrap();
        assert!(inner.total <= 1024, "cache over budget: {}", inner.total);
        assert!(inner.entries.contains_key(&c), "newest entry must survive");
        assert!(!inner.entries.contains_key(&b), "LRU entry must be evicted");
        drop(inner);
        // evicted entries still answer correctly (disk + repopulate)
        let back = reg.results_bytes(&b, true).unwrap();
        assert!(String::from_utf8_lossy(&back).contains("\"tag\":\"b\""));
    }

    #[test]
    fn oversized_results_bypass_the_cache() {
        let dir = tmpdir("oversize");
        let reg = Registry::open_cfg(&dir, 64).unwrap();
        let id = reg.submit(JobSpec::default()).unwrap();
        reg.finish(
            &id,
            Ok(Json::from_pairs(vec![("pad", jstr(&"y".repeat(500)))])),
        )
        .unwrap();
        assert!(reg.cache.inner.lock().unwrap().entries.is_empty());
        // still served, straight from disk
        assert!(reg.results_bytes(&id, true).is_some());
    }

    #[test]
    fn health_counts_jobs_and_bare_registry_is_healthy() {
        let dir = tmpdir("health");
        let reg = Registry::open(&dir).unwrap();
        let (body, healthy) = reg.health();
        assert!(healthy, "no executors expected => healthy");
        assert_eq!(body.req("ok"), &Json::Bool(true));
        assert_eq!(body.req("version").as_str().unwrap(), env!("CARGO_PKG_VERSION"));
        let a = reg.submit(JobSpec::default()).unwrap();
        let b = reg.submit(JobSpec::default()).unwrap();
        reg.finish(&b, Err(anyhow::anyhow!("boom"))).unwrap();
        let (body, _) = reg.health();
        let jobs = body.req("jobs");
        assert_eq!(jobs.req("queued").as_usize().unwrap(), 1);
        assert_eq!(jobs.req("terminal").as_usize().unwrap(), 1);
        // a dead executor flips the verdict to 503
        reg.exec_expected.store(2, Ordering::SeqCst);
        reg.exec_live.store(1, Ordering::SeqCst);
        let (body, healthy) = reg.health();
        assert!(!healthy, "live < expected must be unhealthy");
        assert_eq!(body.req("exec").req("expected").as_usize().unwrap(), 2);
        let _ = a;
    }

    #[test]
    fn coord_ring_lives_in_memory_then_persists_at_finish() {
        let dir = tmpdir("coordring");
        let reg = Registry::open(&dir).unwrap();
        let id = reg.submit(JobSpec::default()).unwrap();
        assert_eq!(
            reg.coord_metrics(&id),
            Some(Json::Arr(Vec::new())),
            "known job without samples answers empty, not 404"
        );
        assert!(reg.coord_metrics("j999999").is_none(), "unknown job is None");
        let g = vec![coords::GroupStat { name: "w".into(), w_rms: 0.5, upd_rms: 0.25 }];
        reg.record_coords(&id, coords::sample_json(0, &g));
        reg.record_coords(&id, coords::sample_json(8, &g));
        let live = reg.coord_metrics(&id).unwrap();
        assert_eq!(live.as_arr().unwrap().len(), 2);
        reg.finish(&id, Ok(Json::obj())).unwrap();
        // ring drained to coords.json; the route now answers from disk
        assert!(reg.job_dir(&id).join("coords.json").exists());
        let disk = reg.coord_metrics(&id).unwrap();
        assert_eq!(disk, live, "persisted samples must match the live ring");
        assert_eq!(
            disk.as_arr().unwrap()[1].req("step").as_usize().unwrap(),
            8
        );
    }

    #[test]
    fn lazy_best_matches_eager_extract_best() {
        let docs = [
            r#"{"best":{"lr":0.01,"sigma_w":1.5},"best_val_loss":2.5,"curve":[1,2,3]}"#,
            r#"{"best":null,"best_val_loss":null}"#,
            r#"{"best_val_loss":2.0}"#,
            r#"{"best":{"lr":0.1}}"#,
            "{}",
        ];
        for d in docs {
            let eager = json::parse(d).ok().as_ref().and_then(extract_best);
            assert_eq!(lazy_best(d), eager, "lazy/eager disagree on {d}");
        }
    }

    #[test]
    fn terminal_bus_replays_state_for_late_watchers() {
        let dir = tmpdir("latebus");
        let spec = JobSpec::default();
        {
            let reg = Registry::open(&dir).unwrap();
            let id = reg.submit(spec).unwrap();
            reg.finish(&id, Ok(Json::obj())).unwrap();
        }
        let reg = Registry::open(&dir).unwrap();
        let bus = reg.bus("j000001").unwrap();
        let rx = bus.subscribe(0);
        let (_, ev) = rx.recv().unwrap();
        assert_eq!(ev, Event::JobUpdate { state: "done".into() });
        assert!(rx.recv().is_err(), "closed bus must disconnect after replay");
    }
}
