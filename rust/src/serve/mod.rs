//! Tuning-as-a-service (DESIGN.md §9): μTransfer's premise is that HP
//! tuning is *amortizable* — tune once on a small proxy, serve the result
//! to every large run.  This subsystem makes that a service instead of a
//! foreground process, in pure `std` (zero new dependencies):
//!
//! * [`events`] — the typed in-process event bus every long-running layer
//!   (train drive loop, sweep scheduler, SHA tuner) emits progress into;
//!   the offline CLI's stderr output is just the default sink.
//! * [`daemon`] — a durable job registry + queue executing sweep/
//!   transfer/SHA jobs on the existing sweep machinery, now across N
//!   executor slots whose trials share one fair-share worker budget
//!   ([`crate::util::pool::FairBudget`]).  Job specs and terminal states
//!   persist under `--state-dir`; journals and checkpoints (PR-4) make a
//!   SIGKILLed daemon resume its queue on restart without re-running
//!   completed trials.  Terminal results serialize once into an LRU byte
//!   cache.
//! * [`http`] + [`api`] — a minimal HTTP/1.1 server over
//!   `std::net::TcpListener` served by a bounded connection worker pool
//!   (beyond-capacity connects get `503` + `Retry-After`, never an
//!   unbounded thread spawn): JSON endpoints for submit/list/inspect/
//!   results/cancel, lazy partial reads (`?path=`), a journal tail, an
//!   SSE stream per job fed by the bus, and `GET /hp?width=…`, which
//!   answers the μTransfer question directly — the best transferred HPs
//!   recorded by any completed proxy sweep.
//!
//! CLI surface: `mutransfer serve --addr --state-dir` plus the client
//! subcommands `submit` / `status` / `results` / `watch` / `hp`, all
//! speaking the same HTTP code.

pub mod api;
pub mod daemon;
pub mod events;
pub mod http;

pub use daemon::{Daemon, JobKind, JobSpec, JobState, Registry, ServeConfig};
pub use events::{Event, EventBus, EventSink, StderrSink};
