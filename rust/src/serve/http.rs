//! Minimal HTTP/1.1 transport over `std::net` (DESIGN.md §9) — the
//! vendored crate set has no hyper/tokio, and the serve daemon needs only
//! a small, predictable subset:
//!
//! * server side: request parsing ([`read_request`]) with keep-alive, and
//!   response writers ([`respond`], [`respond_json`], [`sse_headers`] +
//!   [`sse_event`] for `text/event-stream`);
//! * client side: a keep-alive [`Client`] (the throughput bench hammers
//!   one connection per thread), a one-shot [`rpc`] helper for the CLI
//!   subcommands, and an [`sse`] reader for `watch`.
//!
//! Hard limits (8 KiB request line/header line, 64 headers, 1 MiB body)
//! turn malformed or hostile input into a clean 400/413 instead of
//! unbounded buffering.  Anything that fails mid-stream just drops the
//! connection — every durable state transition in the daemon is
//! idempotent, so a retried request is always safe.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Bind a listener with `SO_REUSEADDR`, which `std::net::TcpListener::bind`
/// does not set: a daemon restarted on the same `--addr` must be able to
/// re-bind while connections from its previous life sit in TIME_WAIT (the
/// kill‑9-and-restart recovery story, exercised by CI).  On Linux this
/// builds the socket through raw libc calls (no new crates); elsewhere it
/// falls back to plain bind with a bounded AddrInUse retry.
pub fn bind_reuse(addr: &str) -> Result<TcpListener> {
    let sa = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .with_context(|| format!("no address behind {addr}"))?;
    bind_reuse_sa(sa)
}

#[cfg(target_os = "linux")]
fn bind_reuse_sa(sa: std::net::SocketAddr) -> Result<TcpListener> {
    use std::os::fd::FromRawFd;
    use std::os::raw::{c_int, c_void};
    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_void,
            len: u32,
        ) -> c_int;
        fn bind(fd: c_int, addr: *const c_void, len: u32) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }
    const AF_INET: c_int = 2;
    const AF_INET6: c_int = 10;
    const SOCK_STREAM: c_int = 1;
    const SOCK_CLOEXEC: c_int = 0x80000;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    // `sockaddr_in` / `sockaddr_in6`, Linux layout; port in network order.
    #[repr(C)]
    struct SockaddrIn {
        family: u16,
        port: u16,
        addr: u32,
        zero: [u8; 8],
    }
    #[repr(C)]
    struct SockaddrIn6 {
        family: u16,
        port: u16,
        flowinfo: u32,
        addr: [u8; 16],
        scope: u32,
    }
    let os_err = || anyhow::Error::from(std::io::Error::last_os_error());
    unsafe {
        let domain = match sa {
            std::net::SocketAddr::V4(_) => AF_INET,
            std::net::SocketAddr::V6(_) => AF_INET6,
        };
        let fd = socket(domain, SOCK_STREAM | SOCK_CLOEXEC, 0);
        if fd < 0 {
            return Err(os_err()).context("socket()");
        }
        let one: c_int = 1;
        if setsockopt(
            fd,
            SOL_SOCKET,
            SO_REUSEADDR,
            &one as *const c_int as *const c_void,
            std::mem::size_of::<c_int>() as u32,
        ) != 0
        {
            let e = os_err();
            close(fd);
            return Err(e).context("setsockopt(SO_REUSEADDR)");
        }
        let rc = match sa {
            std::net::SocketAddr::V4(v4) => {
                let s = SockaddrIn {
                    family: AF_INET as u16,
                    port: v4.port().to_be(),
                    // octets are already network order; keep the bytes as-is
                    addr: u32::from_ne_bytes(v4.ip().octets()),
                    zero: [0; 8],
                };
                bind(
                    fd,
                    &s as *const SockaddrIn as *const c_void,
                    std::mem::size_of::<SockaddrIn>() as u32,
                )
            }
            std::net::SocketAddr::V6(v6) => {
                let s = SockaddrIn6 {
                    family: AF_INET6 as u16,
                    port: v6.port().to_be(),
                    flowinfo: v6.flowinfo(),
                    addr: v6.ip().octets(),
                    scope: v6.scope_id(),
                };
                bind(
                    fd,
                    &s as *const SockaddrIn6 as *const c_void,
                    std::mem::size_of::<SockaddrIn6>() as u32,
                )
            }
        };
        if rc != 0 {
            let e = os_err();
            close(fd);
            return Err(e).with_context(|| format!("bind({sa})"));
        }
        if listen(fd, 128) != 0 {
            let e = os_err();
            close(fd);
            return Err(e).context("listen()");
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

#[cfg(not(target_os = "linux"))]
fn bind_reuse_sa(sa: std::net::SocketAddr) -> Result<TcpListener> {
    // no raw-socket path off Linux: plain bind, retrying AddrInUse briefly
    // (covers quick restarts; TIME_WAIT-heavy restarts may still wait)
    for _ in 0..25 {
        match TcpListener::bind(sa) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
                std::thread::sleep(Duration::from_millis(200));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(TcpListener::bind(sa)?)
}

pub const MAX_LINE: usize = 8 * 1024;
pub const MAX_HEADERS: usize = 64;
pub const MAX_BODY: usize = 1024 * 1024;

/// One parsed request.  Header names are lower-cased; the query string is
/// split on `&`/`=` without percent-decoding (the API's query values are
/// plain integers).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: String,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive unless the client says otherwise.
    pub fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

fn read_limited_line(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte)? {
            0 => {
                if buf.is_empty() {
                    return Ok(None); // clean EOF between requests
                }
                bail!("connection closed mid-line");
            }
            _ => {
                // mutlint: allow(no-panic-serve, "index 0 of the fixed [u8; 1] read buffer is infallible")
                if byte[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    return Ok(Some(String::from_utf8(buf).context("non-utf8 header line")?));
                }
                // mutlint: allow(no-panic-serve, "index 0 of the fixed [u8; 1] read buffer is infallible")
                buf.push(byte[0]);
                if buf.len() > MAX_LINE {
                    bail!("header line exceeds {MAX_LINE} bytes");
                }
            }
        }
    }
}

/// Parse one request off the wire.  `Ok(None)` = the peer closed the
/// connection cleanly between requests (normal keep-alive shutdown).
///
/// Generic over `BufRead` so the fuzz harness can drive the parser from
/// in-memory byte slices.  Framing is deliberately strict — requests that
/// play Content-Length games (duplicates, signs, `Transfer-Encoding`) are
/// rejected outright rather than interpreted, because ambiguous framing
/// is exactly how request smuggling works.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>> {
    let line = match read_limited_line(r)? {
        None => return Ok(None),
        Some(l) if l.is_empty() => match read_limited_line(r)? {
            // tolerate one stray blank line between pipelined requests
            None => return Ok(None),
            Some(l2) => l2,
        },
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let target = parts.next().context("request line has no target")?;
    let version = parts.next().context("request line has no version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol {version}");
    }
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.to_string(), ""),
    };
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(k.to_string(), v.to_string());
    }
    let mut headers = BTreeMap::new();
    loop {
        let line = read_limited_line(r)?.context("connection closed inside headers")?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            bail!("more than {MAX_HEADERS} headers");
        }
        let (k, v) = line.split_once(':').context("malformed header")?;
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim().to_string();
        if k == "content-length" && headers.contains_key(&k) {
            // duplicate Content-Length is the classic smuggling vector;
            // silently keeping either copy would desync our framing from
            // any front proxy's
            bail!("duplicate content-length");
        }
        headers.insert(k, v);
    }
    if headers.contains_key("transfer-encoding") {
        // we never emit nor accept chunked bodies; a TE header combined
        // with Content-Length is smuggling shape #1, so reject TE outright
        bail!("transfer-encoding not supported");
    }
    let len: usize = match headers.get("content-length") {
        None => 0,
        Some(v) => {
            // digit-only: usize::from_str also accepts a leading '+',
            // which a stricter peer would frame differently
            if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                bail!("bad content-length");
            }
            v.parse().context("bad content-length")?
        }
    };
    if len > MAX_BODY {
        bail!("body of {len} bytes exceeds {MAX_BODY}");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("short body")?;
    Ok(Some(Request {
        method,
        path,
        query,
        headers,
        body: String::from_utf8(body).context("non-utf8 body")?,
    }))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Overload response sent by the acceptor when the connection pool is
/// full: `503` + `Retry-After` so well-behaved clients back off, and
/// `Connection: close` because no worker will ever service this socket.
pub fn respond_overload(w: &mut impl Write) -> std::io::Result<()> {
    let body = br#"{"error":"server at connection capacity"}"#;
    write!(
        w,
        "HTTP/1.1 503 Service Unavailable\r\nContent-Type: application/json\r\nContent-Length: {}\r\nRetry-After: 1\r\nConnection: close\r\n\r\n",
        body.len(),
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Write a complete response.  `keep_alive` controls the `Connection`
/// header; the caller loops on the same stream when it is true.
pub fn respond(
    w: &mut impl Write,
    status: u16,
    ctype: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )?;
    w.write_all(body)?;
    w.flush()
}

pub fn respond_json(
    w: &mut impl Write,
    status: u16,
    j: &Json,
    keep_alive: bool,
) -> std::io::Result<()> {
    respond(w, status, "application/json", j.to_string().as_bytes(), keep_alive)
}

pub fn error_json(status: u16, msg: &str) -> Json {
    Json::from_pairs(vec![("error", crate::util::json::jstr(msg))])
}

/// Start a Server-Sent-Events response.  No `Content-Length`: the stream
/// ends when the server closes the connection, so SSE responses always
/// carry `Connection: close`.
pub fn sse_headers(w: &mut impl Write) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()
}

/// One SSE frame: `id: <seq>` + single-line JSON `data:` payload + blank
/// line (the framing documented in DESIGN.md §9; our JSON writer never
/// emits raw newlines, so one `data:` line always suffices).
pub fn sse_event(w: &mut impl Write, seq: u64, data: &Json) -> std::io::Result<()> {
    write!(w, "id: {seq}\ndata: {}\n\n", data.to_string())?;
    w.flush()
}

/// SSE comment frame — a keep-alive ping that also detects dead clients.
pub fn sse_ping(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(b": ping\n\n")?;
    w.flush()
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// A keep-alive HTTP/1.1 client over one connection.
pub struct Client {
    r: BufReader<TcpStream>,
    w: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr} (is the daemon running?)"))?;
        stream.set_nodelay(true).ok();
        let r = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client { r, w: stream })
    }

    /// Issue one request and read the full response body.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let body = body.unwrap_or("");
        write!(
            self.w,
            "{method} {path} HTTP/1.1\r\nHost: mutransfer\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len(),
        )?;
        self.w.flush()?;
        let status_line = read_limited_line(&mut self.r)?.context("server closed connection")?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad status line {status_line:?}"))?;
        let mut len = 0usize;
        let mut close = false;
        loop {
            let line = read_limited_line(&mut self.r)?.context("connection closed in headers")?;
            if line.is_empty() {
                break;
            }
            let (k, v) = line.split_once(':').context("malformed response header")?;
            let k = k.trim().to_ascii_lowercase();
            let v = v.trim();
            if k == "content-length" {
                len = v.parse().context("bad content-length")?;
            } else if k == "connection" && v.eq_ignore_ascii_case("close") {
                close = true;
            }
        }
        let mut buf = vec![0u8; len];
        self.r.read_exact(&mut buf).context("short response body")?;
        if close {
            // server will drop the socket; force the next request onto a
            // fresh connection by poisoning this one
            self.w.shutdown(std::net::Shutdown::Both).ok();
        }
        Ok((status, String::from_utf8(buf).context("non-utf8 response")?))
    }
}

/// One-shot request on a fresh connection (the CLI subcommands).
pub fn rpc(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    Client::connect(addr)?.request(method, path, body)
}

/// Consume a Server-Sent-Events stream: `on_event(seq, data_json_text)`
/// per frame, until it returns `false` or the server ends the stream.
pub fn sse(
    addr: &str,
    path: &str,
    on_event: impl FnMut(u64, &str) -> bool,
) -> Result<()> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr} (is the daemon running?)"))?;
    stream.set_nodelay(true).ok();
    // generous idle timeout: the server pings every ~500ms, so hitting
    // this means the daemon really died mid-stream
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .ok();
    let mut w = stream.try_clone().context("cloning stream")?;
    write!(
        w,
        "GET {path} HTTP/1.1\r\nHost: mutransfer\r\nAccept: text/event-stream\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()?;
    let mut r = BufReader::new(stream);
    // status + headers
    let status_line = read_limited_line(&mut r)?.context("server closed connection")?;
    if !status_line.contains(" 200 ") {
        bail!("SSE request failed: {status_line}");
    }
    while let Some(line) = read_limited_line(&mut r)? {
        if line.is_empty() {
            break;
        }
    }
    sse_frames(&mut r, on_event)
}

/// Parse SSE frames off any `BufRead` until the stream ends or `on_event`
/// returns `false`.  Factored out of [`sse`] so the fuzz harness can feed
/// the frame parser truncated/garbage byte streams directly.
pub fn sse_frames<R: BufRead>(
    r: &mut R,
    mut on_event: impl FnMut(u64, &str) -> bool,
) -> Result<()> {
    let mut seq = 0u64;
    let mut data: Option<String> = None;
    loop {
        let line = match read_limited_line(r) {
            Ok(Some(l)) => l,
            Ok(None) => return Ok(()), // server ended the stream
            Err(e) => {
                // mid-frame EOF after the job finished is a normal close
                if data.is_none() {
                    return Ok(());
                }
                return Err(e).context("SSE stream died mid-frame");
            }
        };
        if let Some(rest) = line.strip_prefix("id:") {
            seq = rest.trim().parse().unwrap_or(seq);
        } else if let Some(rest) = line.strip_prefix("data:") {
            data = Some(rest.trim().to_string());
        } else if line.is_empty() {
            if let Some(d) = data.take() {
                if !on_event(seq, &d) {
                    return Ok(());
                }
            }
        }
        // comment lines (": ping") fall through untouched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Spin up a tiny echo server for transport-level tests.
    fn echo_server() -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                std::thread::spawn(move || {
                    let mut r = BufReader::new(stream.try_clone().unwrap());
                    let mut w = stream;
                    while let Ok(Some(req)) = read_request(&mut r) {
                        let keep = req.keep_alive();
                        let echo = Json::from_pairs(vec![
                            ("method", crate::util::json::jstr(&req.method)),
                            ("path", crate::util::json::jstr(&req.path)),
                            ("body", crate::util::json::jstr(&req.body)),
                            (
                                "q",
                                crate::util::json::jstr(
                                    req.query.get("x").map(|s| s.as_str()).unwrap_or(""),
                                ),
                            ),
                        ]);
                        if respond_json(&mut w, 200, &echo, keep).is_err() || !keep {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn bind_reuse_binds_accepts_and_rebinds() {
        let l = bind_reuse("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = l.accept().unwrap();
            let _ = s.write_all(b"x");
            // server-side active close -> this endpoint enters TIME_WAIT
            drop(s);
            drop(l);
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let mut b = [0u8; 1];
        c.read_exact(&mut b).unwrap();
        t.join().unwrap();
        drop(c);
        // the daemon-restart story: rebinding the same port right after
        // the old listener died (connections possibly in TIME_WAIT) works
        let l2 = bind_reuse(&addr.to_string()).unwrap();
        assert_eq!(l2.local_addr().unwrap().port(), addr.port());
    }

    #[test]
    fn keep_alive_round_trips() {
        let addr = echo_server().to_string();
        let mut c = Client::connect(&addr).unwrap();
        for i in 0..3 {
            let (st, body) = c
                .request("POST", &format!("/jobs?x={i}"), Some("{\"a\":1}"))
                .unwrap();
            assert_eq!(st, 200);
            let j = crate::util::json::parse(&body).unwrap();
            assert_eq!(j.req("method").as_str().unwrap(), "POST");
            assert_eq!(j.req("path").as_str().unwrap(), "/jobs");
            assert_eq!(j.req("q").as_str().unwrap(), format!("{i}"));
            assert_eq!(j.req("body").as_str().unwrap(), "{\"a\":1}");
        }
    }

    #[test]
    fn rpc_one_shot() {
        let addr = echo_server().to_string();
        let (st, body) = rpc(&addr, "GET", "/healthz", None).unwrap();
        assert_eq!(st, 200);
        assert!(body.contains("healthz"));
    }

    #[test]
    fn oversized_header_line_is_an_error() {
        let addr = echo_server();
        let mut s = TcpStream::connect(addr).unwrap();
        let long = "x".repeat(MAX_LINE + 10);
        // server drops the connection instead of buffering forever
        let _ = write!(s, "GET /{long} HTTP/1.1\r\n\r\n");
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        assert!(buf.is_empty(), "server must hang up on oversized lines");
    }

    #[test]
    fn smuggling_shapes_are_rejected() {
        let parse = |raw: &str| read_request(&mut raw.as_bytes());
        // duplicate Content-Length
        assert!(parse("POST /jobs HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\nab")
            .is_err());
        // any Transfer-Encoding
        assert!(parse("POST /jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").is_err());
        // non-digit / signed Content-Length
        for cl in ["abc", "+5", "-1", "1 2", ""] {
            assert!(
                parse(&format!("POST / HTTP/1.1\r\nContent-Length: {cl}\r\n\r\n")).is_err(),
                "content-length {cl:?} must be rejected"
            );
        }
        // a plain well-formed request still parses from a byte slice
        let req = parse("POST /jobs HTTP/1.1\r\nContent-Length: 2\r\n\r\nok")
            .unwrap()
            .unwrap();
        assert_eq!(req.body, "ok");
    }

    #[test]
    fn overload_response_is_a_parseable_503() {
        let mut buf = Vec::new();
        respond_overload(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 "), "{text}");
        assert!(text.contains("Retry-After: 1"), "{text}");
        assert!(text.contains("Connection: close"), "{text}");
    }

    #[test]
    fn sse_frames_parse() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = stream;
            let _ = read_request(&mut r).unwrap();
            sse_headers(&mut w).unwrap();
            sse_ping(&mut w).unwrap();
            for i in 1..=3u64 {
                sse_event(&mut w, i, &Json::from_pairs(vec![("n", crate::util::json::jnum(i as f64))]))
                    .unwrap();
            }
            // connection drops here -> client sees end of stream
        });
        let mut got = Vec::new();
        sse(&addr, "/jobs/x/events", |seq, data| {
            got.push((seq, data.to_string()));
            true
        })
        .unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].0, 1);
        assert!(got[2].1.contains("\"n\":3"));
    }
}
