//! Typed in-process event bus (DESIGN.md §9).
//!
//! Every long-running layer of the harness — the training drive loop
//! ([`crate::train`]), the sweep scheduler ([`crate::sweep::Sweep`]) and
//! the successive-halving tuner ([`crate::tuner::sha`]) — emits progress
//! through one [`EventSink`] instead of scattering `eprintln!` calls.
//! The sink is a capability, not a policy:
//!
//! * offline CLI runs get a [`StderrSink`], which reproduces the exact
//!   pre-bus stderr output (progress lines only when the sweep is
//!   verbose, warnings always);
//! * the `serve` daemon gives each job an [`EventBus`], which assigns a
//!   monotonically increasing sequence number to every event, retains the
//!   history for late subscribers, and fans live events out to SSE
//!   streams (`GET /jobs/:id/events`).
//!
//! Events serialize through [`Event::to_json`] (a `"type"`-tagged object)
//! — the wire format of the SSE `data:` frames — and parse back with
//! [`Event::from_json`] on the `watch` client side.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;

use crate::obs::metrics;
use crate::util::json::{jnum, jstr, Json};

/// One progress event from the tuning stack.  `key` fields name the trial
/// (the sweep job key) the event belongs to; daemon-level events
/// ([`Event::JobUpdate`]) have no key.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// daemon job lifecycle transition (`queued`/`running`/`done`/
    /// `failed`/`cancelled`); also the terminal event SSE watchers key on
    JobUpdate { state: String },
    /// a trial began executing (after journal skip / checkpoint lookup)
    TrialStarted { key: String },
    /// a validation eval completed at `step`
    StepEval { key: String, step: usize, val_loss: f64 },
    /// a durable snapshot was published (tmp-then-rename completed)
    CheckpointWritten { key: String, step: usize, path: String },
    /// a trial finished; `ordinal`/`total` are the progress counters the
    /// CLI renders as `[k/n]`
    TrialFinished {
        key: String,
        ordinal: usize,
        total: usize,
        train_loss: f64,
        val_loss: f64,
        diverged: bool,
        wall_secs: f64,
    },
    /// successive halving promoted the top of a rung
    RungPromoted { budget: usize, survivors: usize, promoted: usize },
    /// one `Sweep::run` batch drained (SHA emits one per rung)
    SweepDone { total: usize },
    /// a recoverable anomaly (ignored checkpoint, fingerprint mismatch…);
    /// `msg` is the full text the stderr sink prints after `warning: `
    Warning { key: String, msg: String },
    /// a daemon operational log line (job lifecycle, registry repair,
    /// persistence failures) — the `[serve] …` lines that predate the
    /// bus.  [`StderrSink`] prints `msg` verbatim so daemon stderr stays
    /// byte-identical; bus subscribers see it as a typed event.
    ServerLog { msg: String },
    /// live μ-coordinate telemetry sample (DESIGN.md §12): per-tensor
    /// `(name, w_rms, upd_rms)` where `upd_rms` is RMS(Δparam)·√fan_in —
    /// the width-normalized coordcheck signal, sampled every
    /// [`crate::obs::coords::SAMPLE_EVERY`] steps while a trial trains
    CoordStats {
        key: String,
        step: usize,
        groups: Vec<(String, f64, f64)>,
    },
}

impl Event {
    pub fn warning(key: &str, msg: impl Into<String>) -> Event {
        Event::Warning { key: key.to_string(), msg: msg.into() }
    }

    pub fn server_log(msg: impl Into<String>) -> Event {
        Event::ServerLog { msg: msg.into() }
    }

    /// The SSE wire form: a flat `"type"`-tagged JSON object.
    pub fn to_json(&self) -> Json {
        match self {
            Event::JobUpdate { state } => Json::from_pairs(vec![
                ("type", jstr("job_update")),
                ("state", jstr(state)),
            ]),
            Event::TrialStarted { key } => Json::from_pairs(vec![
                ("type", jstr("trial_started")),
                ("key", jstr(key)),
            ]),
            Event::StepEval { key, step, val_loss } => Json::from_pairs(vec![
                ("type", jstr("step_eval")),
                ("key", jstr(key)),
                ("step", jnum(*step as f64)),
                ("val_loss", jnum(*val_loss)),
            ]),
            Event::CheckpointWritten { key, step, path } => Json::from_pairs(vec![
                ("type", jstr("checkpoint")),
                ("key", jstr(key)),
                ("step", jnum(*step as f64)),
                ("path", jstr(path)),
            ]),
            Event::TrialFinished {
                key,
                ordinal,
                total,
                train_loss,
                val_loss,
                diverged,
                wall_secs,
            } => Json::from_pairs(vec![
                ("type", jstr("trial_finished")),
                ("key", jstr(key)),
                ("ordinal", jnum(*ordinal as f64)),
                ("total", jnum(*total as f64)),
                ("train_loss", jnum(*train_loss)),
                ("val_loss", jnum(*val_loss)),
                ("diverged", Json::Bool(*diverged)),
                ("wall_secs", jnum(*wall_secs)),
            ]),
            Event::RungPromoted { budget, survivors, promoted } => Json::from_pairs(vec![
                ("type", jstr("rung_promoted")),
                ("budget", jnum(*budget as f64)),
                ("survivors", jnum(*survivors as f64)),
                ("promoted", jnum(*promoted as f64)),
            ]),
            Event::SweepDone { total } => Json::from_pairs(vec![
                ("type", jstr("sweep_done")),
                ("total", jnum(*total as f64)),
            ]),
            Event::Warning { key, msg } => Json::from_pairs(vec![
                ("type", jstr("warning")),
                ("key", jstr(key)),
                ("msg", jstr(msg)),
            ]),
            Event::ServerLog { msg } => Json::from_pairs(vec![
                ("type", jstr("server_log")),
                ("msg", jstr(msg)),
            ]),
            Event::CoordStats { key, step, groups } => Json::from_pairs(vec![
                ("type", jstr("coord_stats")),
                ("key", jstr(key)),
                ("step", jnum(*step as f64)),
                (
                    "groups",
                    Json::Arr(
                        groups
                            .iter()
                            .map(|(name, w, u)| {
                                Json::from_pairs(vec![
                                    ("name", jstr(name)),
                                    ("w_rms", jnum(*w)),
                                    ("upd_rms", jnum(*u)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        }
    }

    /// Parse the wire form back (the `watch` client).  `None` for unknown
    /// or malformed objects — forward compatibility, not an error.
    pub fn from_json(j: &Json) -> Option<Event> {
        let s = |k: &str| j.get(k).and_then(|v| v.as_str()).map(str::to_string);
        let n = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let u = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        match j.get("type")?.as_str()? {
            "job_update" => Some(Event::JobUpdate { state: s("state")? }),
            "trial_started" => Some(Event::TrialStarted { key: s("key")? }),
            "step_eval" => Some(Event::StepEval {
                key: s("key")?,
                step: u("step"),
                val_loss: n("val_loss"),
            }),
            "checkpoint" => Some(Event::CheckpointWritten {
                key: s("key")?,
                step: u("step"),
                path: s("path").unwrap_or_default(),
            }),
            "trial_finished" => Some(Event::TrialFinished {
                key: s("key")?,
                ordinal: u("ordinal"),
                total: u("total"),
                train_loss: n("train_loss"),
                val_loss: n("val_loss"),
                diverged: j.get("diverged").and_then(|v| v.as_bool()).unwrap_or(false),
                wall_secs: n("wall_secs"),
            }),
            "rung_promoted" => Some(Event::RungPromoted {
                budget: u("budget"),
                survivors: u("survivors"),
                promoted: u("promoted"),
            }),
            "sweep_done" => Some(Event::SweepDone { total: u("total") }),
            "warning" => Some(Event::Warning {
                key: s("key").unwrap_or_default(),
                msg: s("msg")?,
            }),
            "server_log" => Some(Event::ServerLog { msg: s("msg")? }),
            "coord_stats" => Some(Event::CoordStats {
                key: s("key")?,
                step: u("step"),
                groups: j
                    .get("groups")
                    .and_then(|g| g.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|g| {
                                Some((
                                    g.get("name")?.as_str()?.to_string(),
                                    g.get("w_rms").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                                    g.get("upd_rms").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                                ))
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
            }),
            _ => None,
        }
    }
}

/// Where progress events go.  Implementations must be cheap and
/// non-blocking — emit sites sit on the train/sweep hot paths — and
/// thread-safe, because sweep workers emit concurrently.
pub trait EventSink: Send + Sync {
    fn emit(&self, ev: &Event);
}

/// The offline default: byte-for-byte the stderr output the CLI printed
/// before the bus existed.  Warnings always print; `[k/n]` trial progress
/// lines only when constructed with `progress` (the old `Sweep::verbose`).
pub struct StderrSink {
    progress: bool,
}

impl StderrSink {
    pub fn new(progress: bool) -> StderrSink {
        StderrSink { progress }
    }

    /// Warnings only — what the bare train driver used to print.  Even a
    /// quiet sink still counts every warning into the metrics registry
    /// (`mutransfer_warnings_total`), so anomalies that never reach a
    /// terminal remain visible at `GET /metrics`.
    pub fn quiet() -> StderrSink {
        StderrSink { progress: false }
    }
}

impl EventSink for StderrSink {
    fn emit(&self, ev: &Event) {
        count_event(ev);
        match ev {
            Event::Warning { msg, .. } => eprintln!("warning: {msg}"),
            // daemon ops lines printed unconditionally before the bus
            // existed; `msg` carries its own `[serve] ` prefix
            Event::ServerLog { msg } => eprintln!("{msg}"),
            Event::TrialFinished {
                key,
                ordinal,
                total,
                train_loss,
                val_loss,
                diverged,
                wall_secs,
            } if self.progress => eprintln!(
                "[{ordinal}/{total}] {key} -> train {train_loss:.4} val {val_loss:.4}{} ({wall_secs:.1}s)",
                if *diverged { " DIVERGED" } else { "" },
            ),
            _ => {}
        }
    }
}

/// Every sink — including the quiet/null ones — feeds the metrics
/// registry, so a swallowed `Event::Warning` still shows up in
/// `mutransfer_warnings_total` at `GET /metrics` even when no sink
/// prints or retains it.  (The bus counts via its own `emit`; wrapper
/// sinks that *forward* to another sink must not call this again.)
fn count_event(ev: &Event) {
    if let Event::Warning { .. } = ev {
        metrics::WARNINGS.inc();
    }
}

/// Swallow everything (benches that only want the numbers) — except the
/// warning count, which no sink may drop.
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&self, ev: &Event) {
        count_event(ev);
    }
}

/// Capture events in memory — unit tests and the bench harness.
#[derive(Default)]
pub struct CollectSink {
    pub events: Mutex<Vec<Event>>,
}

impl CollectSink {
    pub fn take(&self) -> Vec<Event> {
        std::mem::take(&mut self.events.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl EventSink for CollectSink {
    fn emit(&self, ev: &Event) {
        self.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(ev.clone());
    }
}

/// History cap: a bus never retains more than this many events (a 1k-trial
/// sweep with per-step evals stays far below it; the cap only guards
/// against pathological emitters).  Late subscribers replay from whatever
/// is retained.
const HISTORY_CAP: usize = 65_536;

struct BusState {
    seq: u64,
    history: std::collections::VecDeque<(u64, Event)>,
    subs: Vec<Sender<(u64, Event)>>,
    closed: bool,
}

/// Fan-out bus for one daemon job: every emitted event gets the next
/// sequence number (starting at 1), is retained for replay, and is pushed
/// to every live subscriber.  [`EventBus::close`] drops the subscriber
/// channels, which is how SSE streams learn the job is over.
pub struct EventBus {
    inner: Mutex<BusState>,
}

impl Default for EventBus {
    fn default() -> EventBus {
        EventBus::new()
    }
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus {
            inner: Mutex::new(BusState {
                seq: 0,
                history: Default::default(),
                subs: Vec::new(),
                closed: false,
            }),
        }
    }

    /// Subscribe from just after `after` (0 = full history): retained
    /// events with `seq > after` are pre-loaded into the channel, then
    /// live events follow.  If the bus is already closed the receiver
    /// yields the replay and then disconnects immediately.
    pub fn subscribe(&self, after: u64) -> Receiver<(u64, Event)> {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut b = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        for (seq, ev) in b.history.iter() {
            if *seq > after {
                let _ = tx.send((*seq, ev.clone()));
            }
        }
        if !b.closed {
            b.subs.push(tx);
        }
        rx
    }

    /// Sequence number of the latest event (0 = none yet).
    pub fn seq(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).seq
    }

    /// Stop accepting events and disconnect every subscriber.  History is
    /// retained for late `subscribe` calls.
    pub fn close(&self) {
        let mut b = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        b.closed = true;
        b.subs.clear();
    }
}

impl EventSink for EventBus {
    fn emit(&self, ev: &Event) {
        count_event(ev);
        metrics::BUS_EVENTS.inc();
        let mut b = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if b.closed {
            return;
        }
        b.seq += 1;
        let seq = b.seq;
        b.history.push_back((seq, ev.clone()));
        if b.history.len() > HISTORY_CAP {
            b.history.pop_front();
        }
        // dead subscribers (disconnected SSE clients) drop out here
        b.subs.retain(|s| s.send((seq, ev.clone())).is_ok());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(k: &str) -> Event {
        Event::TrialStarted { key: k.to_string() }
    }

    #[test]
    fn event_json_roundtrip() {
        let cases = vec![
            Event::JobUpdate { state: "running".into() },
            ev("a/b@r4"),
            Event::StepEval { key: "k".into(), step: 10, val_loss: 2.5 },
            Event::CheckpointWritten { key: "k".into(), step: 5, path: "/tmp/x.ckpt".into() },
            Event::TrialFinished {
                key: "k".into(),
                ordinal: 3,
                total: 8,
                train_loss: 2.1,
                val_loss: 2.3,
                diverged: false,
                wall_secs: 0.5,
            },
            Event::RungPromoted { budget: 20, survivors: 8, promoted: 4 },
            Event::SweepDone { total: 12 },
            Event::warning("k", "ignoring checkpoint /x: bad magic"),
            Event::server_log("[serve] job j-1 started on slot 0"),
            Event::CoordStats {
                key: "k".into(),
                step: 16,
                groups: vec![
                    ("block0.wq".into(), 0.5, 0.25),
                    ("unembed".into(), 1.0, 0.125),
                ],
            },
        ];
        for c in cases {
            let j = crate::util::json::parse(&c.to_json().to_string()).unwrap();
            assert_eq!(Event::from_json(&j).unwrap(), c, "case {c:?}");
        }
    }

    #[test]
    fn from_json_tolerates_unknown_types() {
        let j = crate::util::json::parse(r#"{"type":"from_the_future","x":1}"#).unwrap();
        assert!(Event::from_json(&j).is_none());
    }

    #[test]
    fn bus_assigns_sequence_and_replays() {
        let bus = EventBus::new();
        bus.emit(&ev("a"));
        bus.emit(&ev("b"));
        // full replay
        let rx = bus.subscribe(0);
        assert_eq!(rx.try_recv().unwrap(), (1, ev("a")));
        assert_eq!(rx.try_recv().unwrap(), (2, ev("b")));
        // live delivery
        bus.emit(&ev("c"));
        assert_eq!(rx.try_recv().unwrap(), (3, ev("c")));
        // resume-from-seq replay skips what the client already saw
        let rx2 = bus.subscribe(2);
        assert_eq!(rx2.try_recv().unwrap(), (3, ev("c")));
        assert!(rx2.try_recv().is_err());
    }

    #[test]
    fn closed_bus_disconnects_subscribers_and_drops_emits() {
        let bus = EventBus::new();
        bus.emit(&ev("a"));
        let rx = bus.subscribe(0);
        bus.close();
        bus.emit(&ev("b")); // dropped
        assert_eq!(rx.recv().unwrap(), (1, ev("a")));
        // channel is disconnected after the replay: recv errors, no hang
        assert!(rx.recv().is_err());
        assert_eq!(bus.seq(), 1);
        // late subscriber still gets the retained history, then EOF
        let rx2 = bus.subscribe(0);
        assert_eq!(rx2.recv().unwrap(), (1, ev("a")));
        assert!(rx2.recv().is_err());
    }

    #[test]
    fn quiet_and_null_sinks_still_count_warnings() {
        // Delta-based: the registry is process-global and other tests may
        // emit warnings concurrently, so assert growth, not equality.
        let before = metrics::WARNINGS.get();
        NullSink.emit(&Event::warning("k", "dropped on the floor"));
        let bus = EventBus::new();
        bus.emit(&Event::warning("k", "onto the bus"));
        // progress events do not count as warnings
        NullSink.emit(&ev("not-a-warning"));
        assert!(
            metrics::WARNINGS.get() >= before + 2,
            "quiet sinks must count warnings into mutransfer_warnings_total"
        );
    }

    #[test]
    fn collect_sink_captures() {
        let s = CollectSink::default();
        s.emit(&ev("x"));
        s.emit(&Event::SweepDone { total: 1 });
        let got = s.take();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], ev("x"));
        assert!(s.take().is_empty());
    }
}
