//! Hyperparameter search: spaces, samplers, trials, selection.
//!
//! The paper deliberately uses plain random / grid search ("it is only for
//! scientific reasons that we use either grid search or random search
//! throughout this work", §10.1); both are implemented here, plus a
//! low-discrepancy Halton sampler as an extension, and — in [`sha`] —
//! synchronous successive halving over the checkpoint subsystem (the
//! paper notes fancier tuners compose with μTransfer — they tune the
//! proxy).

pub mod sha;

use std::collections::BTreeMap;

use crate::init::rng::Rng;
use crate::mup::HyperParams;
use crate::util::json::{jnum, Json};

/// One tunable dimension.
#[derive(Debug, Clone)]
pub enum Dim {
    /// log-uniform continuous (LR-like)
    LogUniform { lo: f64, hi: f64 },
    /// uniform continuous
    Uniform { lo: f64, hi: f64 },
    /// explicit grid of values (the paper's 2^z grids, App. F.1/F.2)
    Grid(Vec<f64>),
}

impl Dim {
    /// The paper's `base × 2^z, z ∈ {zlo, zlo+step, …, zhi}` grid shape.
    ///
    /// Iterates an integer index (`zlo + i·step`) rather than accumulating
    /// `z += step`: for steps that are not exact binary fractions (0.1,
    /// 0.25·3, …) the accumulated float error could overshoot `zhi` and
    /// silently drop the grid endpoint.
    pub fn pow2_grid(base: f64, zlo: f64, zhi: f64, step: f64) -> Dim {
        assert!(step > 0.0, "pow2_grid needs step > 0, got {step}");
        let count = if zhi < zlo {
            0
        } else {
            // same tolerance the old loop used for its `z <= zhi` test
            ((zhi - zlo + 1e-9) / step) as usize + 1
        };
        Dim::Grid(
            (0..count)
                .map(|i| base * 2f64.powf(zlo + i as f64 * step))
                .collect(),
        )
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Dim::LogUniform { lo, hi } => rng.log_uniform(*lo, *hi),
            Dim::Uniform { lo, hi } => rng.range(*lo, *hi),
            Dim::Grid(vals) => vals[rng.below(vals.len())],
        }
    }

    /// Map a quasi-random u in [0,1) into the dimension.
    pub fn from_unit(&self, u: f64) -> f64 {
        match self {
            Dim::LogUniform { lo, hi } => (lo.ln() + u * (hi.ln() - lo.ln())).exp(),
            Dim::Uniform { lo, hi } => lo + u * (hi - lo),
            Dim::Grid(vals) => vals[((u * vals.len() as f64) as usize).min(vals.len() - 1)],
        }
    }
}

/// Named search space over [`HyperParams`] fields.
#[derive(Debug, Clone, Default)]
pub struct SearchSpace {
    pub dims: Vec<(String, Dim)>,
}

impl SearchSpace {
    pub fn new() -> SearchSpace {
        SearchSpace::default()
    }

    pub fn with(mut self, name: &str, dim: Dim) -> SearchSpace {
        self.dims.push((name.to_string(), dim));
        self
    }

    /// The IWSLT grid (App. F.1): η, α_output, α_attn.
    pub fn iwslt_like() -> SearchSpace {
        SearchSpace::new()
            .with("lr", Dim::pow2_grid(5e-4, -1.5, 1.25, 0.25))
            .with("alpha_output", Dim::pow2_grid(1.0, -8.0, 7.0, 1.0))
            .with("alpha_attn", Dim::pow2_grid(1.0, -3.0, 8.0, 1.0))
    }

    /// The BERT grid (App. F.3): η, η_emb ratio, α_output, α_attn, σ.
    pub fn bert_like() -> SearchSpace {
        SearchSpace::new()
            .with("lr", Dim::pow2_grid(1e-4, 1.5, 3.5, 0.5))
            .with("lr_emb_ratio", Dim::pow2_grid(1.0, -1.0, 1.0, 0.5))
            .with("alpha_output", Dim::pow2_grid(1.0, 2.0, 6.0, 2.0))
            .with("alpha_attn", Dim::pow2_grid(1.0, 3.0, 7.0, 0.5))
            .with("sigma", Dim::pow2_grid(1.0, -2.0, 2.0, 1.0))
    }

    /// The GPT-3 space (App. F.4): continuous log-uniform draws.
    pub fn gpt3_like() -> SearchSpace {
        SearchSpace::new()
            .with("lr", Dim::LogUniform { lo: 1e-4, hi: 1e-1 })
            .with("sigma", Dim::LogUniform { lo: 0.1, hi: 10.0 })
            .with(
                "alpha_attn",
                Dim::LogUniform {
                    lo: 0.25,
                    hi: 4.0,
                },
            )
            .with(
                "alpha_output",
                Dim::LogUniform {
                    lo: 0.25,
                    hi: 4.0,
                },
            )
            .with("alpha_embed", Dim::LogUniform { lo: 0.1, hi: 10.0 })
    }

    /// Draw a random assignment.
    pub fn sample(&self, rng: &mut Rng) -> Assignment {
        Assignment {
            values: self
                .dims
                .iter()
                .map(|(n, d)| (n.clone(), d.sample(rng)))
                .collect(),
        }
    }

    /// Halton low-discrepancy sequence point `i` (extension).
    pub fn halton(&self, i: usize) -> Assignment {
        const PRIMES: [usize; 8] = [2, 3, 5, 7, 11, 13, 17, 19];
        Assignment {
            values: self
                .dims
                .iter()
                .enumerate()
                .map(|(k, (n, d))| {
                    let u = radical_inverse(i + 1, PRIMES[k % PRIMES.len()]);
                    (n.clone(), d.from_unit(u))
                })
                .collect(),
        }
    }

    /// Full cartesian grid (only sensible for 1-2 dims).
    pub fn grid(&self) -> Vec<Assignment> {
        let mut out = vec![Assignment::default()];
        for (name, dim) in &self.dims {
            let vals = match dim {
                Dim::Grid(v) => v.clone(),
                _ => panic!("grid() requires Grid dims ({name} is continuous)"),
            };
            let mut next = Vec::with_capacity(out.len() * vals.len());
            for a in &out {
                for &v in &vals {
                    let mut b = a.clone();
                    b.values.insert(name.clone(), v);
                    next.push(b);
                }
            }
            out = next;
        }
        out
    }
}

fn radical_inverse(mut i: usize, base: usize) -> f64 {
    let mut inv = 0.0;
    let mut f = 1.0 / base as f64;
    while i > 0 {
        inv += f * (i % base) as f64;
        i /= base;
        f /= base as f64;
    }
    inv
}

/// A sampled HP assignment (name → value).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Assignment {
    pub values: BTreeMap<String, f64>,
}

impl Assignment {
    pub fn single(name: &str, v: f64) -> Assignment {
        let mut a = Assignment::default();
        a.values.insert(name.to_string(), v);
        a
    }

    /// Apply onto a `HyperParams` baseline.
    pub fn apply(&self, mut hp: HyperParams) -> HyperParams {
        for (k, &v) in &self.values {
            match k.as_str() {
                "lr" => hp.lr = v,
                "sigma" => hp.sigma = v,
                "alpha_output" => hp.alpha_output = v,
                "alpha_attn" => hp.alpha_attn = v,
                "alpha_embed" => hp.alpha_embed = v,
                "lr_emb_ratio" => hp.lr_emb_ratio = v,
                "beta1" => hp.beta1 = v,
                "beta2" => hp.beta2 = v,
                "eps" => hp.eps = v,
                "weight_decay" => hp.weight_decay = v,
                "momentum" => hp.momentum = v,
                other => panic!("unknown HP dimension {other}"),
            }
        }
        hp
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (k, &v) in &self.values {
            o.set(k, jnum(v));
        }
        o
    }
}

/// Result of evaluating one assignment.
#[derive(Debug, Clone)]
pub struct Trial {
    pub assignment: Assignment,
    /// selection metric (validation loss; NaN = diverged)
    pub val_loss: f64,
    pub train_loss: f64,
    pub diverged: bool,
    pub flops: f64,
}

impl Trial {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("assignment", self.assignment.to_json()),
            ("val_loss", jnum(self.val_loss)),
            ("train_loss", jnum(self.train_loss)),
            ("diverged", Json::Bool(self.diverged)),
            ("flops", jnum(self.flops)),
        ])
    }
}

/// Pick the best trial by validation loss (the paper's §7 selection rule).
/// Diverged trials never win.  None if *everything* diverged.
pub fn select_best(trials: &[Trial]) -> Option<&Trial> {
    trials
        .iter()
        .filter(|t| !t.diverged && t.val_loss.is_finite())
        .min_by(|a, b| a.val_loss.total_cmp(&b.val_loss))
}

/// Best-so-far curve: value of the selection metric after k samples —
/// the x-axis of the Fig. 6 (right) sample-efficiency plot.
pub fn best_so_far(trials: &[Trial]) -> Vec<f64> {
    let mut best = f64::NAN;
    trials
        .iter()
        .map(|t| {
            if !t.diverged && t.val_loss.is_finite() && (best.is_nan() || t.val_loss < best) {
                best = t.val_loss;
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_grid_matches_paper_f1() {
        // η: 5e-4 × 2^z, z ∈ {-1.5, -1.25, …, 1.25} -> 12 values
        let d = Dim::pow2_grid(5e-4, -1.5, 1.25, 0.25);
        match &d {
            Dim::Grid(v) => {
                assert_eq!(v.len(), 12);
                assert!((v[0] - 5e-4 * 2f64.powf(-1.5)).abs() < 1e-12);
                assert!((v[11] - 5e-4 * 2f64.powf(1.25)).abs() < 1e-12);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn pow2_grid_keeps_endpoints_with_fractional_step() {
        // Regression: `z += 0.1` accumulated float error past zhi and
        // dropped the z = 7 endpoint; the integer-indexed form keeps it.
        let d = Dim::pow2_grid(1.0, -8.0, 7.0, 0.1);
        match &d {
            Dim::Grid(v) => {
                assert_eq!(v.len(), 151); // z ∈ {-8.0, -7.9, …, 7.0}
                assert_eq!(v[0], 2f64.powf(-8.0));
                assert!(
                    (v[150] / 2f64.powf(7.0) - 1.0).abs() < 1e-12,
                    "endpoint missing or wrong: {}",
                    v[150]
                );
            }
            _ => panic!(),
        }
    }

    #[test]
    fn sample_in_space() {
        let space = SearchSpace::iwslt_like();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let a = space.sample(&mut rng);
            assert_eq!(a.values.len(), 3);
            let lr = a.values["lr"];
            assert!(lr > 1e-4 && lr < 2e-3);
        }
    }

    #[test]
    fn assignment_applies() {
        let a = Assignment {
            values: [("lr".to_string(), 0.01), ("alpha_output".to_string(), 4.0)]
                .into_iter()
                .collect(),
        };
        let hp = a.apply(HyperParams::default());
        assert_eq!(hp.lr, 0.01);
        assert_eq!(hp.alpha_output, 4.0);
        assert_eq!(hp.beta1, 0.9); // untouched default
    }

    #[test]
    #[should_panic]
    fn unknown_dimension_panics() {
        Assignment::single("bogus", 1.0).apply(HyperParams::default());
    }

    #[test]
    fn grid_cartesian_product() {
        let space = SearchSpace::new()
            .with("lr", Dim::Grid(vec![0.1, 0.2]))
            .with("sigma", Dim::Grid(vec![1.0, 2.0, 3.0]));
        let g = space.grid();
        assert_eq!(g.len(), 6);
        assert!(g.iter().any(|a| a.values["lr"] == 0.2 && a.values["sigma"] == 3.0));
    }

    #[test]
    fn halton_deterministic_and_spread() {
        let space = SearchSpace::new().with("lr", Dim::Uniform { lo: 0.0, hi: 1.0 });
        let xs: Vec<f64> = (0..16).map(|i| space.halton(i).values["lr"]).collect();
        assert_eq!(xs[0], 0.5); // radical inverse base 2 of 1
        // all distinct and well spread
        for i in 0..16 {
            for j in 0..i {
                assert!((xs[i] - xs[j]).abs() > 1e-6);
            }
        }
        let low = xs.iter().filter(|&&x| x < 0.5).count();
        assert!((6..=10).contains(&low));
    }

    #[test]
    fn select_best_skips_diverged() {
        let t = |v: f64, d: bool| Trial {
            assignment: Assignment::default(),
            val_loss: v,
            train_loss: v,
            diverged: d,
            flops: 0.0,
        };
        let trials = vec![t(1.0, true), t(2.0, false), t(1.5, false), t(f64::NAN, false)];
        assert_eq!(select_best(&trials).unwrap().val_loss, 1.5);
        assert!(select_best(&[t(1.0, true)]).is_none());
    }

    #[test]
    fn best_so_far_monotone() {
        let t = |v: f64| Trial {
            assignment: Assignment::default(),
            val_loss: v,
            train_loss: v,
            diverged: false,
            flops: 0.0,
        };
        let curve = best_so_far(&[t(3.0), t(4.0), t(2.0), t(2.5)]);
        assert_eq!(curve, vec![3.0, 3.0, 2.0, 2.0]);
    }
}
